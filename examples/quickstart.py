"""Quickstart: partition a mobile CNN across the FPGA-GPU platform model,
inspect the chosen schemes, and run the partitioned network in JAX — first
through the interpreted reference, then through the compiled engine.

    PYTHONPATH=src python examples/quickstart.py [--net mobilenetv2]

For the serving layer on top of the engine (dynamic batching, multi-plan
residency, async dispatch), see ``examples/serving_quickstart.py``.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.executor import compile_network
from repro.core.graph import NETWORKS
from repro.core.hetero import init_network, run_network
from repro.core.partitioner import partition_network, summarize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="mobilenetv2", choices=list(NETWORKS))
    args = ap.parse_args()

    mods = NETWORKS[args.net]()
    print(f"== {args.net}: {len(mods)} modules ==")

    plans = partition_network(mods, paper_faithful=True)
    for p in plans:
        if p.scheme != "gpu_only":
            print(f"  {p.module:16s} -> {p.scheme:16s} g_par={p.g_par:<3d} "
                  f"E x{p.energy_gain:.2f} lat x{p.speedup:.2f}  ({p.note})")
    s = summarize(plans)
    print(f"network: energy x{s['energy_gain']:.2f} "
          f"({s['gpu_only_energy_mJ']:.1f} -> {s['energy_mJ']:.1f} mJ), "
          f"latency x{s['speedup']:.2f} "
          f"({s['gpu_only_latency_ms']:.2f} -> {s['latency_ms']:.2f} ms)")
    print(f"FPGA budget used: {s['fpga_macs']} MACs, "
          f"{s['fpga_bytes']//1024} KiB on-chip")

    # the plan is executable, not just priced:
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, 224, 224, 3))
    params = init_network(mods, jax.random.PRNGKey(0))
    ref = run_network(mods, params, x)
    het = run_network(mods, params, x, plans)
    cos = float(jnp.sum(ref * het)
                / (jnp.linalg.norm(ref) * jnp.linalg.norm(het)))
    print(f"hetero-vs-fp32 cosine similarity: {cos:.5f} "
          f"(int8 on the FPGA substrate)")

    # ... and compiled: jit-once execution with weights quantized at
    # compile time and kernel routing burned into the trace
    engine = compile_network(mods, plans)
    prepared = engine.prepare(params)
    out = engine(prepared, x)
    cos = float(jnp.sum(het * out)
                / (jnp.linalg.norm(het) * jnp.linalg.norm(out)))

    def timed(fn, reps=3):
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / reps * 1e3

    t_int = timed(lambda: run_network(mods, params, x, plans))
    t_cmp = timed(lambda: engine(prepared, x))
    print(f"compiled engine: cosine vs interpreted {cos:.5f}; "
          f"{t_int:.1f} ms/call interpreted -> {t_cmp:.1f} ms/call "
          f"compiled ({t_int / t_cmp:.1f}x)")


if __name__ == "__main__":
    main()
