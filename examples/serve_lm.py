"""Serve a small model with batched requests through the slot scheduler
(prefill + lockstep decode, continuous-batching style).

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args()
    outputs = serve_main(["--arch", args.arch,
                          "--requests", str(args.requests),
                          "--prompt-len", "12", "--gen", "24"])
    for rid, toks in outputs.items():
        print(f"request {rid}: generated {len(toks)} tokens: {toks[:10]}...")


if __name__ == "__main__":
    main()
