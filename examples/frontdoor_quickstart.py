"""Front-door quickstart: put the whole serving stack behind real HTTP.

Builds a two-worker shared-nothing fleet (each worker an in-process
``HeteroServer`` with its own compiled-plan residency), fronts it with
the ``Router`` behind the asyncio ``FrontDoor``, and then exercises the
robustness story end to end with a plain blocking HTTP client:

  1. serve requests and verify the rows coming back THROUGH the socket
     bit-match a batch-1 oracle engine call,
  2. re-serve the same image over ONE keep-alive socket in the binary
     ``application/x-tensor`` framing and verify both framings
     bit-match (protocol v2: no reconnect, no base64),
  3. saturate a token bucket and read the typed 429 + Retry-After shed,
  4. kill one worker mid-fleet and watch requests keep answering the
     SAME bits (least-outstanding failover + one retry on the healthy
     worker, probe-based ejection),
  5. gracefully drain: the fence turns new requests into typed 503s
     while everything already admitted still resolves.

    PYTHONPATH=src python examples/frontdoor_quickstart.py [--n 8]

The default workload is a tiny fire module so the demo compiles in
seconds; pass ``--net mobilenetv2 --res 32`` for a real zoo network.
See docs/serving-frontdoor.md for the wire protocol and the router's
ejection/reinstatement cycle.
"""
import argparse
import http.client
import json
import time
import urllib.error
import urllib.request

import numpy as np

from repro.frontend import FrontDoor, LocalWorker, Router, ServerThread, wire
from repro.frontend.worker import build_server


def post(port, path, body=None, timeout=60, headers=None):
    data = b"" if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", data=data, method="POST",
        headers={"Content-Type": "application/json", **(headers or {})})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.load(r), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.load(e), dict(e.headers)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8, help="requests per phase")
    ap.add_argument("--net", default="tiny",
                    help="'tiny' (fire module, fast) or a zoo name")
    ap.add_argument("--res", type=int, default=32,
                    help="input resolution for zoo networks")
    args = ap.parse_args()

    if args.net == "tiny":
        netspec = {"kind": "fire", "name": "tiny", "hw": [8, 8],
                   "c_in": 16, "squeeze": 4, "expand": 8, "seed": 0}
        shape = (8, 8, 16)
    else:
        netspec = {"kind": "zoo", "name": args.net,
                   "res": [args.res, args.res], "seed": 0}
        shape = (args.res, args.res, 3)
    spec = {"networks": [netspec], "server": {"max_wait_ms": 2.0}}
    name = netspec["name"]

    print(f"== building 2-worker fleet ({name}) ==")
    workers = [LocalWorker(f"w{i}", lambda: build_server(spec))
               for i in range(2)]
    router = Router(workers, rate=20.0, burst=4, auto_restart=False,
                    probe_interval_s=0.05, eject_after=1)
    door = FrontDoor(router)
    with ServerThread(door, also_start=(router,)) as h:
        rng = np.random.default_rng(0)
        x = rng.standard_normal(shape).astype(np.float32)
        payload = wire.infer_payload(name, x)

        # 1. rows through the socket bit-match the in-process oracle
        status, body, _ = post(h.port, "/v1/infer", payload)
        assert status == 200, body
        ref = wire.decode_array(body["result"])
        oracle = np.asarray(workers[0].server.submit(name, x).result(60))
        assert np.array_equal(ref, oracle), "wire row != batch-1 oracle"
        print(f"[1] served over HTTP, row bit-matches oracle "
              f"(shape {ref.shape})")

        # 2. protocol v2: one keep-alive socket, binary framing both
        # ways, on the deadline-critical class-0 lane (3x refill weight)
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=60)
        body_bin, hdr_bin = wire.infer_request(
            name, x, priority=0, binary=True,
            accept=wire.TENSOR_CONTENT_TYPE)
        for i in range(3):
            conn.request("POST", "/v1/infer", body=body_bin,
                         headers=hdr_bin)
            r = conn.getresponse()
            raw = r.read()
            assert r.status == 200, raw
            assert r.getheader("Content-Type") == wire.TENSOR_CONTENT_TYPE
            row = wire.decode_tensor(raw)
            assert np.array_equal(row, ref), "binary framing != base64"
            time.sleep(0.1)                  # stay inside the lane's rate
        conn.close()
        frame_b = len(body_bin)
        json_b = len(json.dumps(payload).encode())
        print(f"[2] 3 binary-framed requests on ONE socket bit-match "
              f"the base64 path (frame {frame_b} B vs JSON {json_b} B, "
              f"keepalive_reuses={door.keepalive_reuses})")

        # 3. saturate the token bucket -> typed 429 + Retry-After
        sheds = 0
        for _ in range(20):
            status, body, headers = post(h.port, "/v1/infer", payload)
            if status == 429:
                sheds += 1
                retry_after = headers.get("Retry-After")
        assert sheds > 0, "burst never shed"
        print(f"[3] burst of 20 shed {sheds} typed 429s "
              f"(Retry-After: {retry_after}s) — admission is pre-body")
        time.sleep(0.2)                      # let the bucket refill

        # 4. kill one worker mid-fleet: answers keep coming, same bits
        # (class-0 lane via the X-Priority header — admission is
        # pre-body, so its 3x refill weight rides out the pressure the
        # shed phase left on the default lane)
        payload0 = wire.infer_payload(name, x, priority=0)
        workers[0].crash()
        served = 0
        for _ in range(args.n):
            status, body, _ = post(h.port, "/v1/infer", payload0,
                                   headers={"X-Priority": "0"})
            if status == 200:
                assert np.array_equal(wire.decode_array(body["result"]),
                                      ref), "failover changed the answer"
                served += 1
            time.sleep(0.1)
        snap = h.call(router.metrics())[1]
        w = snap["workers"]
        print(f"[4] killed w0 mid-fleet: {served}/{args.n} served "
              f"bit-identically; w0={w['w0']['state']}, "
              f"w1={w['w1']['state']}, "
              f"retries={snap['counters']['retries']}, "
              f"ejections={snap['counters']['ejections']}")
        assert served == args.n

        # 4. graceful drain: fence + resolve, then typed 503
        status, body, _ = post(h.port, "/drain")
        assert status == 200 and body["drained"], body
        print(f"[5] drained in {body['elapsed_s'] * 1e3:.0f} ms "
              f"(outstanding={body['outstanding']})")
        status, body, _ = post(h.port, "/v1/infer", payload)
        assert status == 503 and body["error"] == "shutdown", body
        print(f"[5] post-drain request -> typed {status} "
              f"'{body['error']}' (retryable={body['retryable']})")
    print("done: the full robustness story ran over real sockets")


if __name__ == "__main__":
    main()
