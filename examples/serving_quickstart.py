"""Serving quickstart: all three paper networks resident behind one
``HeteroServer`` — multi-resolution lanes, priority QoS, dynamic batching
into padded bucket shapes, async submit/future dispatch, and a mid-stream
prepared-parameter hot-swap, with per-request results bit-identical to
batch-1 engine calls of the serving parameter generation.

    PYTHONPATH=src python examples/serving_quickstart.py [--res 96]
                                                         [--requests 48]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.executor import compile_network
from repro.core.graph import NETWORKS
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.serving import HeteroServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=96)
    ap.add_argument("--res2", type=int, default=64,
                    help="second resident resolution (its own lanes and "
                         "warmed traces; batches never mix shapes)")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--in-flight", type=int, default=2,
                    help="dispatch depth: batches in flight without a "
                         "host block (1 = fully serialized drain loop)")
    args = ap.parse_args()

    server = HeteroServer(buckets=(1, 4, 8, 32), max_wait_ms=2.0,
                          in_flight=args.in_flight)
    engines = {}
    resolutions = [(args.res, args.res), (args.res2, args.res2)]
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params = init_network(mods, jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        stats = server.register(net, mods, plans, params,
                                input_hw=resolutions)
        print(f"registered {net:13s} ({len(mods)} modules, "
              f"{stats['traces']} bucket x resolution traces, "
              f"{time.perf_counter() - t0:.1f}s compile+warm)")
        eng = compile_network(mods, plans)
        engines[net] = (eng, eng.prepare(params))

    names = list(NETWORKS)
    # mixed networks, mixed resolutions, every 4th request deadline-critical
    reqs = [(names[i % 3], i % 4 == 0,
             jax.random.normal(jax.random.PRNGKey(i),
                               (*resolutions[i % 2], 3)))
            for i in range(args.requests)]

    with server:
        t0 = time.perf_counter()
        futs = [(net, x, server.submit(net, x, priority=0 if hot else 1))
                for net, hot, x in reqs]
        outs = [(net, x, f.result()) for net, x, f in futs]
        wall = time.perf_counter() - t0

        # hot-swap mobilenetv2's weights mid-traffic: no drain, batches
        # already in flight finish on the old generation
        net = "mobilenetv2"
        mods = NETWORKS[net]()
        params2 = init_network(mods, jax.random.PRNGKey(1))
        more = [server.submit(net, x) for _n, _h, x in reqs[:6]]
        info = server.swap_params(net, params2)
        eng, prep_old = engines[net]
        engines[net] = (eng, eng.prepare(params2))
        after = [server.submit(net, x).result() for _n, _h, x in reqs[:6]]
        for f in more:
            f.result()

    # the serving contract: batching never changed anyone's logits — the
    # first wave (incl. pre-swap mobilenetv2 rows) checks against the
    # generation it was served with, the post-swap rows against the new one
    def first_wave_prep(net):
        return prep_old if net == "mobilenetv2" else engines[net][1]

    exact = all(bool(jnp.all(out == engines[net][0](first_wave_prep(net),
                                                    x[None])[0]))
                for net, x, out in outs)
    eng, prep2 = engines["mobilenetv2"]
    exact &= all(bool(jnp.all(out == eng(prep2, x[None])[0]))
                 for (_n, _h, x), out in zip(reqs[:6], after))
    snap = server.metrics.snapshot()
    print(f"\n{len(reqs)} mixed requests in {wall * 1e3:.0f} ms "
          f"({len(reqs) / wall:.0f} req/s) across {snap['batches']} batches "
          f"({snap['padded_slots']} padded slots, "
          f"{snap['swaps']} hot-swap -> generation "
          f"{info['generation']})")
    print(f"latency p50 {snap['p50_ms']:.1f} ms, p99 {snap['p99_ms']:.1f} ms")
    for lane, st in sorted(snap["lanes"].items()):
        print(f"  lane {lane:24s} completed={st['completed']:3d} "
              f"p50 {st['p50_ms']:6.1f} ms  p99 {st['p99_ms']:6.1f} ms")
    print(f"bit-identical to per-request engine calls "
          f"(post-swap rows vs the new generation): {exact}")
    print("\nper-engine exec stats:")
    for name, e in server.stats()["engines"].items():
        print(f"  {name:13s} calls={e['calls']:3d} traces={e['traces']} "
              f"prepares={e['prepares']} gen={e['param_generation']} "
              f"donated={e['donated_bytes'] // 1024}kB")


if __name__ == "__main__":
    main()
