"""Serving quickstart: all three paper networks resident behind one
``HeteroServer`` — dynamic batching into padded bucket shapes, async
submit/future dispatch, per-request results bit-identical to batch-1
engine calls.

    PYTHONPATH=src python examples/serving_quickstart.py [--res 96]
                                                         [--requests 48]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.executor import compile_network
from repro.core.graph import NETWORKS
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.serving import HeteroServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=96)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--in-flight", type=int, default=2,
                    help="dispatch depth: batches in flight without a "
                         "host block (1 = fully serialized drain loop)")
    args = ap.parse_args()

    server = HeteroServer(buckets=(1, 4, 8, 32), max_wait_ms=2.0,
                          in_flight=args.in_flight)
    engines = {}
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params = init_network(mods, jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        stats = server.register(net, mods, plans, params,
                                input_hw=(args.res, args.res))
        print(f"registered {net:13s} ({len(mods)} modules, "
              f"{stats['traces']} bucket traces, "
              f"{time.perf_counter() - t0:.1f}s compile+warm)")
        eng = compile_network(mods, plans)
        engines[net] = (eng, eng.prepare(params))

    names = list(NETWORKS)
    reqs = [(names[i % 3],
             jax.random.normal(jax.random.PRNGKey(i),
                               (args.res, args.res, 3)))
            for i in range(args.requests)]

    with server:
        t0 = time.perf_counter()
        futs = [(net, x, server.submit(net, x)) for net, x in reqs]
        outs = [(net, x, f.result()) for net, x, f in futs]
        wall = time.perf_counter() - t0

    # the serving contract: batching never changed anyone's logits
    exact = all(bool(jnp.all(out == eng(prep, x[None])[0]))
                for net, x, out in outs
                for eng, prep in [engines[net]])
    snap = server.metrics.snapshot()
    print(f"\n{len(reqs)} mixed requests in {wall * 1e3:.0f} ms "
          f"({len(reqs) / wall:.0f} req/s) across {snap['batches']} batches "
          f"({snap['padded_slots']} padded slots)")
    print(f"latency p50 {snap['p50_ms']:.1f} ms, p99 {snap['p99_ms']:.1f} ms")
    print(f"bit-identical to per-request engine calls: {exact}")
    print("\nper-engine exec stats:")
    for name, e in server.stats()["engines"].items():
        print(f"  {name:13s} calls={e['calls']:3d} traces={e['traces']} "
              f"buckets={e['buckets']} "
              f"donated={e['donated_bytes'] // 1024}kB")


if __name__ == "__main__":
    main()
