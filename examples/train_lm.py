"""Train an assigned-architecture LM end to end with the full stack:
sharding rules, microbatched train step, WSD schedule, fault-tolerant
checkpointing.

Default runs a ~10M-param xLSTM on CPU for 200 steps in a few minutes;
``--preset 125m --steps 300`` is the full xlstm-125m (use a real slice).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["tiny", "125m"], default="tiny")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", args.ckpt_dir]
    if args.preset == "125m":
        argv += ["--full"]
    loss = train_main(argv)
    print(f"final loss: {loss:.4f}")


if __name__ == "__main__":
    main()
