"""Reproduce the paper's quantitative artifacts in one go:
Fig. 1 sweep, Fig. 4 per-network comparison, Table I gains — plus the
beyond-paper budgeted partitioner.

    PYTHONPATH=src python examples/paper_tables.py
"""
from benchmarks.run import (beyond_paper, fig1_conv_sweep, fig4_models,
                            table1_gains)


def main():
    print("== Fig.1: conv sweep on 224x224x3 (us / mJ) ==")
    rows = fig1_conv_sweep()
    for (name, us, derived) in rows:
        if "n64" in name or "n8/" in name:
            print(f"  {name:28s} {us:8.1f}us  {derived}")
    print("\n== Fig.4: network-level hetero vs GPU-only ==")
    for (name, us, derived) in fig4_models():
        print(f"  {name:32s} {us/1e3:8.2f}ms  {derived}")
    print("\n== Table I: module-family gains vs paper ==")
    for (name, _us, derived) in table1_gains():
        print(f"  {name:24s} {derived}")
    print("\n== Beyond paper: budgeted all-scheme partitioner ==")
    for (name, us, derived) in beyond_paper():
        print(f"  {name:24s} {us/1e3:8.2f}ms  {derived}")


if __name__ == "__main__":
    main()
