"""Online re-partitioning quickstart: register MobileNetV2 under a plan
picked by a deliberately WRONG cost model (the FPGA/GPU coefficients
swapped, so the partitioner over-commits to the FPGA), drive live traffic
while a deterministic 4 ms delay is injected into every FPGA stage, and
watch the ``Replanner`` close the loop — timed batches re-fit the
coefficients online, the partitioner re-runs under the fitted model, and
the server hot-migrates mid-stream to the plan reality actually favors.
Every printed round reports the plan generation that served it, and the
script ends by printing the fitted coefficients and the migration event.

    PYTHONPATH=src python examples/replan_quickstart.py [--res 32]
                                                        [--rounds 12]

See docs/architecture.md for the loop and docs/cost-model.md for what the
fitted coefficients mean and how to tune the hysteresis knobs.
"""
import argparse
import time

import jax

from repro.core.costmodel import CostScales
from repro.core.graph import NETWORKS
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.core.replan import Replanner, boundary_distance
from repro.runtime.faults import FaultPlan, FaultRule, inject
from repro.serving import HeteroServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=12,
                    help="8-request rounds to serve (stops early once "
                         "the plan has converged and stayed put)")
    ap.add_argument("--delay-ms", type=float, default=4.0,
                    help="injected per-FPGA-stage delay: the model error "
                         "the fitter has to discover")
    args = ap.parse_args()
    net = "mobilenetv2"
    mods = NETWORKS[net]()

    # the wrong belief: GPU 8x more expensive than modelled, FPGA at par
    # -> the partitioner hands as much as it can to the FPGA
    misfit = CostScales(gpu=8.0, fpga=1.0)
    plans = partition_network(mods, objective="latency", scales=misfit)
    n_fpga = sum(1 for p in plans
                 for d in p.assign.values() if d == "fpga")
    print(f"misfit plan (gpu x8 belief): {n_fpga} FPGA-assigned nodes")

    params = init_network(mods, jax.random.PRNGKey(0))
    imgs = [0.5 * jax.random.normal(k, (args.res, args.res, 3))
            for k in jax.random.split(jax.random.PRNGKey(1), 8)]

    rep = Replanner(objective="latency", threshold=0.15, patience=2,
                    min_samples=2)
    server = HeteroServer(buckets=(8,), max_wait_ms=2.0, replanner=rep,
                          measure_every=1)
    t0 = time.perf_counter()
    server.register(net, mods, plans, params,
                    input_hw=(args.res, args.res), pipelined=True)
    print(f"registered {net} ({time.perf_counter() - t0:.1f}s "
          f"compile+warm), serving with online replanning\n")

    # reality: every FPGA stage is slower than the model says
    rule = FaultRule(op="stage", kind="delay", device="fpga",
                     delay_s=args.delay_ms * 1e-3, times=None)
    stable = 0
    with inject(FaultPlan([rule])):
        with server:
            for rnd in range(args.rounds):
                t0 = time.perf_counter()
                for f in [server.submit(net, x) for x in imgs]:
                    f.result()
                dt = time.perf_counter() - t0
                st = server.stats()
                eng = st["engines"][net]
                print(f"round {rnd:2d}: {dt / len(imgs) * 1e3:6.2f} "
                      f"ms/req  generation={eng['plan_generation']}  "
                      f"devices={'+'.join(eng['devices'])}")
                stable = stable + 1 if eng["devices"] == ("gpu",) else 0
                if stable >= 3:
                    break
            st = server.stats()

    fit = rep.fitted(net)
    print(f"\nfitted coefficients: gpu={fit.gpu:.2f} fpga={fit.fpga:.2f} "
          f"xfer={fit.xfer:.2f}  (identity = the paper model was right; "
          f"the injected delay shows up as fpga/xfer inflation)")
    for ev in st["replan"]["events"]:
        print(f"migration {ev['migration']}: modelled win {ev['win']:.1%} "
              f"(measured {ev['measured_s'] * 1e3:.2f} ms -> modelled "
              f"{ev['modelled_s'] * 1e3:.2f} ms serial)")
    oracle = partition_network(mods, objective="latency",
                               scales=rep.fitted(net))
    entry_plans = server._entries[net].plans
    print(f"boundary distance to the fitted-model oracle plan: "
          f"{boundary_distance(mods, entry_plans, oracle)}")
    assert st["server"]["replans"] >= 1, "no migration happened"
    assert st["engines"][net]["devices"] == ("gpu",), \
        "did not converge to the all-GPU plan"
    print("converged: live traffic migrated off the misfit plan")


if __name__ == "__main__":
    main()
