"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 4 x 50 GB/s links)
FLOPs/bytes/collective bytes come from the trip-count-aware HLO analyzer
(per-device numbers; see repro/launch/hlo_analysis.py).  MODEL_FLOPS is the
analytic 6*N_active*D (train) / 2*N_active*D (inference) budget.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LINKS = 4

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(results_dir=RESULTS, mesh="pod16x16", tag=""):
    cells = []
    for f in sorted(glob.glob(str(results_dir / "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        cells.append(r)
    return cells


def model_min_bytes(rec: dict) -> float:
    """Analytic minimum HBM traffic per device per step.

    train:   read params + write grads + opt update (r/w) + activation
             checkpoints written+read once       ≈ 6*P/n + 4*A/n
    prefill: read params once + write KV cache   ≈ 2*P/n + C/n
    decode:  read ALL resident params + the whole KV cache once
             (the defining decode bound)         ≈ (2*P + C)/n
    P = active params (weights bf16), A = per-layer residual checkpoints,
    C = cache bytes.  Sharding divides by n devices.
    """
    from repro.configs import SHAPES, get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n = rec["n_devices"]
    P = cfg.n_params() * 2                       # resident weight bytes
    P_active = cfg.n_active_params() * 2
    tokens = shape.global_batch * shape.seq_len
    A = tokens * cfg.d_model * 2 * cfg.n_layers  # residual checkpoints
    # cache bytes (decode): per assigned shape
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.resolved_head_dim
        if cfg.window:
            pass
    kv_len = min(shape.seq_len, cfg.window or shape.seq_len)
    C = shape.global_batch * kv_len * per_tok * 2 * cfg.n_layers
    if shape.kind == "train":
        return (6 * P + 4 * A) / n
    if shape.kind == "prefill":
        return (2 * P + C) / n
    return (P_active + P + C) / n


def roofline_terms(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    h = rec["hlo"]
    n = rec["n_devices"]
    t_comp = h["flops_per_device"] / PEAK_FLOPS
    t_mem = h["bytes_per_device"] / HBM_BW
    coll = sum(h["collective_bytes"].values())
    t_coll = coll / (ICI_BW * ICI_LINKS)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bound = max(terms, key=terms.get)
    model_flops = rec.get("model_flops", 0.0)
    hlo_global = h["flops_per_device"] * n
    # the ideal step: whichever of analytic-compute / analytic-memory binds
    ideal = max(model_flops / n / PEAK_FLOPS,
                model_min_bytes(rec) / HBM_BW)
    achieved = max(max(terms.values()), 1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **terms,
        "bound": bound,
        "step_s_lower_bound": achieved,
        "ideal_step_s": ideal,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_frac": (model_flops / hlo_global) if hlo_global else 0.0,
        "roofline_frac": ideal / achieved,
        "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2**30,
    }


def table(results_dir=RESULTS, mesh="pod16x16", tag="") -> list[dict]:
    out = []
    for rec in load_cells(results_dir, mesh, tag):
        if rec["status"] == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "bound": rec["reason"]})
            continue
        t = roofline_terms(rec)
        if t:
            out.append(t)
    return out


def fmt_row(t: dict) -> str:
    if "compute_s" not in t:
        return (f"{t['arch']:22s} {t['shape']:12s} {t['bound']}")
    return (f"{t['arch']:22s} {t['shape']:12s} "
            f"comp {t['compute_s']:9.3e}  mem {t['memory_s']:9.3e}  "
            f"coll {t['collective_s']:9.3e}  [{t['bound'][:-2]:10s}] "
            f"useful {100*t['useful_frac']:5.1f}%  "
            f"roofline {100*t['roofline_frac']:5.1f}%  "
            f"peak {t['peak_gib']:6.2f}GiB")


def main():
    print("name,us_per_call,derived")
    for t in table():
        if "compute_s" in t:
            print(f"roofline/{t['arch']}/{t['shape']},"
                  f"{t['step_s_lower_bound']*1e6:.1f},"
                  f"bound={t['bound']};roofline_frac={t['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
