"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1   conv-size sweep, FPGA-DHM vs TX2-GPU latency/energy   (paper Fig.1)
  fig4   per-network hetero vs GPU-only energy/latency         (paper Fig.4)
  table1 module-family gains vs the paper's reported numbers   (paper Tab.I)
  beyond beyond-paper budgeted partitioner (all schemes)       (§Perf)
  hetero_exec interpreted vs compiled plan execution, batch 1/8/32
  kernels wall-clock of the kernel reference paths on this host
  roofline per-cell dry-run roofline terms                     (§Roofline)

``python benchmarks/run.py [section ...]`` runs a subset (default: all).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp


def fig1_conv_sweep():
    from repro.core import costmodel as cm
    from repro.core.costmodel import ConvSpec
    rows = []
    for k in (1, 3, 5):
        for n in (2, 4, 8, 16, 32, 64):
            spec = ConvSpec("conv", 224, 224, 3, n, k=k)
            g = cm.GPU.op_cost(spec)
            f = cm.FPGA.full_unroll_cost(spec)
            feasible = cm.FPGA.fits_full_unroll(spec)
            rows.append((f"fig1/conv{k}x{k}_n{n}/gpu", g.latency * 1e6,
                         f"energy_mJ={g.energy*1e3:.3f}"))
            rows.append((f"fig1/conv{k}x{k}_n{n}/fpga", f.latency * 1e6,
                         f"energy_mJ={f.energy*1e3:.3f};fits={feasible}"))
    return rows


def fig4_models():
    from repro.core.graph import NETWORKS
    from repro.core.partitioner import partition_network, summarize
    rows = []
    for net, builder in NETWORKS.items():
        mods = builder()
        het = summarize(partition_network(mods, paper_faithful=True))
        rows.append((f"fig4/{net}/gpu_only", het["gpu_only_latency_ms"] * 1e3,
                     f"energy_mJ={het['gpu_only_energy_mJ']:.2f}"))
        rows.append((f"fig4/{net}/hetero", het["latency_ms"] * 1e3,
                     f"energy_mJ={het['energy_mJ']:.2f};"
                     f"gain={het['energy_gain']:.2f}x;"
                     f"speedup={het['speedup']:.2f}x"))
    return rows


PAPER_TABLE1 = {
    "squeezenet": (1.34, 1.01),
    "mobilenetv2": (1.55, 1.26),
    "shufflenetv2": (1.39, 1.35),
}


def table1_gains():
    from repro.core import costmodel as cm
    from repro.core.graph import NETWORKS
    from repro.core.partitioner import PAPER_SCHEMES, candidates
    rows = []
    for net, builder in NETWORKS.items():
        es, ls = [], []
        for m in builder():
            if m.kind in ("stem", "head"):
                continue
            cands = [p for p in candidates(m)
                     if p.scheme in PAPER_SCHEMES.get(m.kind, ())
                     and p.res.macs <= cm.FPGA.mac_budget]
            if not cands:
                continue
            best = min(cands, key=lambda p: p.cost.energy * p.cost.latency)
            es.append(best.energy_gain)
            ls.append(best.speedup)
        e, l = sum(es) / len(es), sum(ls) / len(ls)
        pe, pl = PAPER_TABLE1[net]
        rows.append((f"table1/{net}", 0.0,
                     f"energy_gain={e:.2f}x(paper={pe});"
                     f"speedup={l:.2f}x(paper={pl})"))
    return rows


def beyond_paper():
    from repro.core.graph import NETWORKS
    from repro.core.partitioner import partition_network, summarize
    rows = []
    for net, builder in NETWORKS.items():
        s = summarize(partition_network(builder(), objective="edp"))
        rows.append((f"beyond/{net}", s["latency_ms"] * 1e3,
                     f"energy_gain={s['energy_gain']:.2f}x;"
                     f"speedup={s['speedup']:.2f}x"))
    return rows


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def hetero_exec_rows(batches=(1, 8, 32), res=96):
    """The engine's reason to exist: the same (modules, plans) pair through
    the unjitted per-node interpreter vs the jit-once compiled executor
    (weights quantized at compile time, fused/int8 kernel routing)."""
    from repro.core.executor import compile_network
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network, run_network
    from repro.core.partitioner import partition_network
    rows = []
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params = init_network(mods, jax.random.PRNGKey(0))
        engine = compile_network(mods, plans)
        prepared = engine.prepare(params)
        for b in batches:
            x = jax.random.normal(jax.random.PRNGKey(1), (b, res, res, 3))
            t_i = _time(lambda: run_network(mods, params, x, plans), reps=2)
            t_c = _time(lambda: engine(prepared, x), reps=5)
            rows.append((f"hetero_exec/{net}/b{b}/interpreted", t_i,
                         f"res={res}"))
            rows.append((f"hetero_exec/{net}/b{b}/compiled", t_c,
                         f"res={res};speedup={t_i / t_c:.1f}x"))
    return rows


def kernel_bench():
    from repro.kernels.flash_attention.ref import attention
    from repro.kernels.fused_block.ref import fused_dw_pw
    from repro.quant import int8_matmul, quantize
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (4, 56, 56, 48))
    args = (x, 0.2 * jax.random.normal(ks[1], (3, 3, 48)),
            jnp.zeros((48,)), 0.2 * jax.random.normal(ks[2], (48, 96)),
            jnp.zeros((96,)))
    f = jax.jit(fused_dw_pw)
    rows.append(("kernels/fused_block_ref_56x56x48", _time(f, *args),
                 "xla_reference_path"))
    q = jax.random.normal(ks[3], (1, 8, 1024, 64))
    f = jax.jit(attention)
    rows.append(("kernels/attention_ref_1k", _time(f, q, q, q),
                 "xla_reference_path"))
    a = jax.random.normal(ks[4], (512, 512))
    w = jax.random.normal(ks[5], (512, 512))
    aq, s1 = quantize(a)
    wq, s2 = quantize(w, axis=-1)
    f = jax.jit(int8_matmul)
    rows.append(("kernels/int8_matmul_512", _time(f, aq, s1, wq, s2),
                 "int8_path"))
    return rows


def tpu_map_rows():
    """The paper's substrate decision on TPU v5e: fused-Pallas (VMEM
    resident, DHM analogue) vs generic XLA, per module."""
    from repro.core.graph import NETWORKS
    from repro.core.tpu_map import plan_network, summarize
    rows = []
    for net, builder in NETWORKS.items():
        s = summarize(plan_network(builder()))
        rows.append((f"tpu_map/{net}", s["planned_us"],
                     f"generic_us={s['generic_us']:.1f};"
                     f"speedup={s['speedup']:.2f}x;"
                     f"fused={s['fused_modules']}/{s['n_modules']}"))
    return rows


def roofline_rows():
    try:
        from benchmarks.roofline import table
        rows = []
        for t in table():
            if "compute_s" in t:
                rows.append((f"roofline/{t['arch']}/{t['shape']}",
                             t["step_s_lower_bound"] * 1e6,
                             f"bound={t['bound']};"
                             f"roofline_frac={t['roofline_frac']:.3f};"
                             f"useful_frac={t['useful_frac']:.3f}"))
        return rows
    except Exception as e:  # dry-run results absent
        return [("roofline/unavailable", 0.0, f"run dryrun first ({e})")]


SECTIONS = {
    "fig1": fig1_conv_sweep,
    "fig4": fig4_models,
    "table1": table1_gains,
    "beyond": beyond_paper,
    "tpu_map": tpu_map_rows,
    "hetero_exec": hetero_exec_rows,
    "kernels": kernel_bench,
    "roofline": roofline_rows,
}


def main(argv: list[str] | None = None) -> None:
    names = (argv if argv else sys.argv[1:]) or list(SECTIONS)
    unknown = [n for n in names if n not in SECTIONS]
    if unknown:
        raise SystemExit(f"unknown section(s) {unknown}; "
                         f"choose from {list(SECTIONS)}")
    print("name,us_per_call,derived")
    for n in names:
        for name, us, derived in SECTIONS[n]():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
