"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1   conv-size sweep, FPGA-DHM vs TX2-GPU latency/energy   (paper Fig.1)
  fig4   per-network hetero vs GPU-only energy/latency         (paper Fig.4)
  table1 module-family gains vs the paper's reported numbers   (paper Tab.I)
  beyond beyond-paper budgeted partitioner (all schemes)       (§Perf)
  hetero_exec interpreted vs compiled plan execution, batch 1/8/32, plus
         per-network fused-chain coverage (fraction of FPGA conv nodes
         lowered inside a fused group) as hetero_exec/<net>/fused_coverage
  pipeline monolithic vs stage-pipelined execution and the serving
         in-flight depth sweep (§Pipelining): cost-model overlap bound,
         run_many micro-batch throughput, burst rps at in_flight 1/2/4,
         and the served-rows-bit-match check — the guarded rows assert
         multi-in-flight >= single-in-flight at batch >= 8
  qos    multi-resolution QoS serving (§Serving QoS): per-(network,
         resolution, priority) lane scheduling vs sequential
         per-resolution batch-1 serving, per-priority lane percentiles,
         and the prepared-parameter hot-swap bit-match check — the
         guarded rows assert mixed-resolution batched throughput >=
         the sequential loop on all three networks and that every
         served row matches exactly one parameter generation
  serve  batched multi-plan serving vs sequential baselines    (§Serving):
         serve/<net>/seq_interpreted   per-request us through the oracle
         serve/<net>/seq_compiled      per-request us, engine batch-1 loop
         serve/<net>/batched_burst     us/req + rps;p50_ms;p99_ms;vs_seq;
                                       vs_interp (closed-loop burst)
         serve/<net>/load<m>x          offered-load point at m x batched
                                       capacity: offered_rps;rps;p50;p99
         serve/mixed/batched_burst     all plans resident, interleaved
  faults fault-tolerant serving (§Robustness): deterministic FPGA-fault
         injection against a live mobilenetv2 server — circuit-breaker
         failover to the GPU-only plan and half-open probe recovery
         (faults/<net>/failover: bitmatch/recovered/served_frac floors,
         failover-pause p99 from inter-completion gaps) and queue-bound
         load shedding under injected dispatch latency
         (faults/shed: shed_rate + within_deadline floor — rejects are
         synchronous, admitted rows all resolve)
  replan online re-partitioning (§Replanning): injected FPGA stage
         delays make the live hybrid plan measurably slow; the replanner
         fits the delay from timed batches, re-partitions, and
         hot-migrates mid-stream to the all-GPU plan
         (replan/<net>/migrate: converged/bitmatch/post_speedup floors —
         migration must happen, every row must bit-match its own plan
         generation's oracle, and post-migration latency must not exceed
         pre-migration)
  replicas replica-striped data-parallel serving (§Replica striping):
         the same burst striped over 1/2/4 data-axis replicas of a
         forced multi-device host (replicas/<net>/r<k>: vs_1replica and
         bitmatch floors on r4 — striping must never cost throughput and
         every served row must equal its batch-1 oracle) plus the
         cross-replica straggler backup check (replicas/backup:
         other_replica floor — a stuck dispatch re-runs on a DIFFERENT
         replica, bit-matched); needs XLA_FLAGS to force >= 4 devices
  frontend HTTP front-door serving (§Front door): an open-loop offered-
         load sweep through the asyncio door over a live server — real
         sockets, admission control, typed wire errors — then a drain
         under load (frontend/door/load<m>x: offered_rps/rps/p50/p99/
         shed_frac; floors: every 200 row bit-matches the batch-1
         oracle, every non-200 is a typed wire error with a stable
         code, and a drain under load answers every in-flight request)
  kernels wall-clock of the kernel reference paths on this host
  roofline per-cell dry-run roofline terms                     (§Roofline)

``python benchmarks/run.py [section ...]`` runs a subset (default: all).
``--json PATH`` additionally dumps rows plus a flat ``metrics`` dict
(every ``key=value`` float in ``derived``) — CI stores this as the
``BENCH_ci.json`` artifact and guards it against ``baseline.json`` with
``check_regression.py``.
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def fig1_conv_sweep():
    from repro.core import costmodel as cm
    from repro.core.costmodel import ConvSpec
    rows = []
    for k in (1, 3, 5):
        for n in (2, 4, 8, 16, 32, 64):
            spec = ConvSpec("conv", 224, 224, 3, n, k=k)
            g = cm.GPU.op_cost(spec)
            f = cm.FPGA.full_unroll_cost(spec)
            feasible = cm.FPGA.fits_full_unroll(spec)
            rows.append((f"fig1/conv{k}x{k}_n{n}/gpu", g.latency * 1e6,
                         f"energy_mJ={g.energy*1e3:.3f}"))
            rows.append((f"fig1/conv{k}x{k}_n{n}/fpga", f.latency * 1e6,
                         f"energy_mJ={f.energy*1e3:.3f};fits={feasible}"))
    return rows


def fig4_models():
    from repro.core.graph import NETWORKS
    from repro.core.partitioner import partition_network, summarize
    rows = []
    for net, builder in NETWORKS.items():
        mods = builder()
        het = summarize(partition_network(mods, paper_faithful=True))
        rows.append((f"fig4/{net}/gpu_only", het["gpu_only_latency_ms"] * 1e3,
                     f"energy_mJ={het['gpu_only_energy_mJ']:.2f}"))
        rows.append((f"fig4/{net}/hetero", het["latency_ms"] * 1e3,
                     f"energy_mJ={het['energy_mJ']:.2f};"
                     f"gain={het['energy_gain']:.2f}x;"
                     f"speedup={het['speedup']:.2f}x"))
    return rows


PAPER_TABLE1 = {
    "squeezenet": (1.34, 1.01),
    "mobilenetv2": (1.55, 1.26),
    "shufflenetv2": (1.39, 1.35),
}


def table1_gains():
    from repro.core import costmodel as cm
    from repro.core.graph import NETWORKS
    from repro.core.partitioner import PAPER_SCHEMES, candidates
    rows = []
    for net, builder in NETWORKS.items():
        es, ls = [], []
        for m in builder():
            if m.kind in ("stem", "head"):
                continue
            cands = [p for p in candidates(m)
                     if p.scheme in PAPER_SCHEMES.get(m.kind, ())
                     and p.res.macs <= cm.FPGA.mac_budget]
            if not cands:
                continue
            best = min(cands, key=lambda p: p.cost.energy * p.cost.latency)
            es.append(best.energy_gain)
            ls.append(best.speedup)
        e, l = sum(es) / len(es), sum(ls) / len(ls)
        pe, pl = PAPER_TABLE1[net]
        rows.append((f"table1/{net}", 0.0,
                     f"energy_gain={e:.2f}x(paper={pe});"
                     f"speedup={l:.2f}x(paper={pl})"))
    return rows


def beyond_paper():
    from repro.core.graph import NETWORKS
    from repro.core.partitioner import partition_network, summarize
    rows = []
    for net, builder in NETWORKS.items():
        s = summarize(partition_network(builder(), objective="edp"))
        rows.append((f"beyond/{net}", s["latency_ms"] * 1e3,
                     f"energy_gain={s['energy_gain']:.2f}x;"
                     f"speedup={s['speedup']:.2f}x"))
    return rows


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def hetero_exec_rows(batches=(1, 8, 32), res=96):
    """The engine's reason to exist: the same (modules, plans) pair through
    the unjitted per-node interpreter vs the jit-once compiled executor
    (weights quantized at compile time, fused/int8 kernel routing)."""
    from repro.core.executor import compile_network
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network, run_network
    from repro.core.partitioner import fused_chain_coverage, partition_network
    rows = []
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params = init_network(mods, jax.random.PRNGKey(0))
        engine = compile_network(mods, plans)
        prepared = engine.prepare(params)
        cov = fused_chain_coverage(mods, plans)
        rows.append((f"hetero_exec/{net}/fused_coverage", 0.0,
                     f"coverage={cov['coverage']:.3f};"
                     f"fpga_nodes={cov['fpga_nodes']};"
                     f"fused_nodes={cov['fused_nodes']}"))
        for b in batches:
            x = jax.random.normal(jax.random.PRNGKey(1), (b, res, res, 3))
            t_i = _time(lambda: run_network(mods, params, x, plans), reps=2)
            t_c = _time(lambda: engine(prepared, x), reps=5)
            rows.append((f"hetero_exec/{net}/b{b}/interpreted", t_i,
                         f"res={res}"))
            rows.append((f"hetero_exec/{net}/b{b}/compiled", t_c,
                         f"res={res};speedup={t_i / t_c:.1f}x"))
    return rows


def _serve_setup(res):
    from repro.core.executor import compile_network
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network
    nets = {}
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params = init_network(mods, jax.random.PRNGKey(0))
        eng = compile_network(mods, plans)
        prep = eng.prepare(params)
        jax.block_until_ready(eng(prep, jnp.zeros((1, res, res, 3))))
        # per-network bucket policy: SqueezeNet is all fp32-GEMM compute and
        # goes cache-bound past batch 8 on small hosts; the depthwise nets
        # keep batching gains through 32
        buckets = (1, 4, 8) if net == "squeezenet" else (1, 4, 8, 32)
        nets[net] = dict(mods=mods, plans=plans, params=params, eng=eng,
                         prep=prep, buckets=buckets)
    return nets


def _burst(server, reqs, timeout=300):
    """Submit (net, img) pairs as fast as possible; returns (wall_s,
    per-request latencies).  Latency is stamped by a done-callback (fires
    in the drain thread at result time) — polling result() in submit order
    would bill early finishers for the poll loop's position."""
    t0 = time.perf_counter()
    lats = []
    subs = []
    for net, x in reqs:
        t_sub = time.perf_counter()
        f = server.submit(net, x)
        f.add_done_callback(
            lambda _f, t=t_sub: lats.append(time.perf_counter() - t))
        subs.append(f)
    for f in subs:
        f.result(timeout=timeout)
    return time.perf_counter() - t0, lats


def serve_rows(n_req=32, res=96):
    """Batched async serving vs the sequential interpreted / compiled
    baselines, plus an offered-load sweep (open loop, paced arrivals)."""
    from repro.core.hetero import run_network
    from repro.serving import HeteroServer, percentile
    nets = _serve_setup(res)
    rows = []
    seq_total = 0.0
    for net, d in nets.items():
        imgs = [jax.random.normal(jax.random.PRNGKey(i), (res, res, 3))
                for i in range(n_req)]
        # sequential interpreted oracle (1 warm + 2 timed calls: it's slow)
        t_i = _time(lambda: run_network(d["mods"], d["params"],
                                        imgs[0][None], d["plans"]), reps=2)
        # sequential compiled: engine batch-1 loop, one dispatch per request
        t0 = time.perf_counter()
        for x in imgs:
            jax.block_until_ready(d["eng"](d["prep"], x[None]))
        t_c = (time.perf_counter() - t0) / n_req * 1e6
        seq_total += t_c
        # batched burst through a server with this net's bucket policy
        server = HeteroServer(buckets=d["buckets"], max_wait_ms=2.0)
        server.register(net, d["mods"], d["plans"], d["params"],
                        input_hw=(res, res), buckets=d["buckets"])
        with server:
            _burst(server, [(net, x) for x in imgs[:d["buckets"][-1]]])
            wall, lats = _burst(server, [(net, x) for x in imgs])
            wall2, lats2 = _burst(server, [(net, x) for x in imgs])
            if wall2 < wall:
                wall, lats = wall2, lats2
        t_b = wall / n_req * 1e6
        snap = server.metrics.snapshot()
        rows.append((f"serve/{net}/seq_interpreted", t_i,
                     f"rps={1e6 / t_i:.1f}"))
        rows.append((f"serve/{net}/seq_compiled", t_c,
                     f"rps={1e6 / t_c:.1f}"))
        rows.append((f"serve/{net}/batched_burst", t_b,
                     f"rps={1e6 / t_b:.1f};"
                     f"p50_ms={percentile(lats, 50) * 1e3:.2f};"
                     f"p99_ms={percentile(lats, 99) * 1e3:.2f};"
                     f"batches={snap['batches']};"
                     f"vs_seq={t_c / t_b:.2f}x;vs_interp={t_i / t_b:.2f}x"))
        # offered-load sweep: open loop at 0.5x / 0.9x of burst capacity
        cap_rps = 1e6 / t_b
        for mult in (0.5, 0.9):
            interval = 1.0 / (cap_rps * mult)
            server = HeteroServer(buckets=d["buckets"], max_wait_ms=2.0)
            server.register(net, d["mods"], d["plans"], d["params"],
                            input_hw=(res, res), buckets=d["buckets"])
            with server:
                t0 = time.perf_counter()
                subs = []
                lats = []
                for i, x in enumerate(imgs):
                    target = t0 + i * interval
                    while time.perf_counter() < target:
                        time.sleep(0)
                    t_sub = time.perf_counter()
                    f = server.submit(net, x)
                    f.add_done_callback(
                        lambda _f, t=t_sub:
                        lats.append(time.perf_counter() - t))
                    subs.append(f)
                for f in subs:
                    f.result(timeout=300)
                wall = time.perf_counter() - t0
            rows.append((f"serve/{net}/load{mult}x", wall / n_req * 1e6,
                         f"offered_rps={cap_rps * mult:.1f};"
                         f"rps={n_req / wall:.1f};"
                         f"p50_ms={percentile(lats, 50) * 1e3:.2f};"
                         f"p99_ms={percentile(lats, 99) * 1e3:.2f}"))
    # mixed multi-plan: every network resident, interleaved burst
    server = HeteroServer(buckets=(1, 4, 8, 32), max_wait_ms=2.0)
    for net, d in nets.items():
        server.register(net, d["mods"], d["plans"], d["params"],
                        input_hw=(res, res), buckets=d["buckets"])
    per_net = max(1, n_req // len(nets))
    reqs = [(net, jax.random.normal(jax.random.PRNGKey(100 + i),
                                    (res, res, 3)))
            for i in range(per_net) for net in nets]
    with server:
        _burst(server, reqs[:8])
        wall, lats = _burst(server, reqs)
        wall2, lats2 = _burst(server, reqs)
        if wall2 < wall:
            wall, lats = wall2, lats2
    t_mix = wall / len(reqs) * 1e6
    t_seq_mix = seq_total / len(nets)     # mean sequential-compiled us/req
    rows.append(("serve/mixed/batched_burst", t_mix,
                 f"rps={1e6 / t_mix:.1f};"
                 f"p50_ms={percentile(lats, 50) * 1e3:.2f};"
                 f"p99_ms={percentile(lats, 99) * 1e3:.2f};"
                 f"vs_seq={t_seq_mix / t_mix:.2f}x"))
    return rows


def qos_rows(n_req=48, res_list=(32, 48)):
    """Multi-resolution QoS serving: every (network, resolution, priority)
    triple is its own batching lane, so one server multiplexes input
    shapes the way real deployments do (fixed accelerator config, varying
    request shapes).  Rows per network:

      qos/<net>/seq_perres       sequential batch-1 engine loop over the
                                 same mixed-resolution stream (us/req)
      qos/<net>/mixed_res_burst  batched mixed-resolution + mixed-priority
                                 burst (best of 3): us/req + rps, overall
                                 and per-priority-lane p50/p99, and
                                 vs_seq — guarded at an absolute floor of
                                 1.0 on ALL three networks (batching must
                                 never lose to the sequential loop)
      qos/<net>/hotswap          swap_params mid-stream: shadow-prepare
                                 wall time + the bit-match invariant
                                 (every served row — across resolutions,
                                 priorities, and the swap — equals a
                                 batch-1 engine call under exactly one
                                 parameter generation; rows submitted
                                 after the swap returned match the new
                                 generation; floor bitmatch = 1)
    """
    from repro.core.executor import compile_network
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network
    from repro.serving import HeteroServer, percentile
    rows = []
    buckets = (1, 4, 8)

    def qos_burst(server, net, reqs):
        """Closed-loop burst keeping (x, priority, future) for bit-checks."""
        t0 = time.perf_counter()
        lats, futs = [], []
        for x, prio in reqs:
            t_sub = time.perf_counter()
            f = server.submit(net, x, priority=prio)
            f.add_done_callback(
                lambda _f, t=t_sub: lats.append(time.perf_counter() - t))
            futs.append(f)
        outs = [f.result(timeout=300) for f in futs]
        return time.perf_counter() - t0, lats, outs

    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params_a = init_network(mods, jax.random.PRNGKey(0))
        params_b = init_network(mods, jax.random.PRNGKey(7))
        eng = compile_network(mods, plans)
        prep_a, prep_b = eng.prepare(params_a), eng.prepare(params_b)
        # interleaved mixed-resolution stream, every 4th request urgent
        reqs = [(jax.random.normal(jax.random.PRNGKey(i),
                                   (res_list[i % len(res_list)],
                                    res_list[i % len(res_list)], 3)),
                 0 if i % 4 == 0 else 1)
                for i in range(n_req)]
        for r in res_list:                 # warm the batch-1 shapes
            jax.block_until_ready(eng(prep_a, jnp.zeros((1, r, r, 3))))
        t0 = time.perf_counter()
        for x, _prio in reqs:              # sequential per-resolution loop
            jax.block_until_ready(eng(prep_a, x[None]))
        t_seq = (time.perf_counter() - t0) / n_req * 1e6
        server = HeteroServer(buckets=buckets, max_wait_ms=2.0, in_flight=2)
        server.register(net, mods, plans, params_a,
                        input_hw=[(r, r) for r in res_list], buckets=buckets)
        with server:
            qos_burst(server, net, reqs[:8])          # warm the live path
            wall, lats, outs = qos_burst(server, net, reqs)
            for _ in range(2):                        # best of 3 bursts
                w2, l2, o2 = qos_burst(server, net, reqs)
                if w2 < wall:
                    wall, lats, outs = w2, l2, o2
            # per-lane percentiles snapshotted HERE so they describe the
            # burst phase, not the hot-swap traffic below
            burst_lanes = server.metrics.snapshot()["lanes"]
            match = all(
                bool((out == eng(prep_a, x[None])[0]).all())
                for (x, _p), out in zip(reqs, outs))
            # hot-swap mid-stream: first half rides the old generation,
            # the swap lands without draining, second half must serve
            # the new one
            pre = [server.submit(net, x, priority=p)
                   for x, p in reqs[:n_req // 2]]
            t_swap = time.perf_counter()
            server.swap_params(net, params_b)
            swap_ms = (time.perf_counter() - t_swap) * 1e3
            post = [server.submit(net, x, priority=p)
                    for x, p in reqs[n_req // 2:]]
            pre_outs = [f.result(timeout=300) for f in pre]
            post_outs = [f.result(timeout=300) for f in post]
            for (x, _p), out in zip(reqs, pre_outs):  # old OR new, never mixed
                match &= (bool((out == eng(prep_a, x[None])[0]).all())
                          or bool((out == eng(prep_b, x[None])[0]).all()))
            for (x, _p), out in zip(reqs[n_req // 2:], post_outs):
                match &= bool((out == eng(prep_b, x[None])[0]).all())
        t_b = wall / n_req * 1e6
        snap = server.metrics.snapshot()
        lane_p99 = {0: [], 1: []}
        for label, st in burst_lanes.items():
            lane_p99[int(label.rsplit("/p", 1)[1])].append(st["p99_ms"])
        res_tag = "x".join(str(r) for r in res_list)   # comma-free CSV
        rows.append((f"qos/{net}/seq_perres", t_seq,
                     f"rps={1e6 / t_seq:.1f};res={res_tag}"))
        rows.append((f"qos/{net}/mixed_res_burst", t_b,
                     f"rps={1e6 / t_b:.1f};"
                     f"p50_ms={percentile(lats, 50) * 1e3:.2f};"
                     f"p99_ms={percentile(lats, 99) * 1e3:.2f};"
                     f"hi_p99_ms={max(lane_p99[0] or [0.0]):.2f};"
                     f"bulk_p99_ms={max(lane_p99[1] or [0.0]):.2f};"
                     f"vs_seq={t_seq / t_b:.2f}x"))
        rows.append((f"qos/{net}/hotswap", swap_ms * 1e3,
                     f"swap_ms={swap_ms:.1f};swaps={snap['swaps']};"
                     f"bitmatch={1.0 if match else 0.0}"))
    return rows


def pipeline_rows(n_req=96, res=32, batch=8):
    """The paper's overlap argument, made measurable: monolithic vs
    stage-pipelined engine execution, and single- vs multi-in-flight
    serving.  The sweep runs at res 32 / batch 8 deliberately — the
    small-feature-map regime where per-op parallelism cannot hide dispatch
    gaps, so keeping k batches in flight is what fills the hardware (at
    large maps XLA already saturates the host and every depth measures the
    same compute).  Each depth is scored by its BEST of 5 alternating
    bursts: host noise only ever slows a burst down, so best-of-n
    estimates capability and the structural gap shows through jitter that
    would whipsaw a median.  Rows:

      pipeline/<net>/model           cost-model stage count + overlap bound
      pipeline/<net>/stage_engine_b8 run_many depth-4 vs serialized
                                     monolithic micro-batches (us/batch)
      pipeline/<net>/serve_if<k>     best-burst rps at in-flight depth k
      pipeline/<net>/inflight        best multi-in-flight vs depth-1
                                     (speedup>=1 guarded in baseline.json
                                     for the depthwise nets; SqueezeNet is
                                     fp32-GEMM cache-bound and stays
                                     informational, like its bucket cap)
                                     + served-row bit-match vs batch-1
                                     monolithic calls (bitmatch=1.0)
    """
    from repro.core.executor import compile_network, compile_pipelined
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network, pipelined_summary
    from repro.serving import HeteroServer, percentile
    rows = []
    depths = (1, 2, 4)
    buckets = (1, 4, batch)       # cap at `batch`: the sweep's batch size
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params = init_network(mods, jax.random.PRNGKey(0))
        mono = compile_network(mods, plans)
        prep = mono.prepare(params)
        pipe = compile_pipelined(mods, plans)
        est = pipelined_summary(mods, plans)
        rows.append((f"pipeline/{net}/model", 0.0,
                     f"stages={est['n_stages']};"
                     f"overlap_speedup={est['overlap_speedup']:.2f};"
                     f"steady_ms={est['steady_ms_per_input']:.2f}"))
        # stage engine: 8 micro-batches, serialized monolithic (block per
        # batch) vs depth-4 pipelined dispatch
        xs = [jax.random.normal(jax.random.PRNGKey(i), (batch, res, res, 3))
              for i in range(8)]
        jax.block_until_ready(mono(prep, xs[0]))
        jax.block_until_ready(pipe(prep, xs[0]))

        def mono_sweep():
            for x in xs:
                jax.block_until_ready(mono(prep, x))

        def pipe_sweep():
            for o in pipe.run_many(prep, xs, depth=4):
                jax.block_until_ready(o)

        t_mono = min(_time(mono_sweep, reps=2) for _ in range(2)) / len(xs)
        t_pipe = min(_time(pipe_sweep, reps=2) for _ in range(2)) / len(xs)
        rows.append((f"pipeline/{net}/stage_engine_b{batch}", t_pipe,
                     f"mono_us={t_mono:.1f};vs_mono={t_mono / t_pipe:.2f}x;"
                     f"stages={len(pipe.stages)}"))
        # serving: in-flight depth sweep.  One live server per depth; the
        # five timed bursts ALTERNATE across depths so host-load drift
        # hits every depth equally, and each depth's best burst is scored.
        imgs = [jax.random.normal(jax.random.PRNGKey(100 + i),
                                  (res, res, 3)) for i in range(n_req)]
        reqs = [(net, x) for x in imgs]
        servers, walls, lat_best = {}, {}, {}
        for infl in depths:
            s = HeteroServer(buckets=buckets, max_wait_ms=2.0,
                             in_flight=infl)
            s.register(net, mods, plans, params, input_hw=(res, res),
                       buckets=buckets)
            s.start()
            _burst(s, reqs[:batch])              # warm the live path
            servers[infl], walls[infl] = s, []
        for _round in range(5):
            for infl in depths:
                wall, lats = _burst(servers[infl], reqs)
                walls[infl].append(wall)
                if wall <= min(walls[infl]):
                    lat_best[infl] = lats
        rps = {}
        for infl in depths:
            wall = min(walls[infl])              # best burst (capability)
            rps[infl] = n_req / wall
            lats = lat_best[infl]
            rows.append((f"pipeline/{net}/serve_if{infl}",
                         wall / n_req * 1e6,
                         f"rps={rps[infl]:.1f};"
                         f"p50_ms={percentile(lats, 50) * 1e3:.2f};"
                         f"p99_ms={percentile(lats, 99) * 1e3:.2f}"))
        # served rows must still bit-match batch-1 monolithic calls
        deep = servers[depths[-1]]
        futs = [deep.submit(net, x) for x in imgs[:8]]
        outs = [f.result(timeout=300) for f in futs]
        match = all(bool((out == mono(prep, x[None])[0]).all())
                    for x, out in zip(imgs, outs))
        for s in servers.values():
            s.shutdown()
        best = max(rps[k] for k in depths if k > 1)
        rows.append((f"pipeline/{net}/inflight", 0.0,
                     f"speedup={best / rps[1]:.3f};"
                     f"bitmatch={1.0 if match else 0.0}"))
    return rows


def faults_rows(res=32, n_req=48):
    """Fault-tolerant serving under deterministic injection (§Robustness).

      faults/<net>/failover   a paced request stream rides through injected
                              FPGA dispatch failures: the breaker trips,
                              traffic fails over to the shadow-prepared
                              GPU-only plan, half-open probes recover the
                              hybrid plan.  Floors: bitmatch (every served
                              row equals its batch-1 oracle on the plan
                              that served it), recovered (breaker closed
                              by stream end), served_frac (zero lost
                              futures).  pause_p99_ms is the p99 of
                              inter-completion gaps — the failover pause a
                              client would see.
      faults/shed             queue-bound load shedding under injected
                              dispatch latency: rejects raise synchronous
                              ``Overloaded``.  Floor: within_deadline
                              (every shed raised in < 50 ms AND every
                              admitted request resolved).
    """
    from repro.core.executor import compile_network
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network
    from repro.runtime.faults import FaultPlan, FaultRule, inject
    from repro.serving import HeteroServer, Overloaded, percentile
    rows = []
    net = "mobilenetv2"
    mods = NETWORKS[net]()
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    imgs = [jax.random.normal(jax.random.PRNGKey(i), (res, res, 3))
            for i in range(n_req)]
    # oracles computed OUTSIDE the inject scope (the injection point is
    # process-global, like the engine cache)
    hybrid = compile_network(mods, plans)
    h_prep = hybrid.prepare(params)
    gpu = compile_network(mods, None)
    g_prep = gpu.prepare(params)
    refs_h = [hybrid(h_prep, x[None])[0] for x in imgs]
    refs_g = [gpu(g_prep, x[None])[0] for x in imgs]

    server = HeteroServer(buckets=(1, 4, 8), max_wait_ms=2.0,
                          breaker_threshold=2, probe_interval_s=0.03,
                          recover_after=1)
    # prewarm: the pause metric should measure the redirect + retry, not
    # a first-failure fallback compile
    server.register(net, mods, plans, params, input_hw=(res, res),
                    prewarm_fallback=True)
    done_t = []
    # 8 clean dispatches, then 3 FPGA faults: two trip the breaker
    # (threshold=2, the first burns the rows' retry budget-free failover),
    # the third fails the first half-open probe; the next probe heals
    plan = FaultPlan([FaultRule(op="dispatch", device="fpga",
                                after=8, times=3)])
    with server:
        with inject(plan):
            futs = []
            for x in imgs:
                f = server.submit(net, x)
                f.add_done_callback(
                    lambda _f: done_t.append(time.perf_counter()))
                futs.append(f)
                time.sleep(0.005)       # paced: probes need wall-clock room
            outs = [f.result(timeout=300) for f in futs]
        recovered = (1.0 if server.stats()["engines"][net]["mode"]
                     == "primary" else 0.0)
    match = all(bool((out == h).all()) or bool((out == g).all())
                for out, h, g in zip(outs, refs_h, refs_g))
    snap = server.metrics.snapshot()
    gaps = [b - a for a, b in zip(sorted(done_t), sorted(done_t)[1:])]
    pause_p99 = percentile(gaps, 99) * 1e3 if gaps else 0.0
    served_frac = snap["completed"] / max(1, snap["submitted"])
    rows.append((f"faults/{net}/failover", pause_p99 * 1e3,
                 f"bitmatch={1.0 if match else 0.0};"
                 f"recovered={recovered};"
                 f"served_frac={served_frac:.3f};"
                 f"pause_p99_ms={pause_p99:.2f};"
                 f"failovers={snap['failovers']};"
                 f"recoveries={snap['recoveries']};"
                 f"retries={snap['retries']};"
                 f"injected={len(plan.fired)}"))

    # queue-bound shedding: bucket-1 lane, depth bound 4, +20 ms injected
    # dispatch latency — an unpaced burst must shed, and shed fast
    server = HeteroServer(buckets=(1,), max_wait_ms=1.0, max_queue=4)
    server.register(net, mods, None, input_hw=(res, res))
    shed, shed_lat, admitted = 0, [], []
    with server:
        with inject(FaultPlan([FaultRule(op="dispatch", kind="delay",
                                         delay_s=0.02, times=None)])):
            for x in imgs:
                t_s = time.perf_counter()
                try:
                    admitted.append(server.submit(net, x))
                except Overloaded:
                    shed += 1
                    shed_lat.append(time.perf_counter() - t_s)
            resolved = sum(1 for f in admitted
                           if f.result(timeout=300) is not None)
    within = (1.0 if resolved == len(admitted)
              and all(dt < 0.050 for dt in shed_lat) else 0.0)
    rows.append(("faults/shed", max(shed_lat, default=0.0) * 1e6,
                 f"within_deadline={within};"
                 f"shed_rate={shed / n_req:.3f};"
                 f"shed={shed};admitted={len(admitted)};"
                 f"shed_p99_us={percentile(shed_lat, 99) * 1e6:.0f}"
                 if shed_lat else
                 f"within_deadline={within};shed_rate=0.000;"
                 f"shed=0;admitted={len(admitted)};shed_p99_us=0"))
    return rows


def replan_rows(res=32, rounds_cap=15):
    """Online re-partitioning under live traffic (§Replanning).

      replan/<net>/migrate   the paper-faithful hybrid plan serves a
                             request stream while every FPGA stage pays a
                             deterministic injected 4 ms delay; the
                             replanner fits the inflated coefficients from
                             timed batches, re-partitions, and
                             hot-migrates to the all-GPU plan mid-stream.
                             Floors: converged (the migration happened and
                             landed on the all-GPU plan), bitmatch (every
                             row from a generation-stable round equals the
                             batch-1 oracle of the plan generation that
                             served it), post_speedup (best post-migration
                             round >= best pre-migration round — shedding
                             the injected delay must show up in latency).
    """
    from repro.core.executor import compile_network
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network
    from repro.core.replan import Replanner
    from repro.runtime.faults import FaultPlan, FaultRule, inject
    from repro.serving import HeteroServer
    net = "mobilenetv2"
    mods = NETWORKS[net]()
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    imgs = [0.5 * jax.random.normal(k, (res, res, 3))
            for k in jax.random.split(jax.random.PRNGKey(1), 8)]
    rep = Replanner(objective="latency", threshold=0.15, patience=2,
                    min_samples=2)
    # buckets=(8,) so each 8-request round is exactly one batch: a round
    # is either fully inside one plan generation or the migration round
    server = HeteroServer(buckets=(8,), max_wait_ms=2.0, replanner=rep,
                          measure_every=1)
    server.register(net, mods, plans, params, input_hw=(res, res),
                    pipelined=True)
    rule = FaultRule(op="stage", kind="delay", device="fpga",
                     delay_s=0.004, times=None)
    trace = []          # (gen_before, gen_after, plans_after, dt, outs)
    with inject(FaultPlan([rule])):
        with server:
            entry = server._entries[net]
            for rnd in range(rounds_cap):
                g0 = entry.plan_generation
                t0 = time.perf_counter()
                outs = [f.result(timeout=300)
                        for f in [server.submit(net, x) for x in imgs]]
                dt = time.perf_counter() - t0
                trace.append((g0, entry.plan_generation,
                              list(entry.plans), dt, outs))
                devs = server.stats()["engines"][net]["devices"]
                if devs == ("gpu",) and rnd >= 3:
                    break
            st = server.stats()
    converged = (1.0 if st["engines"][net]["devices"] == ("gpu",)
                 and st["server"]["replans"] >= 1 else 0.0)
    # per-generation bit-match: oracle engines built and called OUTSIDE
    # the inject scope.  Rounds that migrated mid-flight have no single
    # generation and are excluded (their rows were served, just not
    # attributable to one oracle).
    checked, match = 0, True
    for g0, g1, plans_after, _dt, outs in trace:
        if g0 != g1:
            continue
        oracle = compile_network(mods, plans_after)
        oprep = oracle.prepare(params)
        for x, out in zip(imgs, outs):
            ref = oracle(oprep, jnp.asarray(x)[None])[0]
            match = match and bool((out == ref).all())
            checked += 1
    bitmatch = 1.0 if match and checked else 0.0
    pre = [dt for g0, g1, _p, dt, _o in trace if g0 == g1 == 0]
    post = [dt for g0, g1, _p, dt, _o in trace if g0 == g1 >= 1]
    pre_req = min(pre) / len(imgs) if pre else float("nan")
    post_req = min(post) / len(imgs) if post else float("nan")
    mig_round = next((i for i, (g0, g1, *_r) in enumerate(trace)
                      if g1 > g0), -1)
    fit = st["server"]["fitted"].get(net, {})
    return [(f"replan/{net}/migrate", post_req * 1e6,
             f"converged={converged};bitmatch={bitmatch};"
             f"post_speedup={pre_req / post_req:.2f};"
             f"pre_req_us={pre_req * 1e6:.0f};"
             f"post_req_us={post_req * 1e6:.0f};"
             f"replans={st['server']['replans']};"
             f"measured={st['server']['measured_batches']};"
             f"migration_round={mig_round};rounds={len(trace)};"
             f"checked={checked};"
             f"fit_gpu={fit.get('gpu', 0.0):.2f};"
             f"fit_fpga={fit.get('fpga', 0.0):.2f};"
             f"fit_xfer={fit.get('xfer', 0.0):.2f}")]


def replicas_rows(res=48, n_req=64, counts=(1, 2, 4), rounds=5):
    """Replica-striped data-parallel serving (§Replica striping).

    The striped points need a multi-device host — under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
    multi-device job) every forced CpuDevice carries one replica.  Rows:

      replicas/mobilenetv2/r<k>  best-of-n burst rps serving the SAME
                                 request stream striped over k replicas;
                                 the r4 row carries vs_1replica (guarded
                                 >= 1: striping must never cost
                                 throughput) and bitmatch (guarded == 1:
                                 every served row equals its batch-1
                                 oracle no matter which replica ran it)
      replicas/backup            cross-replica straggler backup: a stuck
                                 primary dispatch re-runs on the
                                 least-outstanding OTHER replica —
                                 other_replica (guarded == 1) asserts it
                                 fired on a different replica AND its
                                 rows bit-match; pause_ms is the watch ->
                                 backup-result wall time
      replicas/unavailable       informational — too few devices to
                                 stripe (single-device local runs)
    """
    from repro.core.executor import ReplicaSet, compile_network
    from repro.core.graph import NETWORKS
    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network
    from repro.serving import HeteroServer
    rows = []
    net = "mobilenetv2"
    ndev = len(jax.devices())
    usable = [k for k in counts if k <= ndev]
    if usable != list(counts):
        rows.append(("replicas/unavailable", 0.0,
                     f"devices={ndev};needed={max(counts)};"
                     f"hint=XLA_FLAGS=--xla_force_host_platform_"
                     f"device_count=8"))
    mods = NETWORKS[net]()
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    imgs = [np.asarray(jax.random.normal(jax.random.PRNGKey(i),
                                         (res, res, 3)))
            for i in range(n_req)]
    eng = compile_network(mods, plans)
    prep = eng.prepare(params)
    refs = [np.asarray(eng(prep, x[None]))[0] for x in imgs]
    thr = {}
    for k in usable:
        server = HeteroServer(buckets=(1, 4, 8), in_flight=2,
                              max_wait_ms=1.0)
        server.register(net, mods, plans, params, input_hw=(res, res),
                        replicas=k)
        with server:
            # untimed warm burst: python/thread/trace warmup must not be
            # billed to the FIRST measured round (best-of-n below scores
            # capability, like the pipeline in-flight sweep)
            for f in [server.submit(net, x) for x in imgs[:16]]:
                f.result(timeout=300)
            outs, best = [], float("inf")
            for r in range(rounds):
                futs = [server.submit(net, x) for x in imgs]
                t0 = time.perf_counter()
                got = [f.result(timeout=300) for f in futs]
                best = min(best, time.perf_counter() - t0)
                outs = outs or got
            snap = server.metrics.snapshot()
        match = all(bool((o == ref).all())
                    for o, ref in zip(outs, refs))
        thr[k] = n_req / best
        derived = (f"rps={thr[k]:.1f};bitmatch={1.0 if match else 0.0};"
                   f"replica_lanes={max(1, len(snap['replicas']))};"
                   f"batches={snap['batches']}")
        if k > 1:
            derived += f";vs_1replica={thr[k] / thr[1]:.3f}"
        rows.append((f"replicas/{net}/r{k}", best / n_req * 1e6, derived))

    # cross-replica straggler backup: drive the watchdog directly (the
    # deterministic idiom from the fault suite) with a never-ready
    # primary — the backup must land on the OTHER replica, bit-matched
    if ndev >= 2:
        class _NeverReady:
            def is_ready(self):
                return False

        server = HeteroServer(buckets=(1, 4), straggler_min_ms=1.0)
        server.register(net, mods, plans, params, input_hw=(res, res),
                        replicas=2)
        entry = server._entries[net]
        for s in range(10):
            entry.monitor.record(s, 0.001)
        xb = imgs[0][None]
        straggler = entry.engine.pick()
        t0 = time.perf_counter()
        out = server._watch(entry, xb, _NeverReady(), entry.engine,
                            entry.prepared, straggler)
        jax.block_until_ready(out)
        pause = time.perf_counter() - t0
        snap = server.metrics.snapshot()
        calls = entry.engine.exec_stats()["replica_calls"]
        ok = (not isinstance(out, _NeverReady)
              and isinstance(entry.engine, ReplicaSet)
              and snap["cross_replica_backups"] == 1
              and calls[1 - straggler] >= 1
              and bool((np.asarray(out)[0] == refs[0]).all()))
        server.shutdown()
        rows.append(("replicas/backup", pause * 1e6,
                     f"other_replica={1.0 if ok else 0.0};"
                     f"pause_ms={pause * 1e3:.2f};"
                     f"straggler_events={snap['straggler_events']}"))
    return rows


def kernel_bench():
    from repro.kernels.flash_attention.ref import attention
    from repro.kernels.fused_block.ref import fused_dw_pw
    from repro.quant import int8_matmul, quantize
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(ks[0], (4, 56, 56, 48))
    args = (x, 0.2 * jax.random.normal(ks[1], (3, 3, 48)),
            jnp.zeros((48,)), 0.2 * jax.random.normal(ks[2], (48, 96)),
            jnp.zeros((96,)))
    f = jax.jit(fused_dw_pw)
    rows.append(("kernels/fused_block_ref_56x56x48", _time(f, *args),
                 "xla_reference_path"))
    q = jax.random.normal(ks[3], (1, 8, 1024, 64))
    f = jax.jit(attention)
    rows.append(("kernels/attention_ref_1k", _time(f, q, q, q),
                 "xla_reference_path"))
    a = jax.random.normal(ks[4], (512, 512))
    w = jax.random.normal(ks[5], (512, 512))
    aq, s1 = quantize(a)
    wq, s2 = quantize(w, axis=-1)
    f = jax.jit(int8_matmul)
    rows.append(("kernels/int8_matmul_512", _time(f, aq, s1, wq, s2),
                 "int8_path"))
    return rows


def tpu_map_rows():
    """The paper's substrate decision on TPU v5e: fused-Pallas (VMEM
    resident, DHM analogue) vs generic XLA, per module."""
    from repro.core.graph import NETWORKS
    from repro.core.tpu_map import plan_network, summarize
    rows = []
    for net, builder in NETWORKS.items():
        s = summarize(plan_network(builder()))
        rows.append((f"tpu_map/{net}", s["planned_us"],
                     f"generic_us={s['generic_us']:.1f};"
                     f"speedup={s['speedup']:.2f}x;"
                     f"fused={s['fused_modules']}/{s['n_modules']}"))
    return rows


def roofline_rows():
    try:
        from benchmarks.roofline import table
        rows = []
        for t in table():
            if "compute_s" in t:
                rows.append((f"roofline/{t['arch']}/{t['shape']}",
                             t["step_s_lower_bound"] * 1e6,
                             f"bound={t['bound']};"
                             f"roofline_frac={t['roofline_frac']:.3f};"
                             f"useful_frac={t['useful_frac']:.3f}"))
        return rows
    except Exception as e:  # dry-run results absent
        return [("roofline/unavailable", 0.0, f"run dryrun first ({e})")]


def frontend_rows(n_req=48):
    """HTTP front-door serving (§Front door): open-loop offered load
    through real sockets against a live in-process server.

      frontend/door/load<m>x  requests fired at m x the door's measured
                              closed-loop capacity, each on its own
                              client thread (open loop: arrivals don't
                              wait for completions).  Derived: offered
                              vs achieved rps, p50/p99 ms, shed_frac.
                              Floors: bitmatch (every 200 row equals the
                              batch-1 oracle THROUGH the wire) and typed
                              (every non-200 carries a stable wire code
                              with a retryable bit — never a traceback).
      frontend/keepalive      the SAME 2x-offered-load schedule served
                              by a fixed worker pool twice: fresh
                              connection per request vs persistent
                              keep-alive connections.  Floor:
                              vs_reconnect >= 1.0 (best of 3 rounds) —
                              pooling sockets never costs throughput,
                              and on a dial-taxed path it buys some.
      frontend/binary/<net>   one image through BOTH wire framings
                              (JSON-base64 and application/x-tensor)
                              for each zoo network.  Floor: bitmatch
                              (the encodings are interchangeable
                              codecs).  Derived: wire_ratio (binary
                              frame bytes / JSON body bytes).
      frontend/fuzz           a malformed-body volley (bad dtype,
                              truncated base64, shape overflow, negative
                              dims, bad tensor frames, garbage JSON) on
                              one keep-alive socket.  Floor: typed_4xx
                              == 1.0 — zero 500s, and the socket
                              still serves afterwards.
      frontend/drain          POST /drain while a burst is in flight:
                              the fence is immediate, yet every already-
                              admitted request still gets an answer.
                              Floor: resolved (no request lost to the
                              drain) — plus the drain's wall-clock.
    """
    import http.client
    import json as _json
    import queue as _queue
    import threading
    import urllib.error
    import urllib.request

    from repro.core.executor import compile_network
    from repro.core.graph import fire
    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network
    from repro.frontend import FrontDoor, LocalBackend, ServerThread, wire
    from repro.frontend.worker import build_server
    from repro.serving import percentile

    hw, c = (8, 8), 16
    spec = {"networks": [{"kind": "fire", "name": "tiny", "hw": list(hw),
                          "c_in": c, "squeeze": 4, "expand": 8, "seed": 0}],
            "server": {"max_wait_ms": 1.0}}
    mods = [fire("tiny", hw[0], c, 4, 8)]
    eng = compile_network(mods, partition_network(mods, paper_faithful=True))
    prep = eng.prepare(init_network(mods, jax.random.PRNGKey(0)))
    imgs = [np.asarray(0.5 * jax.random.normal(jax.random.PRNGKey(i),
                                               (*hw, c)), dtype=np.float32)
            for i in range(n_req)]
    refs = [np.asarray(eng(prep, x[None])[0]) for x in imgs]
    bodies = [_json.dumps(wire.infer_payload("tiny", x)).encode()
              for x in imgs]

    def post(port, path, data=b"", timeout=60):
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}{path}", data=data, method="POST",
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=timeout) as r:
                return r.status, _json.load(r)
        except urllib.error.HTTPError as e:
            return e.code, _json.load(e)

    def open_loop(port, interval_s):
        """Fire every request on schedule on its own thread (open loop),
        then collect (status, body, latency_s)."""
        out = [None] * len(bodies)
        threads = []

        def one(i):
            t0 = time.perf_counter()
            status, body = post(port, "/v1/infer", bodies[i])
            out[i] = (status, body, time.perf_counter() - t0)

        t_start = time.perf_counter()
        for i in range(len(bodies)):
            while time.perf_counter() - t_start < i * interval_s:
                time.sleep(interval_s / 20)
            th = threading.Thread(target=one, args=(i,))
            th.start()
            threads.append(th)
        for th in threads:
            th.join(120)
        elapsed = time.perf_counter() - t_start
        return out, elapsed

    def judge(results):
        """(bitmatch, typed, ok_lats, n_ok, n_shed) over one sweep."""
        bitmatch, typed, lats, n_ok, n_shed = 1.0, 1.0, [], 0, 0
        for i, r in enumerate(results):
            if r is None:
                typed = 0.0            # a lost request is worse than shed
                continue
            status, body, lat = r
            if status == 200:
                n_ok += 1
                lats.append(lat)
                if not np.array_equal(wire.decode_array(body["result"]),
                                      refs[i]):
                    bitmatch = 0.0
            else:
                n_shed += 1
                if not (isinstance(body, dict) and body.get("error")
                        and "retryable" in body):
                    typed = 0.0
        return bitmatch, typed, lats, n_ok, n_shed

    rows = []
    server = build_server(spec)
    with ServerThread(FrontDoor(LocalBackend(server))) as h:
        # closed-loop capacity probe: one client, back to back
        t0 = time.perf_counter()
        for b in bodies[:12]:
            post(h.port, "/v1/infer", b)
        cap_rps = 12 / (time.perf_counter() - t0)
        for mult in (0.5, 2.0):
            interval = 1.0 / max(1e-6, cap_rps * mult)
            results, elapsed = open_loop(h.port, interval)
            bitmatch, typed, lats, n_ok, n_shed = judge(results)
            us = (np.mean(lats) * 1e6) if lats else 0.0
            rows.append((
                f"frontend/door/load{mult:g}x", us,
                f"bitmatch={bitmatch};typed={typed};"
                f"offered_rps={1.0 / interval:.1f};"
                f"rps={n_ok / elapsed:.1f};"
                f"shed_frac={n_shed / len(results):.3f};"
                f"p50_ms={percentile(lats, 50) * 1e3 if lats else 0:.2f};"
                f"p99_ms={percentile(lats, 99) * 1e3 if lats else 0:.2f}"))

        # keep-alive vs reconnect: the same 2x-offered-load schedule,
        # consumed by a fixed pool of client workers — once dialing a
        # fresh connection per request, once on persistent sockets
        interval = 1.0 / max(1e-6, cap_rps * 2.0)
        n_workers = 8

        def run_mode(keepalive: bool) -> float:
            done = [0] * len(bodies)
            q = _queue.Queue()
            t_start = time.perf_counter()
            for i in range(len(bodies)):
                q.put((i, t_start + i * interval))
            for _ in range(n_workers):
                q.put(None)

            def client():
                conn = (http.client.HTTPConnection(
                    "127.0.0.1", h.port, timeout=60) if keepalive
                    else None)
                while True:
                    item = q.get()
                    if item is None:
                        break
                    i, due = item
                    wait = due - time.perf_counter()
                    if wait > 0:
                        time.sleep(wait)
                    c = conn if keepalive else http.client.HTTPConnection(
                        "127.0.0.1", h.port, timeout=60)
                    try:
                        c.request("POST", "/v1/infer", body=bodies[i],
                                  headers={"Content-Type":
                                           "application/json"})
                        r = c.getresponse()
                        r.read()
                        done[i] = 1 if r.status == 200 else 0
                    except Exception:
                        done[i] = 0
                        if keepalive:       # a dead pooled socket: redial
                            conn = http.client.HTTPConnection(
                                "127.0.0.1", h.port, timeout=60)
                            c = conn
                    finally:
                        if not keepalive:
                            c.close()
                if conn is not None:
                    conn.close()

            threads = [threading.Thread(target=client)
                       for _ in range(n_workers)]
            for th in threads:
                th.start()
            for th in threads:
                th.join(180)
            elapsed = time.perf_counter() - t_start
            return sum(done) / elapsed

        ratios, ka_best, rc_best = [], 0.0, 0.0
        for _round in range(3):             # best of 3: floor-grade signal
            rc = run_mode(keepalive=False)
            ka = run_mode(keepalive=True)
            ka_best, rc_best = max(ka_best, ka), max(rc_best, rc)
            ratios.append(ka / max(1e-9, rc))
        rows.append((
            "frontend/keepalive", 1e6 / max(1e-9, ka_best),
            f"vs_reconnect={max(ratios):.3f};"
            f"keepalive_rps={ka_best:.1f};reconnect_rps={rc_best:.1f}"))

        # malformed-body volley on ONE keep-alive socket: the acceptance
        # bar is zero 500s — every reply a typed 4xx, socket survives
        mal = []
        good = wire.infer_payload("tiny", imgs[0])
        for patch in ({"dtype": "float99"}, {"dtype": "object"},
                      {"shape": "nope"}, {"shape": [-1, 4]},
                      {"shape": [2 ** 31, 2 ** 31]}, {"shape": [1] * 17},
                      {"data": "!!not-base64!!"},
                      {"data": good["data"][:len(good["data"]) // 2]}):
            mal.append((_json.dumps({**good, **patch}).encode(),
                        {"Content-Type": "application/json"}))
        mal.append((b"{garbage", {"Content-Type": "application/json"}))
        mal.append((b"[1,2]", {"Content-Type": "application/json"}))
        mal.append((b"NOPE" + b"\x00" * 12,
                    {"Content-Type": wire.TENSOR_CONTENT_TYPE,
                     "X-Network": "tiny"}))
        mal.append((wire.encode_tensor(imgs[0])[:-3],
                    {"Content-Type": wire.TENSOR_CONTENT_TYPE,
                     "X-Network": "tiny"}))
        n_4xx = n_other = 0
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=30)
        t0 = time.perf_counter()
        for body, headers in mal:
            try:
                conn.request("POST", "/v1/infer", body=body,
                             headers=headers)
                r = conn.getresponse()
                r.read()
                if 400 <= r.status < 500:
                    n_4xx += 1
                else:
                    n_other += 1
            except Exception:
                n_other += 1
                conn = http.client.HTTPConnection("127.0.0.1", h.port,
                                                  timeout=30)
        fuzz_us = (time.perf_counter() - t0) / len(mal) * 1e6
        try:        # the volley must not have burned the socket
            conn.request("POST", "/v1/infer", body=bodies[0],
                         headers={"Content-Type": "application/json"})
            survived = conn.getresponse().status == 200
        except Exception:
            survived = False
        conn.close()
        typed_4xx = 1.0 if (n_other == 0 and n_4xx == len(mal)
                            and survived) else 0.0
        rows.append((
            "frontend/fuzz", fuzz_us,
            f"typed_4xx={typed_4xx};volley={len(mal)};"
            f"n_500={n_other};socket_survived={1.0 if survived else 0.0}"))

        # drain under load: a burst is mid-flight when the fence drops
        results = [None] * 16
        threads = []

        def fire_one(i):
            t0 = time.perf_counter()
            status, body = post(h.port, "/v1/infer", bodies[i])
            results[i] = (status, body, time.perf_counter() - t0)

        for i in range(16):
            th = threading.Thread(target=fire_one, args=(i,))
            th.start()
            threads.append(th)
        time.sleep(0.002)
        t0 = time.perf_counter()
        _status, drain_body = post(h.port, "/drain", b"")
        drain_s = time.perf_counter() - t0
        for th in threads:
            th.join(60)
        bitmatch, typed, _lats, n_ok, n_shed = judge(results)
        resolved = (1.0 if all(r is not None for r in results)
                    and bitmatch and typed else 0.0)
        rows.append((
            "frontend/drain", drain_s * 1e6,
            f"resolved={resolved};drained={1.0 if drain_body.get('drained') else 0.0};"
            f"served={n_ok};typed_rejects={n_shed};"
            f"drain_ms={drain_s * 1e3:.1f}"))

    # binary-framing parity across the whole zoo: the same image through
    # both wire encodings must serve a bit-identical row per network
    zoo = ("mobilenetv2", "squeezenet", "shufflenetv2")
    zoo_spec = {"networks": [{"kind": "zoo", "name": n, "res": [32, 32],
                              "buckets": [1]} for n in zoo],
                "server": {"max_wait_ms": 1.0}}
    zserver = build_server(zoo_spec)
    with ServerThread(FrontDoor(LocalBackend(zserver))) as h:
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=120)

        def ask(net, x, binary):
            body, headers = wire.infer_request(
                net, x, binary=binary,
                accept=wire.TENSOR_CONTENT_TYPE if binary else None)
            t0 = time.perf_counter()
            conn.request("POST", "/v1/infer", body=body, headers=headers)
            r = conn.getresponse()
            raw = r.read()
            dt = time.perf_counter() - t0
            assert r.status == 200, raw[:200]
            row = (wire.decode_tensor(raw) if binary
                   else wire.decode_array(_json.loads(raw)["result"]))
            return row, len(body), dt

        for net in zoo:
            x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                             (32, 32, 3)),
                           dtype=np.float32)
            ask(net, x, binary=False)           # warm the bucket
            row_j, size_j, _ = ask(net, x, binary=False)
            row_b, size_b, t_b = ask(net, x, binary=True)
            bitmatch = 1.0 if (row_j.dtype == row_b.dtype
                               and np.array_equal(row_j, row_b)) else 0.0
            rows.append((
                f"frontend/binary/{net}", t_b * 1e6,
                f"bitmatch={bitmatch};"
                f"wire_ratio={size_b / max(1, size_j):.3f};"
                f"body_bytes={size_b}"))
        conn.close()
    return rows


SECTIONS = {
    "fig1": fig1_conv_sweep,
    "fig4": fig4_models,
    "table1": table1_gains,
    "beyond": beyond_paper,
    "tpu_map": tpu_map_rows,
    "hetero_exec": hetero_exec_rows,
    "serve": serve_rows,
    "qos": qos_rows,
    "pipeline": pipeline_rows,
    "faults": faults_rows,
    "replan": replan_rows,
    "replicas": replicas_rows,
    "frontend": frontend_rows,
    "kernels": kernel_bench,
    "roofline": roofline_rows,
}


def metrics_from_rows(rows) -> dict:
    """Flatten every ``key=value`` float in ``derived`` (trailing 'x'
    stripped) into {"<row>/<key>": value} — the regression-guard input."""
    out = {}
    for name, _us, derived in rows:
        for part in str(derived).split(";"):
            if "=" not in part:
                continue
            k, v = part.split("=", 1)
            try:
                out[f"{name}/{k}"] = float(v.rstrip("x"))
            except ValueError:
                continue
    return out


def main(argv: list[str] | None = None) -> None:
    args = list(argv if argv is not None else sys.argv[1:])
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        json_path = args[i + 1]
        del args[i:i + 2]
    names = args or list(SECTIONS)
    unknown = [n for n in names if n not in SECTIONS]
    if unknown:
        raise SystemExit(f"unknown section(s) {unknown}; "
                         f"choose from {list(SECTIONS)}")
    print("name,us_per_call,derived")
    all_rows = []
    for n in names:
        for name, us, derived in SECTIONS[n]():
            print(f"{name},{us:.1f},{derived}")
            all_rows.append((name, us, derived))
    if json_path:
        payload = {
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in all_rows],
            "metrics": metrics_from_rows(all_rows),
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {json_path} ({len(payload['metrics'])} metrics)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
