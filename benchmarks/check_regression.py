"""CI benchmark regression guard.

Compares a fresh ``--json`` dump from ``benchmarks/run.py`` against the
committed ``benchmarks/baseline.json`` and FAILS (exit 1) when any pinned
metric regressed more than the threshold (default 30%).

    python benchmarks/check_regression.py BENCH_ci.json \
        benchmarks/baseline.json [--threshold 0.30]

All pinned metrics are higher-is-better (throughput in rps, or unit-free
speedup ratios).  The baseline deliberately pins mostly RATIOS
(batched-vs-sequential, compiled-vs-interpreted): absolute wall-clock on
shared CI runners swings far more than 30%, while the ratios cancel the
host speed and catch real scheduling/lowering regressions.  Baseline
values are themselves conservative floors below locally measured numbers
(see ``note`` in the file), so the guard trips on structural regressions,
not host jitter.  A metric missing from the fresh run also fails —
silently dropping a benchmark must not pass the guard.

Besides the threshold-derated ``metrics``, the baseline may pin absolute
``floors`` — invariants checked without derating: multi-in-flight serving
must not fall below the single-in-flight loop (speedup >= 1), batched
mixed-resolution QoS serving must not fall below the sequential
per-resolution loop (qos vs_seq >= 1), and served rows must bit-match
batch-1 monolithic calls (bitmatch == 1 — across resolutions, priority
lanes, and a mid-stream ``swap_params`` for the qos hotswap row).
"""
from __future__ import annotations

import argparse
import json
import sys


def check(current: dict, baseline: dict, threshold: float) -> list[str]:
    failures = []
    cur = current.get("metrics", {})
    print(f"{'metric':56s} {'base':>10s} {'now':>10s} {'floor':>10s}  ok")
    # "metrics": threshold-derated throughput guards (host jitter allowed);
    # "floors": absolute invariants — e.g. pipelined serving >= the
    # single-in-flight loop, served rows bit-matching — no derating.
    pinned = [(name, base, base * (1.0 - threshold))
              for name, base in baseline.get("metrics", {}).items()]
    pinned += [(name, floor, floor)
               for name, floor in baseline.get("floors", {}).items()]
    for name, base, floor in sorted(pinned):
        have = cur.get(name)
        if have is None:
            print(f"{name:56s} {base:10.3f} {'MISSING':>10s} {floor:10.3f}  "
                  f"FAIL")
            failures.append(f"{name}: missing from current run")
            continue
        ok = have >= floor
        print(f"{name:56s} {base:10.3f} {have:10.3f} {floor:10.3f}  "
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(f"{name}: {have:.3f} < floor {floor:.3f} "
                            f"(baseline {base:.3f})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh run.py --json output")
    ap.add_argument("baseline", help="committed baseline.json")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max tolerated relative regression (default 0.30)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(current, baseline, args.threshold)
    if failures:
        print(f"\nREGRESSION GUARD FAILED ({len(failures)}):",
              file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    n = (len(baseline.get("metrics", {}))
         + len(baseline.get("floors", {})))
    print(f"\nregression guard passed: {n} metrics within "
          f"{args.threshold:.0%} of baseline (absolute floors exact)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
