"""The roofline's HLO analyzer must multiply scan bodies by trip count."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, shape_bytes


def test_shape_bytes():
    assert shape_bytes("bf16[64,64]{1,0}") == 64 * 64 * 2
    assert shape_bytes("f32[10,256,64]") == 10 * 256 * 64 * 4
    assert shape_bytes("(s32[], bf16[8,8])") == 4 + 128
    assert shape_bytes("pred[]") == 1


def test_scan_flops_trip_multiplied():
    n_layers, m, k = 10, 64, 128

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((n_layers, k, k), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    res = analyze(compiled.as_text())
    expect = n_layers * 2 * m * k * k
    assert 0.9 * expect <= res["flops_per_device"] <= 1.5 * expect, res


def test_matmul_flops_counted_once_outside_scan():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    res = analyze(compiled.as_text())
    expect = 2 * 128 * 256 * 64
    assert 0.9 * expect <= res["flops_per_device"] <= 1.2 * expect
