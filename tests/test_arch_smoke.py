"""Per-architecture smoke tests: reduced same-family config, one forward and
one real train step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models.lm import model as lm
from repro.optim import make_optimizer
from repro.train.steps import TrainState, make_train_step


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)}
    if cfg.vlm_patches:
        batch["image_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.vlm_patches, cfg.d_model))
    if cfg.enc_dec:
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (B, max(S // cfg.enc_ratio, 8),
                                    cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch), dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, _, aux = lm.forward(cfg, params, batch)
    S_total = S + cfg.vlm_patches
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = reduced(get_config(arch), dtype="float32")
    opt = make_optimizer(cfg.optimizer)
    step = make_train_step(cfg, opt)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    state2, metrics = jax.jit(step)(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     state.params, state2.params)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(arch):
    cfg = reduced(get_config(arch), dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, smax = 2, 16
    enc_len = 8 if cfg.enc_dec else 0
    cache = lm.init_cache(cfg, B, smax, enc_len)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = lm.decode_step(cfg, params, cache, tok,
                                    jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
