"""Property-based replica-striping tests (PR 8): random interleavings of
submits and mid-stream ``swap_params`` through a REAL ``HeteroServer``
striped over R replicas lose, duplicate and reorder nothing within the
lane; every served row bit-matches the batch-1 oracle of exactly one
parameter generation, regardless of which replica served it; and no
dispatched batch ever mixes generations (each batch's rows all match the
ONE generation its prepared handle carried).

R = min(2, device count), so on a single-device tier-1 host this runs the
R=1 degenerate striping path and the CI multi-device job runs real
striping.  Optional suite: skips cleanly when hypothesis is absent.
"""
import functools

import pytest

pytest.importorskip("hypothesis")

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.executor import ReplicaSet, compile_network
from repro.core.graph import fire
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.launch.mesh import make_production_mesh
from repro.serving import HeteroServer

HW, C = (8, 8), 16
POOL = 24                                 # distinct images per example
R = min(2, len(jax.devices()))

_ops = st.lists(st.sampled_from(["submit", "submit", "submit", "swap"]),
                min_size=1, max_size=POOL)


@functools.lru_cache(maxsize=1)
def _fixture():
    """One network, two parameter generations, and both batch-1 oracles —
    built once; the executor cache keeps every example after the first
    cheap."""
    mods = [fire("f", 8, 16, 4, 8)]
    plans = partition_network(mods, paper_faithful=True)
    params = {"A": init_network(mods, jax.random.PRNGKey(0)),
              "B": init_network(mods, jax.random.PRNGKey(1))}
    rng = np.random.RandomState(42)
    imgs = [0.5 * rng.randn(*HW, C).astype(np.float32) for _ in range(POOL)]
    eng = compile_network(mods, plans, use_pallas=False)
    oracle = {k: [np.asarray(eng(eng.prepare(p), x[None]))[0] for x in imgs]
              for k, p in params.items()}
    lookup = {x.tobytes(): i for i, x in enumerate(imgs)}
    return mods, plans, params, imgs, oracle, lookup


class _RecordingSet(ReplicaSet):
    """A real ReplicaSet (``isinstance`` checks in ``_flush`` stay true)
    that records (generation, batch rows) per dispatch, in dispatch
    order — the ground truth for the no-mixed-generation and in-lane
    order properties."""

    def __init__(self, engine, mesh):
        super().__init__(engine, mesh)
        self.dispatched = []

    def __call__(self, prepared, x, *, donate=False, replica=None):
        self.dispatched.append((prepared.generation, np.asarray(x).copy()))
        return super().__call__(prepared, x, donate=donate, replica=replica)


@pytest.mark.serving
@settings(max_examples=15, deadline=None)
@given(ops=_ops)
def test_random_submit_swap_interleavings_exactly_once_one_generation(ops):
    mods, plans, params, imgs, oracle, lookup = _fixture()
    server = HeteroServer(buckets=(1, 4), in_flight=2, max_wait_ms=1.0,
                          straggler_min_ms=10_000.0)
    server.register("f", mods, plans, params["A"], input_hw=HW,
                    mesh=make_production_mesh(shape=(R,)))
    entry = server._entries["f"]
    rec = _RecordingSet(entry.engine.engine, entry.engine.mesh)
    entry.engine = rec
    gen_key = {entry.prepared.generation: "A"}
    key, futures = "A", []
    with server:
        for op in ops:
            if op == "swap":
                key = "B" if key == "A" else "A"
                info = server.swap_params("f", params[key])
                gen_key[info["generation"]] = key
            elif len(futures) < POOL:
                futures.append(server.submit("f", imgs[len(futures)]))
        rows = [f.result(timeout=60) for f in futures]

    # nothing lost: every submit resolved with a full-shape row
    assert len(rows) == len(futures)
    # reconstruct which image each dispatched batch row was (padded rows
    # are zero and never collide with the randn pool)
    served = []                           # (submit index, generation)
    for gen, xb in rec.dispatched:
        for row in xb:
            i = lookup.get(row.tobytes())
            if i is not None:
                served.append((i, gen))
    # exactly once: no request lost or duplicated across replicas
    assert sorted(i for i, _gen in served) == list(range(len(futures)))
    # in-lane order: one lane here, and dispatch order preserves it
    assert [i for i, _gen in served] == sorted(i for i, _gen in served)
    for i, gen in served:
        k = gen_key[gen]                  # unknown gen would KeyError: a
        # batch can only carry a generation some swap (or register) made
        # ... and the served bits match THAT generation's batch-1 oracle,
        # whichever replica ran the batch — so no batch mixes generations
        assert (rows[i] == oracle[k][i]).all(), \
            f"row {i} does not match its batch's generation {k!r}"


@pytest.mark.serving
@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=1, max_value=POOL))
def test_striped_rows_bitmatch_batch1_oracle_without_swaps(n):
    mods, plans, params, imgs, oracle, _lookup = _fixture()
    server = HeteroServer(buckets=(1, 4), in_flight=2, max_wait_ms=1.0,
                          straggler_min_ms=10_000.0)
    server.register("f", mods, plans, params["A"], input_hw=HW,
                    mesh=make_production_mesh(shape=(R,)))
    with server:
        rows = [f.result(timeout=60)
                for f in [server.submit("f", x) for x in imgs[:n]]]
    for i, row in enumerate(rows):
        assert (row == oracle["A"][i]).all()
