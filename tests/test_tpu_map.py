"""TPU substrate selection — the paper's decision structure on v5e."""
import pytest

from repro.core.graph import NETWORKS
from repro.core.tpu_map import plan_network, summarize


@pytest.mark.parametrize("net", list(NETWORKS))
def test_tpu_plans_are_sound(net):
    mods = NETWORKS[net]()
    plans = plan_network(mods)
    for p in plans:
        if p.substrate == "fused":
            # a fused choice must actually be a predicted win and fit VMEM
            assert p.t_fused <= p.t_generic
            assert p.vmem_bytes <= 64 * 2**20
    s = summarize(plans)
    assert s["speedup"] >= 1.0
    # mobile CNNs are bandwidth-bound on a 197-TFLOP chip: fusion must win
    # somewhere on every one of the paper's networks
    assert s["fused_modules"] >= 1


def test_fusion_speedup_is_meaningful():
    mods = NETWORKS["mobilenetv2"]()
    s = summarize(plan_network(mods))
    # dw/pw chains are heavily memory-bound: expect a solid win
    assert s["speedup"] > 1.5, s
