"""Replica-striped serving (PR 8): explicit placement on ``PreparedParams``,
``ReplicaSet`` striping and occupancy policy, atomic all-replica hot-swap,
cross-replica straggler backup, the online EMA scale calibrator, and the
``make_production_mesh`` shape override.

Single-device-safe tests run everywhere (tier-1).  Tests that need real
replicas carry ``@pytest.mark.multidevice`` plus a device-count skip, and
run in the CI multi-device job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.core.executor import (PreparedParams, ReplicaPrepared, ReplicaSet,
                                 compile_network, plan_signature)
from repro.core.graph import fire
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.launch.mesh import make_production_mesh, replica_shardings
from repro.serving import HeteroServer

HW, C = (8, 8), 16


def _need(n):
    return pytest.mark.skipif(len(jax.devices()) < n,
                              reason=f"needs {n} devices (XLA_FLAGS="
                                     f"--xla_force_host_platform_device_"
                                     f"count={n})")


def _setup():
    mods = [fire("f", 8, 16, 4, 8)]
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    return mods, plans, params


def _images(n, seed=0):
    rng = np.random.RandomState(seed)
    return [0.5 * rng.randn(*HW, C).astype(np.float32) for _ in range(n)]


def _oracle(mods, plans, params, imgs):
    eng = compile_network(mods, plans, use_pallas=False)
    prep = eng.prepare(params)
    return [np.asarray(eng(prep, x[None]))[0] for x in imgs]


# --- mesh shape override ----------------------------------------------------

def test_make_production_mesh_shape_override():
    mesh = make_production_mesh(shape=(1,))
    assert mesh.axis_names == ("data",)
    assert mesh.devices.shape == (1,)
    with pytest.raises(ValueError, match="1-3 positive axis sizes"):
        make_production_mesh(shape=(2, 2, 2, 2))
    with pytest.raises(ValueError, match="1-3 positive axis sizes"):
        make_production_mesh(shape=(0,))
    # defaults unchanged: pod-scale shapes still demand pod-scale devices
    if len(jax.devices()) < 256:
        with pytest.raises(RuntimeError, match="need 256 devices"):
            make_production_mesh()


def test_replica_shardings_one_per_data_index():
    shs = replica_shardings(make_production_mesh(shape=(1,)))
    assert len(shs) == 1
    (dev,) = shs[0].device_set
    assert dev == jax.devices()[0]


@pytest.mark.multidevice
@_need(4)
def test_replica_shardings_distinct_devices():
    shs = replica_shardings(make_production_mesh(shape=(4,)))
    assert len(shs) == 4
    devs = [tuple(s.device_set) for s in shs]
    assert len({d for ds in devs for d in ds}) == 4


# --- placement on PreparedParams -------------------------------------------

def test_default_placement_none_and_explicit_placement_bitmatch():
    mods, plans, params = _setup()
    eng = compile_network(mods, plans, use_pallas=False)
    p0 = eng.prepare(params)
    assert p0.placement is None
    x = np.stack(_images(2, seed=1))
    base = np.asarray(eng(p0, x))
    # committing the tree to an explicit single-device placement changes
    # nothing numerically — same program, same bits
    (sharding,) = replica_shardings(make_production_mesh(shape=(1,)))
    p1 = eng.prepare(params, placement=sharding)
    assert p1.placement is sharding
    assert p1.generation > p0.generation
    assert (np.asarray(eng(p1, x)) == base).all()


def test_replica_prepared_rejects_mixed_generations():
    a, b = PreparedParams({}, 1), PreparedParams({}, 2)
    with pytest.raises(ValueError, match="share one generation"):
        ReplicaPrepared([a, b])
    with pytest.raises(ValueError, match="at least one"):
        ReplicaPrepared([])


# --- ReplicaSet -------------------------------------------------------------

def test_replicaset_single_replica_bitmatches_engine():
    mods, plans, params = _setup()
    eng = compile_network(mods, plans, use_pallas=False)
    prep = eng.prepare(params)
    rset = ReplicaSet(eng, make_production_mesh(shape=(1,)))
    rprep = rset.prepare(params)
    assert len(rprep) == 1
    x = np.stack(_images(3, seed=2))
    assert (np.asarray(rset(rprep, x)) == np.asarray(eng(prep, x))).all()
    stats = rset.exec_stats()
    assert stats["replicas"] == 1 and stats["replica_calls"][0] == 1


@pytest.mark.multidevice
@_need(4)
def test_replicaset_prepare_one_generation_and_bitmatch_all_replicas():
    mods, plans, params = _setup()
    eng = compile_network(mods, plans, use_pallas=False)
    base_prep = eng.prepare(params)
    rset = ReplicaSet(eng, make_production_mesh(shape=(4,)))
    rprep = rset.prepare(params)
    assert len({rprep[r].generation for r in range(4)}) == 1
    x = np.stack(_images(2, seed=3))
    base = np.asarray(eng(base_prep, x))
    for r in range(4):
        assert (np.asarray(rset(rprep, x, replica=r)) == base).all()
        (dev,) = jax.tree.leaves(rprep[r].tree)[0].devices()
        assert dev == jax.devices()[r]


@pytest.mark.multidevice
@_need(4)
def test_replicaset_pick_is_least_outstanding_with_exclude():
    mods, plans, params = _setup()
    eng = compile_network(mods, plans, use_pallas=False)
    rset = ReplicaSet(eng, make_production_mesh(shape=(4,)))
    a, b = rset.pick(), rset.pick()
    assert a != b                         # round-robin while load is equal
    c = rset.pick(exclude=(0, 1, 2))
    assert c == 3
    assert rset.peek(exclude=(c,)) != c   # peek respects exclusion...
    before = rset.exec_stats()["replica_outstanding"]
    rset.peek()
    assert rset.exec_stats()["replica_outstanding"] == before  # ...no claim
    rset.release(a)
    rset.release(a)                       # over-release never goes negative
    assert rset.exec_stats()["replica_outstanding"][a] == 0
    # least-outstanding: the freed replica is preferred over loaded ones
    assert rset.pick() == a


# --- replica-striped serving ------------------------------------------------

@pytest.mark.multidevice
@_need(4)
def test_striped_serving_bitmatches_batch1_oracle():
    mods, plans, params = _setup()
    imgs = _images(40, seed=4)
    oracle = _oracle(mods, plans, params, imgs)
    server = HeteroServer(buckets=(1, 4, 8), in_flight=2, max_wait_ms=1.0)
    server.register("f", mods, plans, params, input_hw=HW, replicas=4)
    with server:
        rows = [f.result(timeout=60)
                for f in [server.submit("f", x) for x in imgs]]
        snap = server.metrics.snapshot()
        st = server.stats()["engines"]["f"]
    for i, (r, o) in enumerate(zip(rows, oracle)):
        assert (r == o).all(), f"row {i} differs from the batch-1 oracle"
    assert st["replica_count"] == 4
    assert sum(st["replica_calls"]) >= snap["batches"]
    assert sum(v["batches"] for v in snap["replicas"].values()) \
        == snap["batches"]
    assert len(snap["replicas"]) > 1      # traffic actually striped


@pytest.mark.multidevice
@_need(2)
def test_pipelined_entry_stripes_too():
    mods, plans, params = _setup()
    imgs = _images(12, seed=5)
    oracle = _oracle(mods, plans, params, imgs)
    server = HeteroServer(buckets=(1, 4), in_flight=2, max_wait_ms=1.0)
    server.register("f", mods, plans, params, input_hw=HW, replicas=2,
                    pipelined=True)
    with server:
        rows = [f.result(timeout=60)
                for f in [server.submit("f", x) for x in imgs]]
    for r, o in zip(rows, oracle):
        assert (np.asarray(r) == o).all()


@pytest.mark.multidevice
@_need(2)
def test_swap_params_swaps_all_replicas_under_one_generation():
    mods, plans, params = _setup()
    params2 = init_network(mods, jax.random.PRNGKey(7))
    imgs = _images(24, seed=6)
    o_old = _oracle(mods, plans, params, imgs)
    o_new = _oracle(mods, plans, params2, imgs)
    server = HeteroServer(buckets=(1, 4), in_flight=2, max_wait_ms=1.0)
    server.register("f", mods, plans, params, input_hw=HW, replicas=2)
    with server:
        pre = [server.submit("f", x) for x in imgs[:12]]
        info = server.swap_params("f", params2)
        entry = server._entries["f"]
        # every replica handle carries the ONE new generation stamp
        gens = {entry.prepared[r].generation
                for r in range(len(entry.prepared))}
        assert gens == {info["generation"]}
        post = [server.submit("f", x) for x in imgs[12:]]
        rows_pre = [f.result(timeout=60) for f in pre]
        rows_post = [f.result(timeout=60) for f in post]
    for i, r in enumerate(rows_pre):     # one generation per row, never mixed
        assert (r == o_old[i]).all() or (r == o_new[i]).all()
    for i, r in enumerate(rows_post):    # post-swap rows: new generation only
        assert (r == o_new[12 + i]).all()


@pytest.mark.multidevice
@_need(2)
def test_cross_replica_backup_dispatch_bitmatches():
    class _NeverReady:
        def is_ready(self):
            return False

    mods, plans, params = _setup()
    server = HeteroServer(buckets=(1, 4), straggler_min_ms=1.0)
    server.register("f", mods, plans, params, input_hw=HW, replicas=2)
    entry = server._entries["f"]
    for s in range(10):                   # establish a tiny rolling budget
        entry.monitor.record(s, 0.001)
    imgs = _images(1, seed=8)
    xb = np.zeros((1, *HW, C), np.float32)
    xb[0] = imgs[0]
    straggler = entry.engine.pick()       # the replica the batch "ran" on
    out = server._watch(entry, xb, _NeverReady(), entry.engine,
                        entry.prepared, straggler)
    assert not isinstance(out, _NeverReady)   # backup result won the race
    assert (np.asarray(out)[0] == _oracle(mods, plans, params, imgs)[0]).all()
    snap = server.metrics.snapshot()
    assert snap["straggler_events"] == 1
    assert snap["cross_replica_backups"] == 1
    # the backup fired on a replica OTHER than the straggling one
    calls = entry.engine.exec_stats()["replica_calls"]
    assert calls[1 - straggler] >= 1


@pytest.mark.multidevice
@_need(2)
def test_fallback_inherits_striping():
    mods, plans, params = _setup()
    server = HeteroServer(buckets=(1, 4))
    server.register("f", mods, plans, params, input_hw=HW, replicas=2,
                    prewarm_fallback=True)
    entry = server._entries["f"]
    assert isinstance(entry.fb_engine, ReplicaSet)
    assert entry.fb_engine.n_replicas == 2
    assert len({entry.fb_prepared[r].generation for r in range(2)}) == 1


# --- EMA activation-scale calibrator ----------------------------------------

def test_ema_calibrator_is_kind_aware_in_plan_signature():
    mods, plans, _params = _setup()
    sigs = {plan_signature(mods, [replace(p, calibrate=k) for p in plans],
                           False)
            for k in (False, True, "pct99", "ema")}
    assert len(sigs) == 4                 # no two calibrators ever alias
    with pytest.raises(ValueError, match="unknown calibrator"):
        plan_signature(mods, [replace(p, calibrate="emaa") for p in plans],
                       False)


def test_ema_refine_blends_toward_batch_and_restamps():
    mods, plans, params = _setup()
    cplans = [replace(p, calibrate="ema") for p in plans]
    eng = compile_network(mods, cplans, use_pallas=False)
    assert eng.ema_modules == {"f"}
    calib = np.stack(_images(4, seed=9))
    prep = eng.prepare(params, calib)
    live = 3.0 * np.stack(_images(4, seed=10))   # hotter than the calib batch
    scales = {m: s for m, s in eng.capture_scales(prep, live).items()
              if m in eng.ema_modules}
    refined = eng.refine_scales(prep, scales, alpha=0.5)
    assert refined.generation > prep.generation
    site = next(iter(scales["f"]))
    old = float(prep["f"][site]["x_scale"])
    new = float(refined["f"][site]["x_scale"])
    target = float(scales["f"][site])
    assert abs(new - (0.5 * old + 0.5 * target)) < 1e-6
    # alpha=0 keeps the frozen scales (and therefore the bits) unchanged
    frozen = eng.refine_scales(prep, scales, alpha=0.0)
    x = np.stack(_images(2, seed=11))
    assert (np.asarray(eng(frozen, x)) == np.asarray(eng(prep, x))).all()


def test_server_refines_ema_scales_over_first_k_batches():
    mods, plans, params = _setup()
    cplans = [replace(p, calibrate="ema") for p in plans]
    calib = np.stack(_images(4, seed=12))
    imgs = _images(20, seed=13)
    server = HeteroServer(buckets=(1, 4), in_flight=1, max_wait_ms=1.0,
                          ema_batches=3, ema_alpha=0.3)
    server.register("f", mods, cplans, params, input_hw=HW, calib_x=calib)
    g0 = server._entries["f"].prepared.generation
    with server:
        rows = [f.result(timeout=60)
                for f in [server.submit("f", x) for x in imgs]]
        snap = server.metrics.snapshot()
        entry = server._entries["f"]
        # steady state after the budget: served rows bit-match the batch-1
        # oracle of the CURRENT (refined) prepared handle
        eng, prep = entry.active()
        assert (rows[-1] == np.asarray(eng(prep, imgs[-1][None]))[0]).all()
    assert snap["ema_updates"] == 3
    assert entry.ema_left == 0
    assert entry.prepared.generation == g0 + 3   # one stamp per refinement
    assert len(rows) == len(imgs)


def test_amax_calibrator_never_refines_online():
    mods, plans, params = _setup()
    cplans = [replace(p, calibrate=True) for p in plans]
    calib = np.stack(_images(4, seed=14))
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0, ema_batches=8)
    server.register("f", mods, cplans, params, input_hw=HW, calib_x=calib)
    g0 = server._entries["f"].prepared.generation
    with server:
        for f in [server.submit("f", x) for x in _images(8, seed=15)]:
            f.result(timeout=60)
        snap = server.metrics.snapshot()
    assert snap["ema_updates"] == 0
    assert server._entries["f"].prepared.generation == g0


@pytest.mark.multidevice
@_need(2)
def test_ema_refines_all_replicas_under_one_stamp():
    mods, plans, params = _setup()
    cplans = [replace(p, calibrate="ema") for p in plans]
    calib = np.stack(_images(4, seed=16))
    eng = compile_network(mods, cplans, use_pallas=False)
    rset = ReplicaSet(eng, make_production_mesh(shape=(2,)))
    prep = rset.prepare(params, calib)
    live = np.stack(_images(4, seed=17))
    scales = rset.capture_scales(prep, live)
    refined = rset.refine_scales(prep, scales, alpha=0.5)
    assert len({refined[r].generation for r in range(2)}) == 1
    assert refined.generation > prep.generation
    x = np.stack(_images(2, seed=18))
    assert (np.asarray(rset(refined, x, replica=0))
            == np.asarray(rset(refined, x, replica=1))).all()
