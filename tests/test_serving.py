"""Batched multi-plan serving: correctness (batched results bit-match
per-request ``compile_network`` calls across networks and partitioner
schemes), scheduling (bucket selection, deadline flush, multi-plan
isolation), and executor-cache behaviour under a live server."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core.executor import cache_stats, clear_cache, compile_network
from repro.core.graph import NETWORKS, bottleneck, fire, shuffle_unit
from repro.core.hetero import init_network
from repro.core.partitioner import candidates, partition_network
from repro.serving import (DynamicBatcher, HeteroServer, pad_batch,
                           percentile, pick_bucket)

RES = 24


def _assert_bitmatch(server, name, engine, prepared, images, timeout=60):
    futs = [server.submit(name, x) for x in images]
    outs = [f.result(timeout=timeout) for f in futs]
    for x, out in zip(images, outs):
        ref = engine(prepared, x[None])[0]
        assert out.shape == ref.shape
        assert bool(jnp.all(out == ref)), \
            f"{name}: served result differs from per-request engine call"


def _images(n, hw, c, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return [0.5 * jax.random.normal(k, (*hw, c)) for k in ks]


# --- correctness: full networks, interleaved multi-plan --------------------

def test_full_networks_bitmatch_interleaved():
    """All three paper networks resident at once; interleaved requests come
    back bit-identical to batch-1 engine calls despite shared batches."""
    server = HeteroServer(buckets=(1, 4, 8), max_wait_ms=5.0)
    refs = {}
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        params = init_network(mods, jax.random.PRNGKey(0))
        server.register(net, mods, plans, params, input_hw=(RES, RES))
        eng = compile_network(mods, plans)
        refs[net] = (eng, eng.prepare(params))
    imgs = {net: _images(6, (RES, RES), 3, seed=i)
            for i, net in enumerate(NETWORKS)}
    with server:
        futs = [(net, x, server.submit(net, x))
                for i in range(6) for net, x in
                ((n, imgs[n][i]) for n in NETWORKS)]
        for net, x, f in futs:
            out = f.result(timeout=120)
            eng, prep = refs[net]
            assert bool(jnp.all(out == eng(prep, x[None])[0]))
    snap = server.metrics.snapshot()
    assert snap["completed"] == 18 and snap["failed"] == 0


# --- correctness: every partitioner scheme through the server --------------

def _scheme_case(m, scheme):
    ps = [p for p in candidates(m) if p.scheme == scheme]
    assert ps, f"no {scheme} candidate for {m.kind}"
    return [m], [ps[0]]


SCHEME_CASES = [
    ("fire", lambda: fire("f", 16, 64, 16, 64),
     ["gpu_only", "fpga_fused", "parallel_branch", "gconv_split"]),
    ("bottleneck", lambda: bottleneck("b", 16, 24, 24, 1, 6),
     ["gpu_only", "fpga_fused", "dwconv_split", "fused_layer"]),
    ("shuffle_unit", lambda: shuffle_unit("s", 16, 48, False),
     ["fpga_fused", "dwconv_split", "fused_layer"]),
    ("shuffle_unit_down", lambda: shuffle_unit("sd", 16, 48, True),
     ["parallel_branch"]),
]


@pytest.mark.parametrize("kind,builder,schemes", SCHEME_CASES,
                         ids=[c[0] for c in SCHEME_CASES])
def test_scheme_bitmatch(kind, builder, schemes):
    for scheme in schemes:
        mods, plans = _scheme_case(builder(), scheme)
        params = init_network(mods, jax.random.PRNGKey(1))
        server = HeteroServer(buckets=(1, 4), max_wait_ms=3.0)
        server.register(kind, mods, plans, params, input_hw=(16, 16))
        eng = compile_network(mods, plans)
        prep = eng.prepare(params)
        c_in = mods[0].nodes[0].spec.c_in
        with server:
            _assert_bitmatch(server, kind, eng, prep,
                             _images(5, (16, 16), c_in, seed=2))


# --- scheduling: buckets -----------------------------------------------------

def test_pick_bucket():
    assert pick_bucket(1, (1, 4, 8, 32)) == 1
    assert pick_bucket(2, (1, 4, 8, 32)) == 4
    assert pick_bucket(4, (1, 4, 8, 32)) == 4
    assert pick_bucket(9, (1, 4, 8, 32)) == 32
    assert pick_bucket(40, (1, 4, 8, 32)) == 32   # capped at the largest


def test_deadline_take_pads_small_splits_large():
    ladder = (1, 4, 8, 32)
    # small overshoot: pad up to the covering bucket in one flush
    assert DynamicBatcher._deadline_take(2, ladder) == 2    # -> bucket 4
    assert DynamicBatcher._deadline_take(5, ladder) == 5    # -> bucket 8
    assert DynamicBatcher._deadline_take(8, ladder) == 8    # exact
    # >half the covering bucket would be pad: flush the largest full
    # bucket, leave the remainder queued
    assert DynamicBatcher._deadline_take(10, ladder) == 8
    assert DynamicBatcher._deadline_take(9, ladder) == 8
    assert DynamicBatcher._deadline_take(17, ladder) == 17  # -> bucket 32
    assert DynamicBatcher._deadline_take(32, ladder) == 32


def test_pad_batch_pads_with_inert_zeros():
    xs = [jnp.ones((4, 4, 3)), 2 * jnp.ones((4, 4, 3))]
    xb = pad_batch(xs, 4)
    assert xb.shape == (4, 4, 4, 3)
    assert bool(jnp.all(xb[0] == 1)) and bool(jnp.all(xb[1] == 2))
    assert bool(jnp.all(xb[2:] == 0))


def test_full_bucket_flushes_by_size():
    m = fire("f", 8, 16, 4, 8)
    server = HeteroServer(buckets=(1, 4), max_wait_ms=5000.0)
    server.register("f", [m], None, input_hw=(8, 8))
    with server:
        futs = [server.submit("f", x) for x in _images(4, (8, 8), 16)]
        for f in futs:
            f.result(timeout=60)
    snap = server.metrics.snapshot()
    # a full bucket must not wait for the (5 s) deadline
    assert snap["size_flushes"] >= 1 and snap["deadline_flushes"] == 0
    assert snap["padded_slots"] == 0


def test_partial_group_flushes_by_deadline_into_padded_bucket():
    m = fire("f", 8, 16, 4, 8)
    server = HeteroServer(buckets=(1, 4), max_wait_ms=30.0)
    server.register("f", [m], None, input_hw=(8, 8))
    with server:
        t0 = time.monotonic()
        futs = [server.submit("f", x) for x in _images(2, (8, 8), 16)]
        for f in futs:
            f.result(timeout=60)
        waited = time.monotonic() - t0
    snap = server.metrics.snapshot()
    assert snap["deadline_flushes"] >= 1
    assert snap["padded_slots"] == 2          # 2 requests -> bucket 4
    assert waited >= 0.025                    # sat out the max-wait window


def test_shutdown_flushes_backlog_larger_than_max_bucket():
    """A queued backlog exceeding the largest bucket must drain in chunks
    at shutdown, not error out."""
    m = fire("f", 8, 16, 4, 8)
    server = HeteroServer(buckets=(1, 4), max_wait_ms=10000.0)
    server.register("f", [m], None, input_hw=(8, 8))
    eng = compile_network([m], None)
    prep = eng.prepare(server._entries["f"].params)
    server.start()
    server._stop.set()                      # idle the drain loop...
    time.sleep(0.2)
    imgs = _images(10, (8, 8), 16, seed=5)  # ...then queue 10 > bucket 4
    futs = [server.submit("f", x) for x in imgs]
    server.shutdown()
    for x, f in zip(imgs, futs):
        out = f.result(timeout=60)
        assert bool(jnp.all(out == eng(prep, x[None])[0]))


def test_submit_validates_network_and_shape():
    server = HeteroServer(buckets=(1,))
    with pytest.raises(KeyError, match="unregistered"):
        server.submit("nope", jnp.zeros((8, 8, 16)))
    server.register("f", [fire("f", 8, 16, 4, 8)], None, input_hw=(8, 8))
    with pytest.raises(ValueError, match="expected an image"):
        server.submit("f", jnp.zeros((8, 8, 4)))


# --- scheduling: multi-plan isolation --------------------------------------

def test_multi_plan_isolation_same_network_different_plans():
    """The same topology under two different plans serves from two distinct
    engines (keyed by plan signature) — requests never cross-route."""
    mods_a = NETWORKS["mobilenetv2"]()
    mods_b = NETWORKS["mobilenetv2"]()
    plans_a = partition_network(mods_a, paper_faithful=True)
    plans_b = partition_network(mods_b, objective="gpu_only")
    params = init_network(mods_a, jax.random.PRNGKey(0))
    server = HeteroServer(buckets=(1, 4), max_wait_ms=3.0)
    server.register("hetero", mods_a, plans_a, params, input_hw=(RES, RES))
    server.register("gpu", mods_b, plans_b, params, input_hw=(RES, RES))
    eng_a = compile_network(mods_a, plans_a)
    eng_b = compile_network(mods_b, plans_b)
    assert eng_a is not eng_b
    prep_a, prep_b = eng_a.prepare(params), eng_b.prepare(params)
    imgs = _images(4, (RES, RES), 3, seed=3)
    with server:
        fa = [server.submit("hetero", x) for x in imgs]
        fb = [server.submit("gpu", x) for x in imgs]
        outs_a = [f.result(timeout=120) for f in fa]
        outs_b = [f.result(timeout=120) for f in fb]
    for x, oa, ob in zip(imgs, outs_a, outs_b):
        assert bool(jnp.all(oa == eng_a(prep_a, x[None])[0]))
        assert bool(jnp.all(ob == eng_b(prep_b, x[None])[0]))
        # the two plans really are different programs
        assert not bool(jnp.all(oa == ob))


# --- executor cache behaviour under serving --------------------------------

def test_warmup_trace_and_cache_accounting():
    clear_cache()
    m = fire("f", 8, 16, 4, 8)
    server = HeteroServer(buckets=(1, 4), max_wait_ms=3.0)
    st = server.register("f", [m], None, input_hw=(8, 8))
    assert (st["calls"], st["traces"]) == (2, 2)   # one trace per bucket
    assert cache_stats()["misses"] == 1
    # an equivalent (modules, plans) pair is a compile-cache hit...
    st2 = server.register("f2", [fire("f", 8, 16, 4, 8)], None,
                          input_hw=(8, 8))
    assert cache_stats()["hits"] == 1
    # ...sharing the engine, whose bucket shapes are already traced
    assert st2["traces"] == 2 and st2["calls"] == 4
    with server:
        futs = [server.submit("f", x) for x in _images(4, (8, 8), 16)]
        for f in futs:
            f.result(timeout=60)
    eng = server.stats()["engines"]["f"]
    assert eng["traces"] == 2                 # live traffic hit warm shapes


def test_clear_cache_invalidates_live_server_safely():
    clear_cache()
    mods = [fire("f", 8, 16, 4, 8)]
    params = init_network(mods, jax.random.PRNGKey(0))
    server = HeteroServer(buckets=(1, 4), max_wait_ms=3.0)
    server.register("f", mods, None, params, input_hw=(8, 8))
    imgs = _images(3, (8, 8), 16, seed=4)
    with server:
        before = [server.submit("f", x).result(timeout=60) for x in imgs]
        gen0 = cache_stats()["generation"]
        clear_cache()
        assert cache_stats()["generation"] == gen0 + 1
        assert not server.stats()["engines"]["f"]["current"]
        after = [server.submit("f", x).result(timeout=60) for x in imgs]
    # served through a fresh engine, same bits, no dropped requests
    for b, a in zip(before, after):
        assert bool(jnp.all(a == b))
    snap = server.metrics.snapshot()
    assert snap["recompiles"] == 1 and snap["failed"] == 0
    assert server.stats()["engines"]["f"]["current"]
    assert cache_stats()["misses"] >= 1       # the recompile re-populated


# --- metrics ---------------------------------------------------------------

def test_percentile():
    assert percentile([1.0], 99) == 1.0
    assert percentile(range(1, 101), 50) == pytest.approx(50.5)
    assert percentile(range(1, 101), 99) == pytest.approx(99.01)
    assert percentile([], 50) != percentile([], 50)   # NaN


def test_snapshot_reports_latency_and_throughput():
    server = HeteroServer(buckets=(1, 4), max_wait_ms=3.0)
    server.register("f", [fire("f", 8, 16, 4, 8)], None, input_hw=(8, 8))
    with server:
        futs = [server.submit("f", x) for x in _images(8, (8, 8), 16)]
        for f in futs:
            f.result(timeout=60)
    snap = server.metrics.snapshot()
    assert snap["completed"] == 8
    assert snap["p50_ms"] > 0 and snap["p99_ms"] >= snap["p50_ms"]
    assert snap["throughput_rps"] > 0
