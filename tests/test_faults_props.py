"""Property-based fault-tolerance: random interleavings of submit bursts,
injected dispatch faults, load shedding and early shutdown must preserve
the request-level contract — every admitted future resolves exactly once,
and no lane loses, duplicates, or reorders its surviving rows.

Skips cleanly when hypothesis is absent (the ``property`` extra)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import threading
import time

import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.executor import compile_network
from repro.core.graph import fire
from repro.runtime.faults import FaultPlan, FaultRule, inject
from repro.serving import HeteroServer, Overloaded

HW = (8, 8)
C = 16


def _images(n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return [0.5 * jax.random.normal(k, (*HW, C)) for k in ks]


@pytest.mark.faults
@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_random_interleavings_preserve_request_contract(data):
    n = data.draw(st.integers(4, 14), label="n_requests")
    priorities = data.draw(st.lists(st.integers(0, 1), min_size=n,
                                    max_size=n), label="priorities")
    fail_after = data.draw(st.integers(0, 10), label="fail_after")
    fail_times = data.draw(st.integers(0, 3), label="fail_times")
    delay_times = data.draw(st.integers(0, 2), label="delay_times")
    max_queue = data.draw(st.integers(2, 64), label="max_queue")
    in_flight = data.draw(st.sampled_from([1, 2]), label="in_flight")
    early_shutdown = data.draw(st.booleans(), label="early_shutdown")

    mods = [fire("f", C, 16, 4, 8)]
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0,
                          in_flight=in_flight, max_queue=max_queue)
    server.register("f", mods, None, input_hw=HW)
    eng = compile_network(mods, None)            # oracle OUTSIDE inject
    prep = eng.prepare(server._entries["f"].params)
    imgs = _images(n, seed=n)

    rules = []
    if fail_times:
        rules.append(FaultRule(op="dispatch", after=fail_after,
                               times=fail_times))
    if delay_times:
        rules.append(FaultRule(op="dispatch", kind="delay", delay_s=0.002,
                               times=delay_times))

    completion_order = []                        # (priority, idx) as resolved
    order_lock = threading.Lock()

    def _tracker(idx, prio):
        def cb(fut):
            with order_lock:
                completion_order.append((prio, idx))
        return cb

    admitted = []                                # (idx, x, prio, future)
    shed = 0
    with server:
        with inject(FaultPlan(rules)):
            for i, (x, prio) in enumerate(zip(imgs, priorities)):
                try:
                    f = server.submit("f", x, priority=prio)
                except Overloaded:
                    shed += 1
                    continue
                f.add_done_callback(_tracker(i, prio))
                admitted.append((i, x, prio, f))
            if not early_shutdown:
                for _, _, _, f in admitted:      # wait inside the scope
                    f.exception(timeout=60)
            server.shutdown()                    # graceful drain

    # 1. every admitted future resolved (exactly-once is structural:
    #    concurrent.futures forbids a second set_result/set_exception)
    for _, _, _, f in admitted:
        assert f.done(), "an admitted future never resolved"
    assert not server._pending

    # 2. surviving rows are each caller's own bits — nothing lost to
    #    padding, retries, batch-mates, or the drain path
    survivors = [(i, x, prio, f) for i, x, prio, f in admitted
                 if f.exception(timeout=0) is None]
    for i, x, prio, f in survivors:
        ref = eng(prep, x[None])[0]
        assert bool(jnp.all(f.result(timeout=0) == ref)), \
            f"request {i}: served row differs from its batch-1 oracle"

    # 3. FIFO within lane: surviving rows of one lane resolve in
    #    submission order (head-of-lane retries keep their place)
    surviving_ids = {i for i, _, _, _ in survivors}
    for lane_prio in (0, 1):
        resolved = [i for prio, i in completion_order
                    if prio == lane_prio and i in surviving_ids]
        assert resolved == sorted(resolved), \
            f"lane p{lane_prio} reordered surviving rows: {resolved}"

    # 4. the books balance
    snap = server.metrics.snapshot()
    assert snap["shed"] == shed
    assert snap["submitted"] == len(admitted)
    assert snap["completed"] == len(survivors)


@pytest.mark.faults
@given(seed=st.integers(0, 2**16), shutdown_delay=st.floats(0.0, 0.01))
@settings(max_examples=8, deadline=None)
def test_shutdown_races_live_submissions_without_hanging(seed, shutdown_delay):
    """A shutdown racing a submitting thread: whatever was admitted
    resolves — served or typed — and nothing hangs."""
    mods = [fire("f", C, 16, 4, 8)]
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0)
    server.register("f", mods, None, input_hw=HW)
    imgs = _images(8, seed=seed % 97)
    futs = []

    def pump():
        for x in imgs:
            try:
                futs.append(server.submit("f", x))
            except Exception:                    # ServerClosed mid-race: fine
                return

    server.start()
    t = threading.Thread(target=pump)
    t.start()
    time.sleep(shutdown_delay)
    server.shutdown()
    t.join(30)
    assert not t.is_alive()
    for f in futs:
        f.exception(timeout=60)                  # resolves, result or typed
        assert f.done()
    assert not server._pending
