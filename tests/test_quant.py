"""int8 fixed-point properties (hypothesis)."""
import pytest

pytest.importorskip("hypothesis")  # optional extra; suite stays green without it

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.optim import compress_int8, decompress_int8
from repro.quant import dequantize, fake_quant, int8_matmul, quantize


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_quant_roundtrip_error_bounded(seed, scale_mag):
    x = scale_mag * jax.random.normal(jax.random.PRNGKey(seed), (64, 32))
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_per_channel_beats_or_ties_per_tensor(seed):
    k = jax.random.PRNGKey(seed)
    # heterogeneous channel magnitudes
    scales = jnp.exp(jax.random.normal(jax.random.fold_in(k, 1), (1, 16)) * 2)
    w = jax.random.normal(k, (64, 16)) * scales
    err_pc = float(jnp.abs(fake_quant(w, axis=-1) - w).mean())
    err_pt = float(jnp.abs(fake_quant(w) - w).mean())
    assert err_pc <= err_pt * 1.05


def test_int8_matmul_close_to_fp32():
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 32))
    aq, asc = quantize(a)
    wq, wsc = quantize(w, axis=-1)
    out = int8_matmul(aq, asc, wq, wsc)
    rel = float(jnp.abs(out - a @ w).max() / jnp.abs(a @ w).max())
    assert rel < 0.05


def test_int8_grad_compression_error_feedback():
    """Error feedback makes compressed-grad SGD track true SGD on average."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(1000,)) * 0.1)
    err = jnp.zeros_like(g_true)
    acc_c, acc_t = jnp.zeros_like(g_true), jnp.zeros_like(g_true)
    for step in range(30):
        g = g_true + 0.01 * jnp.asarray(rng.normal(size=(1000,)))
        q, s, err = compress_int8(g, err)
        acc_c = acc_c + decompress_int8(q, s)
        acc_t = acc_t + g
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.02     # error feedback keeps the accumulated drift tiny
