"""Prepare-time calibration: frozen activation scales, plan-signature
separation, serving integration."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import compile_network, plan_signature
from repro.core.graph import NETWORKS
from repro.core.hetero import init_network, run_network
from repro.core.partitioner import partition_network
from repro.serving import HeteroServer


def _setup(net="mobilenetv2", res=32):
    mods = NETWORKS[net]()
    plans = partition_network(mods, paper_faithful=True)
    cplans = [replace(p, calibrate=True) for p in plans]
    params = init_network(mods, jax.random.PRNGKey(0))
    calib = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (4, res, res, 3))
    return mods, plans, cplans, params, calib


def test_prepare_without_calib_batch_raises():
    mods, _plans, cplans, params, _calib = _setup()
    eng = compile_network(mods, cplans, use_pallas=False)
    assert eng.needs_calibration
    with pytest.raises(ValueError, match="calibration batch"):
        eng.prepare(params)


def test_uncalibrated_plans_ignore_calib_batch():
    mods, plans, _cplans, params, calib = _setup()
    eng = compile_network(mods, plans, use_pallas=False)
    assert not eng.needs_calibration
    p1 = eng.prepare(params)
    p2 = eng.prepare(params, calib)          # accepted, no-op
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 3))
    assert (eng(p1, x) == eng(p2, x)).all()


@pytest.mark.parametrize("net", list(NETWORKS))
def test_frozen_scales_stable_and_batch_invariant(net):
    """Calibrated plans produce bit-identical outputs across calls, and a
    row's logits never depend on its batch-mates (frozen scales are
    constants — the serving contract holds trivially)."""
    mods, _plans, cplans, params, calib = _setup(net)
    eng = compile_network(mods, cplans, use_pallas=False)
    prep = eng.prepare(params, calib)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (4, 32, 32, 3))
    out1 = eng(prep, x)
    out2 = eng(prep, x)
    assert (out1 == out2).all()
    for i in range(x.shape[0]):
        row = eng(prep, x[i:i + 1])
        assert (row[0] == out1[i]).all(), f"{net}: row {i} not invariant"


def test_calibrated_close_to_interpreted_oracle():
    mods, plans, cplans, params, calib = _setup()
    eng = compile_network(mods, cplans, use_pallas=False)
    prep = eng.prepare(params, calib)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 3))
    out = eng(prep, x)
    ref = run_network(mods, params, x, plans)
    cos = float(jnp.sum(out * ref)
                / (jnp.linalg.norm(out) * jnp.linalg.norm(ref)))
    assert cos > 0.995


def test_signature_separates_calibrated_plans():
    mods, plans, cplans, params, calib = _setup()
    assert plan_signature(mods, plans, False) \
        != plan_signature(mods, cplans, False)
    e_u = compile_network(mods, plans, use_pallas=False)
    e_c = compile_network(mods, cplans, use_pallas=False)
    assert e_u is not e_c
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 3))
    out_u = e_u(e_u.prepare(params), x)
    out_c = e_c(e_c.prepare(params, calib), x)
    # different quantization grids -> different (but close) numerics
    assert not bool((out_u == out_c).all())
    cos = float(jnp.sum(out_u * out_c)
                / (jnp.linalg.norm(out_u) * jnp.linalg.norm(out_c)))
    assert cos > 0.995


def test_gpu_only_plans_never_need_calibration():
    mods = NETWORKS["squeezenet"]()
    plans = [replace(p, calibrate=True)
             for p in partition_network(mods, objective="gpu_only")]
    eng = compile_network(mods, plans, use_pallas=False)
    assert not eng.needs_calibration    # no FPGA quant sites to freeze


# --- calibrator kinds (amax vs pct99) --------------------------------------

def _scales(prepared):
    """Every frozen x_scale in a prepared tree, keyed module/site."""
    out = {}
    for mod, sites in prepared.items():
        for site, p in sites.items():
            if isinstance(p, dict) and "x_scale" in p:
                out[f"{mod}/{site}"] = float(p["x_scale"])
    return out


def test_pct99_clips_below_amax_with_outliers():
    """With an outlier spike in the calibration batch, the percentile
    calibrator must freeze strictly smaller scales than abs-max at the
    entry site (finer grid for the bulk, outlier saturates)."""
    mods, _plans, cplans, params, calib = _setup()
    spiked = calib.at[0, 0, 0, 0].set(1e3)
    pplans = [replace(p, calibrate="pct99") for p in cplans]
    e_a = compile_network(mods, cplans, use_pallas=False)
    e_p = compile_network(mods, pplans, use_pallas=False)
    s_a = _scales(e_a.prepare(params, spiked))
    s_p = _scales(e_p.prepare(params, spiked))
    assert set(s_a) == set(s_p) and s_a
    assert all(s_p[k] <= s_a[k] + 1e-12 for k in s_a)
    assert any(s_p[k] < s_a[k] * 0.99 for k in s_a)


def test_calibrator_kinds_separate_signatures_and_engines():
    mods, plans, cplans, params, calib = _setup()
    pplans = [replace(p, calibrate="pct99") for p in plans]
    aplans = [replace(p, calibrate="amax") for p in plans]
    sig_a = plan_signature(mods, cplans, False)
    assert sig_a == plan_signature(mods, aplans, False)  # True == "amax"
    sig_p = plan_signature(mods, pplans, False)
    assert sig_p != sig_a
    e_a = compile_network(mods, cplans, use_pallas=False)
    e_p = compile_network(mods, pplans, use_pallas=False)
    assert e_a is not e_p
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 3))
    out_a = e_a(e_a.prepare(params, calib), x)
    out_p = e_p(e_p.prepare(params, calib), x)
    # different frozen grids -> different numerics; pct99 really clips the
    # tail so it drifts further from amax than amax does from uncalibrated
    assert not bool((out_a == out_p).all())
    cos = float(jnp.sum(out_a * out_p)
                / (jnp.linalg.norm(out_a) * jnp.linalg.norm(out_p)))
    assert cos > 0.95


def test_pct99_batch_invariant():
    mods, plans, _cplans, params, calib = _setup("shufflenetv2")
    pplans = [replace(p, calibrate="pct99") for p in plans]
    eng = compile_network(mods, pplans, use_pallas=False)
    prep = eng.prepare(params, calib)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (4, 32, 32, 3))
    out = eng(prep, x)
    for i in range(x.shape[0]):
        assert (eng(prep, x[i:i + 1])[0] == out[i]).all()


def test_unknown_calibrator_kind_raises():
    mods, plans, _c, _params, _calib = _setup()
    bad = [replace(p, calibrate="pct999") for p in plans]
    with pytest.raises(ValueError, match="unknown calibrator"):
        plan_signature(mods, bad, False)
    with pytest.raises(ValueError, match="unknown calibrator"):
        compile_network(mods, bad, use_pallas=False)


# --- serving ---------------------------------------------------------------

def test_serving_rejects_calibrated_plans_without_batch():
    mods, _plans, cplans, params, _calib = _setup("shufflenetv2")
    server = HeteroServer(buckets=(1, 4))
    with pytest.raises(ValueError, match="calib_x"):
        server.register("cal", mods, cplans, params, input_hw=(32, 32))


def test_serving_mixed_calibrated_uncalibrated_isolated():
    """Calibrated and uncalibrated registrations of the SAME network get
    distinct engines (distinct signatures) and each serves rows that
    bit-match its own direct batch-1 calls."""
    mods, plans, cplans, params, calib = _setup("shufflenetv2")
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0)
    server.register("cal", mods, cplans, params, input_hw=(32, 32),
                    calib_x=calib)
    server.register("uncal", mods, plans, params, input_hw=(32, 32))
    e_c = compile_network(mods, cplans)
    e_u = compile_network(mods, plans)
    assert e_c is not e_u
    prep_c = e_c.prepare(params, calib)
    prep_u = e_u.prepare(params)
    imgs = [jax.random.normal(jax.random.PRNGKey(i), (32, 32, 3))
            for i in range(5)]
    with server:
        fc = [server.submit("cal", x) for x in imgs]
        fu = [server.submit("uncal", x) for x in imgs]
        rows_c = [f.result(120) for f in fc]
        rows_u = [f.result(120) for f in fu]
    for x, rc, ru in zip(imgs, rows_c, rows_u):
        xb = np.asarray(x)[None]
        assert (np.asarray(e_c(prep_c, xb))[0] == rc).all()
        assert (np.asarray(e_u(prep_u, xb))[0] == ru).all()
