"""Fault tolerance: atomic async checkpoints, crash/resume determinism,
data-pipeline cursor restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import TokenPipeline, synthetic_batches
from repro.models.lm import model as lm
from repro.optim import make_optimizer
from repro.runtime.resilience import FaultTolerantLoop, StragglerMonitor
from repro.train.steps import TrainState, make_train_step


def _tiny_setup():
    cfg = reduced(get_config("llama3-8b"), n_layers=2, d_model=64, n_heads=2,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype="float32")
    opt = make_optimizer("adamw")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    step = jax.jit(make_train_step(cfg, opt))
    gen = synthetic_batches(cfg.vocab, 4, 32)
    return cfg, state, step, gen


def test_save_restore_roundtrip(tmp_path):
    _, state, _, _ = _tiny_setup()
    ckpt = CheckpointManager(tmp_path)
    ckpt.save(7, state, blocking=True)
    assert ckpt.latest_step() == 7
    restored, step = ckpt.restore(None, state)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)


def test_gc_keeps_last_n(tmp_path):
    _, state, _, _ = _tiny_setup()
    ckpt = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, state, blocking=True)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_crash_and_resume_is_deterministic(tmp_path):
    _, state0, step, gen = _tiny_setup()
    # uninterrupted run
    ckpt_a = CheckpointManager(tmp_path / "a")
    loop_a = FaultTolerantLoop(step, ckpt_a, save_every=3)
    final_a, _ = loop_a.run(state0, gen, total=10)

    # crashed + resumed run
    ckpt_b = CheckpointManager(tmp_path / "b")
    loop_b = FaultTolerantLoop(step, ckpt_b, save_every=3)
    with pytest.raises(RuntimeError, match="simulated preemption"):
        loop_b.run(state0, gen, total=10, crash_at=6)
    final_b, _ = loop_b.run(state0, gen, total=10)   # resumes from step 6
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), final_a, final_b)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0)
    for s in range(20):
        mon.record(s, 0.1)
    assert not mon.flagged
    assert mon.record(20, 0.5)
    assert mon.flagged[-1][0] == 20


def test_token_pipeline_cursor_restore():
    toks = np.arange(100000, dtype=np.int32) % 1000
    p1 = TokenPipeline(toks, batch=4, seq=16)
    b1 = [p1.next_batch() for _ in range(3)]
    saved = p1.state()
    b_next = p1.next_batch()
    p2 = TokenPipeline(toks, batch=4, seq=16)
    p2.restore(saved)
    b_resume = p2.next_batch()
    np.testing.assert_array_equal(b_next["tokens"], b_resume["tokens"])
