"""``repro.runtime.resilience`` coverage: StragglerMonitor window /
threshold / budget semantics, and FaultTolerantLoop crash-resume on a
cheap synthetic state (the LM-model variant lives in test_checkpoint.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime.resilience import (FaultTolerantLoop, StragglerMonitor,
                                      reshard)


# --- StragglerMonitor -------------------------------------------------------

def test_monitor_no_budget_before_min_samples():
    mon = StragglerMonitor(threshold=2.0, min_samples=5)
    for s in range(4):
        assert mon.record(s, 0.1) is False
        assert mon.median() is None
        assert mon.budget() is None
    mon.record(4, 0.1)
    assert mon.median() == pytest.approx(0.1)
    assert mon.budget() == pytest.approx(0.2)


def test_monitor_threshold_is_strict_multiple_of_median():
    mon = StragglerMonitor(threshold=2.0, min_samples=5)
    for s in range(10):
        mon.record(s, 0.1)
    # exactly at threshold x median: not a straggler (strict >)
    assert mon.record(10, 0.2) is False
    assert mon.record(11, 0.21) is True
    step, seconds, med = mon.flagged[-1]
    assert step == 11 and seconds == pytest.approx(0.21)
    assert med == pytest.approx(0.1)


def test_monitor_window_bounds_history_and_adapts_median():
    mon = StragglerMonitor(threshold=2.0, window=50)
    for s in range(200):
        mon.record(s, 0.01)
    assert len(mon.times) <= 50
    # drift the workload slower: the rolling median follows, so what was
    # a straggler against the old regime becomes normal
    for s in range(200, 260):
        mon.record(s, 0.05)
    assert mon.median() == pytest.approx(0.05)
    assert mon.record(260, 0.09) is False


def test_monitor_flagged_list_is_bounded():
    mon = StragglerMonitor(threshold=1.0, window=10, min_samples=1)
    # threshold 1.0: every strictly-increasing step flags
    for s in range(100):
        mon.record(s, 0.01 * (s + 1))
    assert len(mon.flagged) <= 10


# --- FaultTolerantLoop (cheap state; no LM model) ---------------------------

def _counting_loop(tmp_path, name, **kw):
    def step(state, batch):
        w = state["w"] + batch
        return {"w": w}, jnp.sum(w)

    ckpt = CheckpointManager(tmp_path / name)
    return FaultTolerantLoop(step, ckpt, **kw)


def _batches(step):
    return jnp.full((4,), float(step + 1))


def test_loop_crash_resume_bitmatches_uninterrupted(tmp_path):
    state0 = {"w": jnp.zeros((4,))}
    loop_a = _counting_loop(tmp_path, "a", save_every=2)
    final_a, _ = loop_a.run(state0, _batches, total=9)

    loop_b = _counting_loop(tmp_path, "b", save_every=2)
    with pytest.raises(RuntimeError, match="simulated preemption"):
        loop_b.run(state0, _batches, total=9, crash_at=5)
    # the crash landed after step 5's checkpoint logic: step 4 is the
    # latest save (save_every=2), so the relaunch replays 5..8 exactly
    assert loop_b.ckpt.latest_step() == 4
    final_b, _ = loop_b.run(state0, _batches, total=9)
    np.testing.assert_array_equal(np.asarray(final_a["w"]),
                                  np.asarray(final_b["w"]))


def test_loop_records_step_times(tmp_path):
    loop = _counting_loop(tmp_path, "t", save_every=100)
    loop.run({"w": jnp.zeros((4,))}, _batches, total=6)
    assert len(loop.monitor.times) == 6
    assert all(t >= 0.0 for t in loop.monitor.times)


def test_reshard_is_identity_on_single_device():
    state = {"w": jnp.arange(8.0)}
    sharding = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), state)
    out = reshard(state, sharding)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(state["w"]))
