"""Pallas kernels vs pure-jnp oracles, swept over shapes/dtypes (interpret
mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention as attn_ref
from repro.kernels.fused_block.ops import fused_block
from repro.kernels.fused_block.ref import fused_dw_pw
from repro.kernels.int8_gemm.kernel import int8_gemm_pallas
from repro.kernels.int8_gemm.ref import int8_gemm as int8_ref
from repro.quant import quantize


@pytest.mark.parametrize("shape", [(1, 8, 8, 8), (2, 16, 16, 32),
                                   (1, 14, 14, 96), (3, 7, 9, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_block_matches_ref(shape, dtype):
    B, H, W, C = shape
    Co = 2 * C
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], shape, dtype)
    dw_w = (jax.random.normal(ks[1], (3, 3, C)) * 0.3).astype(dtype)
    dw_b = (jax.random.normal(ks[2], (C,)) * 0.1).astype(dtype)
    pw_w = (jax.random.normal(ks[3], (C, Co)) * 0.3).astype(dtype)
    pw_b = (jax.random.normal(ks[4], (Co,)) * 0.1).astype(dtype)
    out = fused_block(x, dw_w, dw_b, pw_w, pw_b)
    ref = fused_dw_pw(x, dw_w, dw_b, pw_w, pw_b)
    tol = 1e-5 if dtype == jnp.float32 else 1.5e-1
    assert out.shape == (B, H, W, Co)
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("mkn", [(128, 64, 128), (256, 128, 256),
                                 (512, 256, 128), (128, 257, 384)])
def test_int8_gemm_matches_ref(mkn):
    M, K, N = mkn
    a = jax.random.normal(jax.random.PRNGKey(1), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(2), (K, N))
    aq, asc = quantize(a)
    wq, wsc = quantize(w, axis=-1)
    out = int8_gemm_pallas(aq, wq, asc, wsc.reshape(-1), tm=128, tn=128,
                           interpret=True)
    ref = int8_ref(aq, wq, asc, wsc.reshape(1, -1))
    assert float(jnp.abs(out - ref).max()) < 1e-4
    # and the whole int8 path stays close to fp32
    rel = float(jnp.abs(out - a @ w).max() / jnp.abs(a @ w).max())
    assert rel < 0.05


@pytest.mark.parametrize("S", [128, 256, 512])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(S, causal, dtype):
    B, H, D = 2, 3, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal)
    ref = attn_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


def test_flash_attention_agrees_with_model_attention():
    """The Pallas kernel and the model's chunked XLA attention agree — the
    kernel is the TPU serving path for what the dry-run lowers in XLA."""
    from repro.models.lm.attention import gqa_attention
    B, H, S, D = 2, 4, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    xla = gqa_attention(q, k, v, causal=True, impl="chunked")
    pal = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True)
    err = float(jnp.abs(xla - pal.transpose(0, 2, 1, 3)).max())
    assert err < 2e-5, err
