"""Multi-resolution QoS serving: lane scheduling policy (EDF deadline
flushes, priority preemption, starvation guard, in-flight-aware admission),
multi-resolution registration/bit-match, prepared-parameter hot-swap, and
the threaded stress suite (``pytest -m serving`` is the CI stress job)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import clear_cache, compile_network
from repro.core.graph import fire
from repro.core.hetero import init_network
from repro.serving import DynamicBatcher, HeteroServer, Request

HW8, HW12 = (8, 8), (12, 12)


def _images(n, hw, c=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return [0.5 * jax.random.normal(k, (*hw, c)) for k in ks]


def _mods():
    return [fire("f", 8, 16, 4, 8)]


# --- batcher policy: lanes, priorities, deadlines ---------------------------

def test_lanes_are_per_network_resolution_priority():
    b = DynamicBatcher(max_wait_s=0.0, max_batch=8)
    specs = [("a", HW8, 0), ("a", HW8, 1), ("a", HW12, 1), ("b", HW8, 1)]
    for i, (net, res, prio) in enumerate(specs):
        b.put(Request(net, i, res=res, priority=prio))
        b.put(Request(net, 100 + i, res=res, priority=prio))
    seen = set()
    while b.pending():
        lane, reqs, _ = b.wait_ready(timeout=0.1)
        assert all(r.lane == lane for r in reqs)   # groups never mix lanes
        assert [r.x % 100 for r in reqs] == sorted(r.x % 100 for r in reqs)
        seen.add((lane.network, lane.res, lane.priority))
    assert seen == set(specs)


def test_high_priority_preempts_at_deadline():
    """Priority <= 0 lanes carry a shorter deadline, so a later-submitted
    urgent request flushes before earlier bulk traffic (EDF)."""
    b = DynamicBatcher(max_wait_s=0.04, max_batch=8)
    b.put(Request("n", "bulk", res=HW8, priority=1))
    time.sleep(0.002)
    b.put(Request("n", "hot", res=HW8, priority=0))
    lane, reqs, by_deadline = b.wait_ready(timeout=1.0)
    assert lane.priority == 0 and by_deadline and reqs[0].x == "hot"
    lane2, reqs2, _ = b.wait_ready(timeout=1.0)
    assert lane2.priority == 1 and reqs2[0].x == "bulk"


def test_overdue_bulk_beats_full_high_bucket():
    """The starvation guard: an overdue bulk lane flushes ahead of a full
    high-priority bucket — saturating the high lane cannot starve bulk."""
    b = DynamicBatcher(max_wait_s=0.01, max_batch=4)
    b.put(Request("n", "bulk", res=HW8, priority=1))
    time.sleep(0.015)                              # bulk is now overdue
    for i in range(4):                             # fresh full high bucket
        b.put(Request("n", f"hot{i}", res=HW8, priority=0))
    lane, reqs, by_deadline = b.wait_ready(timeout=1.0)
    assert lane.priority == 1 and by_deadline and reqs[0].x == "bulk"
    lane2, reqs2, by_deadline2 = b.wait_ready(timeout=1.0)
    assert lane2.priority == 0 and not by_deadline2 and len(reqs2) == 4


def test_full_lanes_flush_highest_priority_first():
    b = DynamicBatcher(max_wait_s=10.0, max_batch=4)
    for i in range(4):
        b.put(Request("n", i, res=HW8, priority=1))
    for i in range(4):
        b.put(Request("n", i, res=HW8, priority=0))
    assert b.wait_ready(timeout=0.1)[0].priority == 0
    assert b.wait_ready(timeout=0.1)[0].priority == 1


def test_deadline_flush_gated_on_downstream_occupancy():
    """The PR 4 follow-up: with the dispatch window full, a soft-overdue
    partial bucket keeps accumulating instead of flushing — until either
    a slot frees (can_dispatch True) or the hard deadline passes."""
    b = DynamicBatcher(max_wait_s=0.01, max_batch=8)
    for i in range(2):
        b.put(Request("n", i, res=HW8))
    time.sleep(0.015)                              # soft-overdue
    # window full: the deadline flush is deferred
    assert b.wait_ready(timeout=0.005, can_dispatch=lambda: False) is None
    # a third request rides along while deferred
    b.put(Request("n", 2, res=HW8))
    # window frees: flushes immediately, with the accumulated requests
    lane, reqs, by_deadline = b.wait_ready(timeout=0.5,
                                           can_dispatch=lambda: True)
    assert by_deadline and len(reqs) == 3
    # hard deadline: flushes even while the window stays full
    b.put(Request("n", 3, res=HW8))
    time.sleep(0.05)                               # > hard_wait_mult * soft
    got = b.wait_ready(timeout=0.5, can_dispatch=lambda: False)
    assert got is not None and got[2]


def test_full_bucket_never_deferred_by_occupancy():
    b = DynamicBatcher(max_wait_s=10.0, max_batch=4)
    for i in range(4):
        b.put(Request("n", i, res=HW8))
    got = b.wait_ready(timeout=0.1, can_dispatch=lambda: False)
    assert got is not None and len(got[1]) == 4 and not got[2]


def test_emptied_lanes_are_pruned():
    """Callers can mint arbitrarily many (network, res, priority) keys
    over a long run — drained lanes must not linger in the scan set."""
    b = DynamicBatcher(max_wait_s=0.0, max_batch=4)
    for p in range(32):                      # 32 distinct priority lanes
        b.put(Request("n", p, res=HW8, priority=p))
    while b.pending():
        assert b.wait_ready(timeout=0.1) is not None
    assert b._queues == {}
    b.put(Request("n", 0, res=HW8))
    b.drain_all()
    assert b._queues == {}


# --- multi-resolution registration + serving --------------------------------

def test_multi_resolution_serving_bitmatch_and_lane_metrics():
    """Two resolutions resident under one name: interleaved mixed-priority
    requests come back bit-identical to batch-1 engine calls, and the
    snapshot reports per-lane percentiles."""
    clear_cache()                       # fresh engine: exact trace counts
    mods = _mods()
    server = HeteroServer(buckets=(1, 4), max_wait_ms=3.0)
    st = server.register("f", mods, None, input_hw=[HW8, HW12])
    assert st["traces"] == 4                  # 2 buckets x 2 resolutions
    eng = compile_network(mods, None)
    prep = eng.prepare(server._entries["f"].params)
    imgs = [(hw, x) for hw in (HW8, HW12)
            for x in _images(3, hw, seed=sum(hw))]
    with server:
        futs = [(x, server.submit("f", x, priority=i % 2))
                for i, (_hw, x) in enumerate(imgs)]
        for x, f in futs:
            out = f.result(timeout=60)
            assert bool(jnp.all(out == eng(prep, x[None])[0]))
    snap = server.metrics.snapshot()
    assert snap["completed"] == 6 and snap["failed"] == 0
    assert snap["lanes"]                      # per-lane p50/p99 reported
    for lane_stats in snap["lanes"].values():
        assert lane_stats["p99_ms"] >= lane_stats["p50_ms"] > 0
    assert server.stats()["engines"]["f"]["resolutions"] == (HW8, HW12)


def test_submit_routes_by_shape_and_rejects_unknown_resolution():
    mods = _mods()
    server = HeteroServer(buckets=(1,))
    server.register("f", mods, None, input_hw=[HW8, HW12])
    eng = compile_network(mods, None)
    prep = eng.prepare(server._entries["f"].params)
    # (1, H, W, C) squeezes into the matching lane
    with pytest.raises(ValueError, match="expected an image"):
        server.submit("f", jnp.zeros((10, 10, 16)))
    with server:
        out = server.submit("f", jnp.zeros((1, 12, 12, 16))).result(60)
    assert bool(jnp.all(out == eng(prep, jnp.zeros((1, 12, 12, 16)))[0]))


def test_register_rejects_malformed_resolutions():
    with pytest.raises(ValueError, match="input_hw"):
        HeteroServer().register("f", _mods(), None, input_hw=[(8, 8, 3)])
    with pytest.raises(ValueError, match="duplicate"):
        HeteroServer().register("f", _mods(), None, input_hw=[HW8, HW8])


# --- prepared-parameter hot-swap --------------------------------------------

def test_swap_params_switches_generation_without_drain():
    mods = _mods()
    pa = init_network(mods, jax.random.PRNGKey(0))
    pb = init_network(mods, jax.random.PRNGKey(9))
    server = HeteroServer(buckets=(1, 4), max_wait_ms=2.0)
    server.register("f", mods, None, pa, input_hw=HW8)
    eng = compile_network(mods, None)
    prep_a, prep_b = eng.prepare(pa), eng.prepare(pb)
    imgs = _images(6, HW8, seed=3)
    with server:
        before = [server.submit("f", x).result(60) for x in imgs[:3]]
        gen0 = server.stats()["engines"]["f"]["param_generation"]
        info = server.swap_params("f", pb)
        after = [server.submit("f", x).result(60) for x in imgs[3:]]
    assert info["previous_generation"] == gen0
    assert info["generation"] > gen0
    assert server.stats()["engines"]["f"]["param_generation"] \
        == info["generation"]
    for x, out in zip(imgs[:3], before):
        assert bool(jnp.all(out == eng(prep_a, x[None])[0]))
    for x, out in zip(imgs[3:], after):
        assert bool(jnp.all(out == eng(prep_b, x[None])[0]))
    # the swap is observable: the two generations really differ
    assert not bool(jnp.all(before[0] == eng(prep_b, imgs[0][None])[0]))
    snap = server.metrics.snapshot()
    assert snap["swaps"] == 1 and snap["failed"] == 0


def test_swap_params_unknown_network_raises():
    with pytest.raises(KeyError, match="unregistered"):
        HeteroServer().swap_params("nope", {})


def test_param_generation_monotonic_across_clear_cache():
    """A clear_cache recompile re-prepares on a fresh engine — the
    generation stamp must keep counting up, never rewind or collide."""
    mods = _mods()
    server = HeteroServer(buckets=(1,), max_wait_ms=2.0)
    server.register("f", mods, None, input_hw=HW8)
    g0 = server.stats()["engines"]["f"]["param_generation"]
    server.swap_params("f", init_network(mods, jax.random.PRNGKey(1)))
    g1 = server.stats()["engines"]["f"]["param_generation"]
    assert g1 > g0
    clear_cache()
    with server:                             # first flush forces a refresh
        server.submit("f", np.zeros((8, 8, 16),
                                    np.float32)).result(timeout=60)
    assert server.metrics.snapshot()["recompiles"] == 1
    assert server.stats()["engines"]["f"]["param_generation"] > g1


def test_refresh_cannot_revert_completed_swap():
    """The refresh x swap race: a stale-engine recompile that STARTED
    before a swap must not finish after it and silently restore the
    pre-swap weights.  The recompile is stalled at a barrier, the swap is
    issued mid-recompile, and the final served generation must be the
    swapped one."""
    mods = _mods()
    pa = init_network(mods, jax.random.PRNGKey(0))
    pb = init_network(mods, jax.random.PRNGKey(9))
    server = HeteroServer(buckets=(1,), max_wait_ms=2.0)
    server.register("f", mods, None, pa, input_hw=HW8)
    eng = compile_network(mods, None)
    prep_b = eng.prepare(pb)
    entry = server._entries["f"]
    started, release = threading.Event(), threading.Event()
    real_compile = entry._compile

    def stalled_compile(*args, **kwargs):
        started.set()
        assert release.wait(timeout=30)
        return real_compile(*args, **kwargs)

    entry._compile = stalled_compile
    refresher = threading.Thread(target=entry.refresh, daemon=True)
    refresher.start()
    assert started.wait(timeout=30)          # recompile is mid-flight
    swapped = []
    swapper = threading.Thread(
        target=lambda: swapped.append(server.swap_params("f", pb)),
        daemon=True)
    swapper.start()                          # swap issued DURING refresh
    time.sleep(0.05)
    release.set()
    refresher.join(timeout=60)
    swapper.join(timeout=60)
    assert swapped and not refresher.is_alive()
    entry._compile = real_compile
    x = _images(1, HW8, seed=4)[0]
    with server:
        out = server.submit("f", x).result(timeout=60)
    # the swap must win: served rows come from pb, not the refreshed pa
    assert bool(jnp.all(out == eng(prep_b, x[None])[0]))
    assert server.stats()["engines"]["f"]["param_generation"] \
        == swapped[0]["generation"]


# --- stress suite (pytest -m serving: the CI stress job) --------------------

@pytest.mark.serving
def test_bulk_lane_bounded_under_high_priority_saturation():
    """Deadline-flush regression guard: with the high-priority lane kept
    saturated by a feeder thread, a lone bulk request must still flush
    within its deadline bound instead of starving behind full buckets."""
    mods = _mods()
    server = HeteroServer(buckets=(1, 4), max_wait_ms=2.0)
    server.register("f", mods, None, input_hw=HW8)
    eng = compile_network(mods, None)
    prep = eng.prepare(server._entries["f"].params)
    hot = np.asarray(_images(1, HW8, seed=7)[0])
    bulk = _images(1, HW8, seed=8)[0]
    stop = threading.Event()
    hi_futs = []

    def feeder():
        while not stop.is_set():
            if server._batcher.pending() < 16:
                hi_futs.append(server.submit("f", hot, priority=0))
            else:
                time.sleep(0.0002)

    with server:
        t = threading.Thread(target=feeder, daemon=True)
        t.start()
        time.sleep(0.05)                     # saturation established
        t0 = time.monotonic()
        out = server.submit("f", bulk, priority=1).result(timeout=30)
        bulk_latency = time.monotonic() - t0
        stop.set()
        t.join()
        for f in hi_futs:
            f.result(timeout=60)
    # deadline is 2 ms; allow generous CI-noise headroom, but far below
    # the seconds it would take to drain the whole saturated high lane
    assert bulk_latency < 1.0, f"bulk request starved: {bulk_latency:.3f}s"
    assert bool(jnp.all(out == eng(prep, bulk[None])[0]))
    snap = server.metrics.snapshot()
    assert snap["failed"] == 0
    assert snap["completed"] == len(hi_futs) + 1


@pytest.mark.serving
def test_threaded_stress_submit_swap_clear_cache():
    """N submitter threads x clear_cache x swap_params racing: every
    future resolves, nothing fails, and every served row bit-matches the
    batch-1 oracle of exactly one parameter generation."""
    mods = _mods()
    pa = init_network(mods, jax.random.PRNGKey(0))
    pb = init_network(mods, jax.random.PRNGKey(9))
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0, in_flight=2)
    server.register("f", mods, None, pa, input_hw=[HW8, HW12])
    eng = compile_network(mods, None)
    preps = [eng.prepare(pa), eng.prepare(pb)]
    n_threads, n_per = 4, 25
    pools = {hw: [np.asarray(x) for x in _images(8, hw, seed=sum(hw))]
             for hw in (HW8, HW12)}
    results: list = []                       # list.append is thread-safe

    def submitter(seed):
        rng = np.random.RandomState(seed)
        for i in range(n_per):
            hw = HW8 if rng.rand() < 0.5 else HW12
            x = pools[hw][rng.randint(len(pools[hw]))]
            f = server.submit("f", x, priority=int(rng.randint(2)))
            results.append((x, f))
            time.sleep(0.002 * rng.rand())

    with server:
        threads = [threading.Thread(target=submitter, args=(s,))
                   for s in range(n_threads)]
        for t in threads:
            t.start()
        flip = 0
        while any(t.is_alive() for t in threads):
            server.swap_params("f", pb if flip % 2 == 0 else pa)
            clear_cache()
            flip += 1
            time.sleep(0.005)
        for t in threads:
            t.join()
        # force one post-clear flush so the recompile path provably ran
        clear_cache()
        final = server.submit("f", pools[HW8][0]).result(timeout=60)
        rows = [(x, f.result(timeout=120)) for x, f in results]
    refs = {}                                # cache batch-1 oracle rows

    def ref_rows(x):
        key = x.tobytes()
        if key not in refs:
            refs[key] = [np.asarray(eng(p, x[None])[0]) for p in preps]
        return refs[key]

    for x, out in rows:
        assert any(np.array_equal(out, r) for r in ref_rows(x)), \
            "served row matches neither parameter generation's oracle"
    current = preps[0] if flip % 2 == 0 else preps[1]  # last swap applied
    assert np.array_equal(final, np.asarray(eng(current,
                                                pools[HW8][0][None])[0]))
    snap = server.metrics.snapshot()
    assert snap["failed"] == 0
    assert snap["completed"] == n_threads * n_per + 1
    assert snap["swaps"] == flip
    assert snap["recompiles"] >= 1           # clear_cache recovery ran live


@pytest.mark.serving
def test_stress_shutdown_mid_traffic_resolves_every_future():
    """Shutdown racing live submissions: whatever was admitted must
    resolve (flushed by the shutdown backlog drain), never hang."""
    mods = _mods()
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0, in_flight=2)
    server.register("f", mods, None, input_hw=HW8)
    eng = compile_network(mods, None)
    prep = eng.prepare(server._entries["f"].params)
    imgs = [np.asarray(x) for x in _images(12, HW8, seed=2)]
    server.start()
    futs = [server.submit("f", x, priority=i % 2)
            for i, x in enumerate(imgs)]
    server.shutdown()
    for x, f in zip(imgs, futs):
        assert bool(jnp.all(f.result(timeout=60) == eng(prep, x[None])[0]))
