"""Process-level serving front door: wire-protocol units (unmarked, run
in tier-1) and e2e HTTP tests (``frontend`` marker) — served rows
bit-match the batch-1 oracle THROUGH the socket, typed rejections arrive
as stable wire codes (429 + Retry-After / 504 / 503) instead of
tracebacks, a killed worker process fails over without changing answers,
and SIGTERM drains a worker to exit 0 with nothing left hanging.

The heavy tests all serve one tiny fire module (seconds to compile,
cached across tests); worker processes are spawned from the same spec,
so their params — and therefore their rows — are bit-identical by
construction (``init_network`` under the spec's seed).
"""
import json
import signal
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core.executor import compile_network
from repro.core.graph import fire
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.frontend import (FrontDoor, LocalBackend, ProcWorker, Router,
                            ServerThread, TokenBucket, build_server, wire)
from repro.runtime.faults import FaultPlan, FaultRule, inject
from repro.serving.errors import (DeadlineExceeded, Overloaded, ServerClosed,
                                  ServingError, Shutdown)

HW = (8, 8)
C = 16
SPEC = {"networks": [{"kind": "fire", "name": "tiny", "hw": list(HW),
                      "c_in": C, "squeeze": 4, "expand": 8, "seed": 0}],
        "server": {"max_wait_ms": 1.0}}


def _images(n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return [np.asarray(0.5 * jax.random.normal(k, (*HW, C)),
                       dtype=np.float32) for k in ks]


def _post(port, path, body=None, timeout=60):
    """(status, parsed-json, headers) via a blocking client — the door
    runs on its own loop thread, so plain urllib is the honest client."""
    data = b"" if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", data=data, method="POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.load(r), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.load(e), dict(e.headers)


def _get(port, path, timeout=30):
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=timeout) as r:
            return r.status, json.load(r)
    except urllib.error.HTTPError as e:
        return e.code, json.load(e)


# --- wire-protocol units (tier-1: no server, no HTTP) ----------------------

def test_array_roundtrip_is_bit_exact():
    for dtype in ("float32", "int32", "uint8"):
        x = (np.arange(2 * 3 * 4) % 7).reshape(2, 3, 4).astype(dtype)
        y = wire.decode_array(wire.encode_array(x))
        assert y.dtype == x.dtype and np.array_equal(x, y)


def test_error_codes_are_a_stable_contract():
    """The wire fields routers key on: frozen, not derived."""
    assert (Overloaded.code, Overloaded.retryable,
            Overloaded.wire_status) == ("overloaded", True, 429)
    assert (DeadlineExceeded.code, DeadlineExceeded.retryable,
            DeadlineExceeded.wire_status) == ("deadline_exceeded", False, 504)
    assert (ServerClosed.code, ServerClosed.retryable,
            ServerClosed.wire_status) == ("server_closed", True, 503)
    assert (Shutdown.code, Shutdown.retryable,
            Shutdown.wire_status) == ("shutdown", True, 503)
    assert issubclass(Overloaded, ServingError) and not ServingError.retryable


def test_error_reply_maps_typed_errors():
    status, body, headers = wire.error_reply(
        Overloaded("lane full", label="tiny@8x8/p1"))
    assert status == 429 and body["retryable"] and "Retry-After" in headers
    assert body["lane"] == "tiny@8x8/p1"
    status, body, _h = wire.error_reply(DeadlineExceeded("late"))
    assert status == 504 and not body["retryable"]
    for exc in (Shutdown("bye"), ServerClosed("closed")):
        status, body, _h = wire.error_reply(exc)
        assert status == 503 and body["retryable"]
    status, body, _h = wire.error_reply(KeyError("nope"))
    assert status == 400 and not body["retryable"]
    # opaque failures: class name only, never a traceback/message dump
    status, body, _h = wire.error_reply(RuntimeError("secret internals"))
    assert status == 500 and body["retryable"]
    assert "secret" not in json.dumps(body)


def test_is_retryable_prefers_body_over_status():
    assert wire.is_retryable(429, {"retryable": True})
    assert not wire.is_retryable(429, {"retryable": False})
    assert wire.is_retryable(503, None) and not wire.is_retryable(504, None)


def test_token_bucket_burst_and_refill():
    tb = TokenBucket(rate=50.0, burst=2)
    assert tb.admit() and tb.admit() and not tb.admit()
    assert tb.retry_after_s() > 0
    time.sleep(0.05)                       # 50/s: ~2.5 tokens back
    assert tb.admit()
    assert TokenBucket(rate=None).admit()  # disabled gate never sheds


# --- e2e over HTTP ----------------------------------------------------------

def _door(**door_kw):
    server = build_server(SPEC)
    handle = ServerThread(FrontDoor(LocalBackend(server, **door_kw)))
    return server, handle.start()


@pytest.fixture(scope="module")
def oracle():
    mods = [fire("tiny", HW[0], C, 4, 8)]
    plans = partition_network(mods, paper_faithful=True)
    eng = compile_network(mods, plans)
    prepared = eng.prepare(init_network(mods, jax.random.PRNGKey(0)))
    return lambda x: np.asarray(eng(prepared, x[None])[0])


@pytest.mark.frontend
def test_http_rows_bitmatch_batch1_oracle(oracle):
    _server, h = _door()
    try:
        imgs = _images(6)
        outs = [_post(h.port, "/v1/infer", wire.infer_payload("tiny", x))
                for x in imgs]
        for x, (status, body, _hdr) in zip(imgs, outs):
            assert status == 200, body
            assert np.array_equal(wire.decode_array(body["result"]),
                                  oracle(x)), \
                "row served over HTTP differs from batch-1 oracle"
        status, hz = _get(h.port, "/healthz")
        assert status == 200 and hz["ok"] and hz["uptime_s"] > 0
        assert hz["completed"] >= 6
    finally:
        h.stop()


@pytest.mark.frontend
def test_deadline_and_bad_request_wire_codes():
    _server, h = _door()
    try:
        # deadline_ms=0: already expired when its batch flushes -> 504,
        # marked NOT retryable (the row may still have been computed)
        status, body, _hdr = _post(
            h.port, "/v1/infer",
            wire.infer_payload("tiny", _images(1)[0], deadline_ms=0.0))
        assert status == 504 and body["error"] == "deadline_exceeded"
        assert body["retryable"] is False
        # unregistered network / malformed body: 400, never retried
        status, body, _hdr = _post(
            h.port, "/v1/infer", wire.infer_payload("nope", _images(1)[0]))
        assert status == 400 and body["retryable"] is False
        status, body, _hdr = _post(h.port, "/v1/infer", {"network": "tiny"})
        assert status == 400
    finally:
        h.stop()


@pytest.mark.frontend
def test_token_bucket_sheds_429_before_submit():
    server, h = _door(rate=0.001, burst=1)
    try:
        first = _post(h.port, "/v1/infer",
                      wire.infer_payload("tiny", _images(1)[0]))
        assert first[0] == 200
        status, body, headers = _post(
            h.port, "/v1/infer", wire.infer_payload("tiny", _images(1)[0]))
        assert status == 429 and body["error"] == "overloaded"
        assert body["gate"] == "rate" and body["retryable"]
        assert float(headers["Retry-After"]) > 0
        # the shed request never reached the server
        assert server.metrics.snapshot()["completed"] == 1
    finally:
        h.stop()


@pytest.mark.frontend
def test_http_fault_injection_is_typed_on_the_wire(oracle):
    _server, h = _door()
    try:
        plan = FaultPlan([FaultRule(op="http", times=1)])
        with inject(plan):
            status, body, _hdr = _post(
                h.port, "/v1/infer", wire.infer_payload("tiny", _images(1)[0]))
        assert status == 500 and body["error"] == "internal"
        assert body["retryable"] and plan.rules[0].fired == 1
        assert "Traceback" not in json.dumps(body)
        x = _images(2)[1]
        status, body, _hdr = _post(h.port, "/v1/infer",
                                   wire.infer_payload("tiny", x))
        assert status == 200
        assert np.array_equal(wire.decode_array(body["result"]), oracle(x))
    finally:
        h.stop()


@pytest.mark.frontend
def test_drain_fences_resolves_and_is_idempotent():
    server, h = _door()
    try:
        assert _post(h.port, "/v1/infer",
                     wire.infer_payload("tiny", _images(1)[0]))[0] == 200
        status, body, _hdr = _post(h.port, "/drain")
        assert status == 200 and body["drained"]
        assert body["pending_requests"] == 0, \
            "drain left admitted futures unresolved"
        again = _post(h.port, "/drain")      # idempotent, still bounded
        assert again[0] == 200 and again[1]["drained"]
        status, body, _hdr = _post(h.port, "/v1/infer",
                                   wire.infer_payload("tiny", _images(1)[0]))
        assert status == 503 and body["error"] == "shutdown"
        assert _get(h.port, "/healthz")[0] == 503
        assert server.state == "closed"
    finally:
        h.stop(drain=False)


# --- multi-process: failover, crash-resume, SIGTERM -------------------------

@pytest.mark.frontend
def test_router_survives_worker_kill_with_bitmatched_rows(oracle):
    """Kill one of two worker processes mid-fleet: every request keeps
    answering 200 with the SAME row (shared-spec determinism), the dead
    worker is ejected, and /healthz stays ok."""
    workers = [ProcWorker("w1", SPEC), ProcWorker("w2", SPEC)]
    router = Router(workers, auto_restart=False, probe_interval_s=0.05,
                    eject_after=1)
    h = ServerThread(FrontDoor(router), also_start=(router,)).start()
    try:
        x = _images(1, seed=3)[0]
        payload = wire.infer_payload("tiny", x)
        ref = oracle(x)
        assert np.array_equal(
            wire.decode_array(_post(h.port, "/v1/infer", payload)[1]["result"]),
            ref)
        workers[0].terminate()               # hard kill, no goodbye
        for _ in range(4):
            status, body, _hdr = _post(h.port, "/v1/infer", payload)
            assert status == 200, body
            assert np.array_equal(wire.decode_array(body["result"]), ref), \
                "failover changed the answer"
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            snap = _get(h.port, "/metrics")[1]
            if snap["workers"]["w1"]["state"] == "ejected":
                break
            time.sleep(0.05)
        assert snap["workers"]["w1"]["state"] == "ejected"
        assert snap["workers"]["w2"]["state"] == "healthy"
        assert _get(h.port, "/healthz")[0] == 200
    finally:
        h.stop(drain=False)
        for w in workers:
            w.terminate()


@pytest.mark.frontend
def test_worker_sigterm_drains_to_clean_exit():
    w = ProcWorker("w", SPEC)
    import asyncio
    asyncio.run(w.start())
    try:
        status, body, _hdr = _post(
            w.port, "/v1/infer", wire.infer_payload("tiny", _images(1)[0]))
        assert status == 200
        w.proc.send_signal(signal.SIGTERM)
        assert w.proc.wait(30.0) == 0, "SIGTERM drain did not exit clean"
        with pytest.raises((ConnectionError, OSError)):
            urllib.request.urlopen(
                f"http://127.0.0.1:{w.port}/healthz", timeout=2)
    finally:
        w.terminate()
