"""Process-level serving front door: wire-protocol units (unmarked, run
in tier-1) and e2e HTTP tests (``frontend`` marker) — served rows
bit-match the batch-1 oracle THROUGH the socket (in BOTH wire framings,
over keep-alive sockets), typed rejections arrive as stable wire codes
(429 + Retry-After / 504 / 503) instead of tracebacks, weighted
admission sheds low-priority lanes first, a killed worker process fails
over without changing answers, the router auto-scales the fleet from
the queue-depth gauge, and SIGTERM drains a worker to exit 0 with
nothing left hanging.

The heavy tests all serve one tiny fire module (seconds to compile,
cached across tests); worker processes are spawned from the same spec,
so their params — and therefore their rows — are bit-identical by
construction (``init_network`` under the spec's seed).
"""
import asyncio
import http.client
import json
import signal
import socket
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core.executor import compile_network
from repro.core.graph import fire
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.frontend import (FrontDoor, LocalBackend, ProcWorker, Router,
                            ServerThread, TokenBucket,
                            WeightedTokenBuckets, build_server, wire)
from repro.runtime.faults import FaultPlan, FaultRule, inject
from repro.serving.errors import (DeadlineExceeded, Overloaded, ServerClosed,
                                  ServingError, Shutdown)

HW = (8, 8)
C = 16
SPEC = {"networks": [{"kind": "fire", "name": "tiny", "hw": list(HW),
                      "c_in": C, "squeeze": 4, "expand": 8, "seed": 0}],
        "server": {"max_wait_ms": 1.0}}


def _images(n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return [np.asarray(0.5 * jax.random.normal(k, (*HW, C)),
                       dtype=np.float32) for k in ks]


def _post(port, path, body=None, timeout=60):
    """(status, parsed-json, headers) via a blocking client — the door
    runs on its own loop thread, so plain urllib is the honest client."""
    data = b"" if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", data=data, method="POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.load(r), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.load(e), dict(e.headers)


def _get(port, path, timeout=30):
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=timeout) as r:
            return r.status, json.load(r)
    except urllib.error.HTTPError as e:
        return e.code, json.load(e)


# --- wire-protocol units (tier-1: no server, no HTTP) ----------------------

def test_array_roundtrip_is_bit_exact():
    for dtype in ("float32", "int32", "uint8"):
        x = (np.arange(2 * 3 * 4) % 7).reshape(2, 3, 4).astype(dtype)
        y = wire.decode_array(wire.encode_array(x))
        assert y.dtype == x.dtype and np.array_equal(x, y)


def test_error_codes_are_a_stable_contract():
    """The wire fields routers key on: frozen, not derived."""
    assert (Overloaded.code, Overloaded.retryable,
            Overloaded.wire_status) == ("overloaded", True, 429)
    assert (DeadlineExceeded.code, DeadlineExceeded.retryable,
            DeadlineExceeded.wire_status) == ("deadline_exceeded", False, 504)
    assert (ServerClosed.code, ServerClosed.retryable,
            ServerClosed.wire_status) == ("server_closed", True, 503)
    assert (Shutdown.code, Shutdown.retryable,
            Shutdown.wire_status) == ("shutdown", True, 503)
    assert issubclass(Overloaded, ServingError) and not ServingError.retryable


def test_error_reply_maps_typed_errors():
    status, body, headers = wire.error_reply(
        Overloaded("lane full", label="tiny@8x8/p1"))
    assert status == 429 and body["retryable"] and "Retry-After" in headers
    assert body["lane"] == "tiny@8x8/p1"
    status, body, _h = wire.error_reply(DeadlineExceeded("late"))
    assert status == 504 and not body["retryable"]
    for exc in (Shutdown("bye"), ServerClosed("closed")):
        status, body, _h = wire.error_reply(exc)
        assert status == 503 and body["retryable"]
    status, body, _h = wire.error_reply(KeyError("nope"))
    assert status == 400 and not body["retryable"]
    # opaque failures: class name only, never a traceback/message dump
    status, body, _h = wire.error_reply(RuntimeError("secret internals"))
    assert status == 500 and body["retryable"]
    assert "secret" not in json.dumps(body)


def test_is_retryable_prefers_body_over_status():
    assert wire.is_retryable(429, {"retryable": True})
    assert not wire.is_retryable(429, {"retryable": False})
    assert wire.is_retryable(503, None) and not wire.is_retryable(504, None)


def test_token_bucket_burst_and_refill():
    tb = TokenBucket(rate=50.0, burst=2)
    assert tb.admit() and tb.admit() and not tb.admit()
    assert tb.retry_after_s() > 0
    time.sleep(0.05)                       # 50/s: ~2.5 tokens back
    assert tb.admit()
    assert TokenBucket(rate=None).admit()  # disabled gate never sheds


def test_retry_after_refills_from_now_not_from_last_take():
    """The PR-10 bugfix: the bucket's time base only advanced inside
    ``admit()``, so a probe WITHOUT traffic reported a stale (too-long,
    or after manual token edits even zero) wait.  ``retry_after_s`` must
    recompute the refill at call time."""
    tb = TokenBucket(rate=10.0, burst=1)
    assert tb.admit() and not tb.admit()   # bucket empty at t0
    w0 = tb.retry_after_s()
    assert 0 < w0 <= 0.1 + 1e-3            # one token at 10/s: <= 100ms
    time.sleep(0.05)
    w1 = tb.retry_after_s()                # NO admit() in between
    assert w1 < w0, "wait must shrink while the bucket refills"
    assert w1 <= 0.06
    time.sleep(0.08)                       # fully refilled now
    assert tb.retry_after_s() <= 0.001 + 1e-9
    assert tb.admit()
    # and the reported bound is honest: waiting it out buys admission
    tb2 = TokenBucket(rate=50.0, burst=1)
    assert tb2.admit() and not tb2.admit()
    time.sleep(tb2.retry_after_s() + 0.005)
    assert tb2.admit()


def test_weighted_buckets_shed_low_priority_first():
    wb = WeightedTokenBuckets(rate=0.001, burst=4, weights={0: 3, 1: 1})
    # class 1 gets 1/4 of the burst (1 token), class 0 gets 3
    assert wb.admit(priority=1) and not wb.admit(priority=1)
    for _ in range(3):
        assert wb.admit(priority=0), "critical lane shed too early"
    assert not wb.admit(priority=0)
    assert wb.retry_after_s(1) > wb.retry_after_s(0) > 0  # weighted refill
    # unknown classes ride the LOWEST-weight bucket, never the critical one
    assert not wb.admit(priority=7)
    assert WeightedTokenBuckets(rate=None).admit(0)       # disabled gate
    with pytest.raises(ValueError):
        WeightedTokenBuckets(rate=1.0, weights={0: -1.0})


def test_infer_request_builds_both_framings():
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    body, headers = wire.infer_request("tiny", x, priority=0,
                                       deadline_ms=25.0)
    payload = json.loads(body)
    assert headers["X-Priority"] == "0"    # admission class rides pre-body
    assert payload["priority"] == 0 and payload["deadline_ms"] == 25.0
    assert np.array_equal(wire.decode_array(payload), x)
    body, headers = wire.infer_request("tiny", x, priority=0, binary=True,
                                       accept=wire.TENSOR_CONTENT_TYPE)
    assert headers["Content-Type"] == wire.TENSOR_CONTENT_TYPE
    assert headers["X-Network"] == "tiny"
    assert np.array_equal(wire.decode_tensor(body), x)
    meta = wire.infer_meta_from_headers(
        {k.lower(): v for k, v in headers.items()})
    assert meta == {"network": "tiny", "priority": 0}


def test_router_autoscales_from_queue_depth():
    """Tier-1 unit on stub workers: mean depth >= scale_up_depth grows
    the fleet to the ceiling; an idle fleet shrinks back to the floor
    through the retiring/drain path."""

    class _Stub:
        def __init__(self, name):
            self.name = name
            self.outstanding = 0
            self.depth = 0
            self.reported = 0
            self.state = "healthy"
            self.fails = self.oks = self.restarts = 0
            self.restarting = False
            self.drained = False

        def alive(self):
            return True

        async def healthz(self):
            return 200, {"ok": True, "pending_requests": self.reported,
                         "queue_total": 0}, {}

        async def drain(self, budget_s):
            self.drained = True

        def terminate(self):
            pass

    async def run():
        made = []

        def factory(name):
            w = _Stub(name)
            made.append(w)
            return w

        seed = _Stub("w0")
        r = Router([seed], worker_factory=factory, scale_min=1,
                   scale_max=3, scale_up_depth=5.0, scale_down_depth=0.5,
                   scale_cooldown_s=0.0, probe_interval_s=0.005)
        assert r.autoscale_enabled()
        await r.start()
        seed.reported = 50                    # saturated: scale up
        deadline = time.monotonic() + 5.0
        while len(r.workers) < 3 and time.monotonic() < deadline:
            for w in r.workers:
                w.reported = 50
            await asyncio.sleep(0.01)
        assert len(r.workers) == 3, "never reached the ceiling"
        assert r.counters["scale_ups"] == 2
        await asyncio.sleep(0.05)
        assert len(r.workers) == 3, "scaled past the ceiling"
        for w in r.workers:                   # idle: scale back down
            w.reported = 0
        deadline = time.monotonic() + 5.0
        while len(r.workers) > 1 and time.monotonic() < deadline:
            await asyncio.sleep(0.01)
        assert len(r.workers) == 1, "never shrank back to the floor"
        assert r.counters["scale_downs"] == 2
        await asyncio.sleep(0.05)
        assert len(r.workers) == 1, "shrank below the floor"
        assert all(w.drained for w in made if w not in r.workers), \
            "a retired worker was killed without draining"
        await r.aclose()

    asyncio.run(run())


# --- e2e over HTTP ----------------------------------------------------------

def _door(idle_timeout_s=None, conn_inflight=None, **door_kw):
    server = build_server(SPEC)
    fd_kw = {}
    if idle_timeout_s is not None:
        fd_kw["idle_timeout_s"] = idle_timeout_s
    if conn_inflight is not None:
        fd_kw["conn_inflight"] = conn_inflight
    handle = ServerThread(FrontDoor(LocalBackend(server, **door_kw),
                                    **fd_kw))
    return server, handle.start()


@pytest.fixture(scope="module")
def oracle():
    mods = [fire("tiny", HW[0], C, 4, 8)]
    plans = partition_network(mods, paper_faithful=True)
    eng = compile_network(mods, plans)
    prepared = eng.prepare(init_network(mods, jax.random.PRNGKey(0)))
    return lambda x: np.asarray(eng(prepared, x[None])[0])


@pytest.mark.frontend
def test_http_rows_bitmatch_batch1_oracle(oracle):
    _server, h = _door()
    try:
        imgs = _images(6)
        outs = [_post(h.port, "/v1/infer", wire.infer_payload("tiny", x))
                for x in imgs]
        for x, (status, body, _hdr) in zip(imgs, outs):
            assert status == 200, body
            assert np.array_equal(wire.decode_array(body["result"]),
                                  oracle(x)), \
                "row served over HTTP differs from batch-1 oracle"
        status, hz = _get(h.port, "/healthz")
        assert status == 200 and hz["ok"] and hz["uptime_s"] > 0
        assert hz["completed"] >= 6
    finally:
        h.stop()


@pytest.mark.frontend
def test_deadline_and_bad_request_wire_codes():
    _server, h = _door()
    try:
        # deadline_ms=0: already expired when its batch flushes -> 504,
        # marked NOT retryable (the row may still have been computed)
        status, body, _hdr = _post(
            h.port, "/v1/infer",
            wire.infer_payload("tiny", _images(1)[0], deadline_ms=0.0))
        assert status == 504 and body["error"] == "deadline_exceeded"
        assert body["retryable"] is False
        # unregistered network / malformed body: 400, never retried
        status, body, _hdr = _post(
            h.port, "/v1/infer", wire.infer_payload("nope", _images(1)[0]))
        assert status == 400 and body["retryable"] is False
        status, body, _hdr = _post(h.port, "/v1/infer", {"network": "tiny"})
        assert status == 400
    finally:
        h.stop()


@pytest.mark.frontend
def test_token_bucket_sheds_429_before_submit():
    server, h = _door(rate=0.001, burst=1)
    try:
        first = _post(h.port, "/v1/infer",
                      wire.infer_payload("tiny", _images(1)[0]))
        assert first[0] == 200
        status, body, headers = _post(
            h.port, "/v1/infer", wire.infer_payload("tiny", _images(1)[0]))
        assert status == 429 and body["error"] == "overloaded"
        assert body["gate"] == "rate" and body["retryable"]
        assert float(headers["Retry-After"]) > 0
        # the shed request never reached the server
        assert server.metrics.snapshot()["completed"] == 1
    finally:
        h.stop()


@pytest.mark.frontend
def test_http_fault_injection_is_typed_on_the_wire(oracle):
    _server, h = _door()
    try:
        plan = FaultPlan([FaultRule(op="http", times=1)])
        with inject(plan):
            status, body, _hdr = _post(
                h.port, "/v1/infer", wire.infer_payload("tiny", _images(1)[0]))
        assert status == 500 and body["error"] == "internal"
        assert body["retryable"] and plan.rules[0].fired == 1
        assert "Traceback" not in json.dumps(body)
        x = _images(2)[1]
        status, body, _hdr = _post(h.port, "/v1/infer",
                                   wire.infer_payload("tiny", x))
        assert status == 200
        assert np.array_equal(wire.decode_array(body["result"]), oracle(x))
    finally:
        h.stop()


@pytest.mark.frontend
def test_drain_fences_resolves_and_is_idempotent():
    server, h = _door()
    try:
        assert _post(h.port, "/v1/infer",
                     wire.infer_payload("tiny", _images(1)[0]))[0] == 200
        status, body, _hdr = _post(h.port, "/drain")
        assert status == 200 and body["drained"]
        assert body["pending_requests"] == 0, \
            "drain left admitted futures unresolved"
        again = _post(h.port, "/drain")      # idempotent, still bounded
        assert again[0] == 200 and again[1]["drained"]
        status, body, _hdr = _post(h.port, "/v1/infer",
                                   wire.infer_payload("tiny", _images(1)[0]))
        assert status == 503 and body["error"] == "shutdown"
        assert _get(h.port, "/healthz")[0] == 503
        assert server.state == "closed"
    finally:
        h.stop(drain=False)


# --- protocol v2 e2e: keep-alive, binary framing, weighted admission --------

@pytest.mark.frontend
def test_keepalive_socket_serves_many_bitmatched_rows(oracle):
    """One persistent connection, many requests: every row bit-matches
    the oracle, the door saw ONE connection, and responses carry
    ``Connection: keep-alive``."""
    _server, h = _door()
    try:
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=60)
        imgs = _images(5, seed=11)
        for x in imgs:
            body, headers = wire.infer_request("tiny", x)
            conn.request("POST", "/v1/infer", body=body, headers=headers)
            r = conn.getresponse()
            assert r.status == 200
            assert r.getheader("Connection") == "keep-alive"
            row = wire.decode_array(json.loads(r.read())["result"])
            assert np.array_equal(row, oracle(x))
        assert h.door.connections == 1
        assert h.door.keepalive_reuses == len(imgs) - 1
        conn.close()
    finally:
        h.stop()


@pytest.mark.frontend
def test_binary_framing_bitmatches_base64_framing(oracle):
    """The same image served through both framings — and a mixed
    round-trip (binary request, JSON reply and vice versa) — produces
    bit-identical rows: the encodings are interchangeable codecs, not
    two numerics paths."""
    _server, h = _door()
    try:
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=60)
        for x in _images(3, seed=7):
            rows = {}
            for label, binary, accept in (
                    ("b64/b64", False, None),
                    ("bin/bin", True, wire.TENSOR_CONTENT_TYPE),
                    ("bin/b64", True, None),
                    ("b64/bin", False, wire.TENSOR_CONTENT_TYPE)):
                body, headers = wire.infer_request("tiny", x, binary=binary,
                                                   accept=accept)
                conn.request("POST", "/v1/infer", body=body,
                             headers=headers)
                r = conn.getresponse()
                raw = r.read()
                assert r.status == 200, raw[:200]
                ctype = r.getheader("Content-Type", "")
                if accept:
                    assert ctype.startswith(wire.TENSOR_CONTENT_TYPE)
                    rows[label] = wire.decode_tensor(raw)
                else:
                    rows[label] = wire.decode_array(
                        json.loads(raw)["result"])
            ref = oracle(x)
            for label, row in rows.items():
                assert row.dtype == ref.dtype, label
                assert np.array_equal(row, ref), \
                    f"framing {label} changed the served row"
        conn.close()
    finally:
        h.stop()


@pytest.mark.frontend
def test_weighted_admission_sheds_low_priority_lane_first():
    """Exhaust the door's buckets: the class-1 lane sheds while the
    deadline-critical class-0 lane (weight 3) still admits."""
    server, h = _door(rate=0.001, burst=4, weights={0: 3, 1: 1})
    try:
        def infer(prio):
            x = _images(1)[0]
            body, headers = wire.infer_request("tiny", x, priority=prio)
            conn = http.client.HTTPConnection("127.0.0.1", h.port,
                                              timeout=60)
            conn.request("POST", "/v1/infer", body=body, headers=headers)
            r = conn.getresponse()
            out = r.status, json.loads(r.read()), dict(r.headers)
            conn.close()
            return out

        assert infer(1)[0] == 200              # class-1 burst: 1 token
        status, body, headers = infer(1)
        assert status == 429 and body["error"] == "overloaded"
        assert float(headers["Retry-After"]) > 0
        for _ in range(3):                     # class-0 burst: 3 tokens
            assert infer(0)[0] == 200, \
                "critical lane shed while it still had budget"
        assert infer(0)[0] == 429
        status, hz = _get(h.port, "/healthz")
        assert hz["sheds_by_class"].get("1") == 1
        assert hz["sheds_by_class"].get("0") == 1
        assert server.metrics.snapshot()["completed"] == 4
    finally:
        h.stop()


@pytest.mark.frontend
def test_conn_fault_is_typed_and_socket_survives(oracle):
    """``op="conn"`` fires once on a keep-alive socket: that request
    answers a typed 500 and the SAME socket keeps serving."""
    _server, h = _door()
    try:
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=60)
        x = _images(1, seed=5)[0]
        body, headers = wire.infer_request("tiny", x)
        plan = FaultPlan([FaultRule(op="conn", times=1)])
        with inject(plan):
            conn.request("POST", "/v1/infer", body=body, headers=headers)
            r = conn.getresponse()
            reply = json.loads(r.read())
            assert r.status == 500 and reply["error"] == "internal"
            assert plan.rules[0].fired == 1
            conn.request("POST", "/v1/infer", body=body, headers=headers)
            r = conn.getresponse()
            assert r.status == 200
            row = wire.decode_array(json.loads(r.read())["result"])
        assert np.array_equal(row, oracle(x))
        assert h.door.connections == 1, "the typed failure burned the socket"
        conn.close()
    finally:
        h.stop()


@pytest.mark.frontend
def test_idle_keepalive_socket_is_closed_and_counted():
    _server, h = _door(idle_timeout_s=0.3)
    try:
        with socket.create_connection(("127.0.0.1", h.port),
                                      timeout=10) as s:
            s.settimeout(5.0)
            assert s.recv(1) == b"", "idle socket never closed"
    finally:
        h.stop()


@pytest.mark.frontend
def test_pipelined_requests_answer_in_order(oracle):
    """Two infer requests written back-to-back before reading either
    response: both answer 200, in request order, on one socket."""
    _server, h = _door()
    try:
        imgs = _images(2, seed=9)
        reqs = b""
        for x in imgs:
            body, headers = wire.infer_request("tiny", x)
            hdr = "".join(f"{k}: {v}\r\n" for k, v in headers.items())
            reqs += (f"POST /v1/infer HTTP/1.1\r\nHost: x\r\n"
                     f"Content-Length: {len(body)}\r\n{hdr}\r\n"
                     ).encode() + body
        with socket.create_connection(("127.0.0.1", h.port),
                                      timeout=30) as s:
            s.sendall(reqs + b"")
            s.settimeout(30.0)
            blob = b""
            while blob.count(b"HTTP/1.1 ") < 2 or not blob.endswith(b"}"):
                chunk = s.recv(65536)
                if not chunk:
                    break
                blob += chunk
        parts = blob.split(b"HTTP/1.1 ")[1:]
        assert len(parts) == 2
        for x, part in zip(imgs, parts):
            assert part.startswith(b"200 ")
            payload = json.loads(part.split(b"\r\n\r\n", 1)[1])
            assert np.array_equal(wire.decode_array(payload["result"]),
                                  oracle(x)), "pipelined answers misordered"
    finally:
        h.stop()


# --- multi-process: failover, crash-resume, SIGTERM -------------------------

@pytest.mark.frontend
def test_router_survives_worker_kill_with_bitmatched_rows(oracle):
    """Kill one of two worker processes mid-fleet: every request keeps
    answering 200 with the SAME row (shared-spec determinism), the dead
    worker is ejected, and /healthz stays ok."""
    workers = [ProcWorker("w1", SPEC), ProcWorker("w2", SPEC)]
    router = Router(workers, auto_restart=False, probe_interval_s=0.05,
                    eject_after=1)
    h = ServerThread(FrontDoor(router), also_start=(router,)).start()
    try:
        x = _images(1, seed=3)[0]
        payload = wire.infer_payload("tiny", x)
        ref = oracle(x)
        assert np.array_equal(
            wire.decode_array(_post(h.port, "/v1/infer", payload)[1]["result"]),
            ref)
        workers[0].terminate()               # hard kill, no goodbye
        for _ in range(4):
            status, body, _hdr = _post(h.port, "/v1/infer", payload)
            assert status == 200, body
            assert np.array_equal(wire.decode_array(body["result"]), ref), \
                "failover changed the answer"
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            snap = _get(h.port, "/metrics")[1]
            if snap["workers"]["w1"]["state"] == "ejected":
                break
            time.sleep(0.05)
        assert snap["workers"]["w1"]["state"] == "ejected"
        assert snap["workers"]["w2"]["state"] == "healthy"
        assert _get(h.port, "/healthz")[0] == 200
    finally:
        h.stop(drain=False)
        for w in workers:
            w.terminate()


@pytest.mark.frontend
def test_worker_sigterm_drains_to_clean_exit():
    w = ProcWorker("w", SPEC)
    import asyncio
    asyncio.run(w.start())
    try:
        status, body, _hdr = _post(
            w.port, "/v1/infer", wire.infer_payload("tiny", _images(1)[0]))
        assert status == 200
        w.proc.send_signal(signal.SIGTERM)
        assert w.proc.wait(30.0) == 0, "SIGTERM drain did not exit clean"
        with pytest.raises((ConnectionError, OSError)):
            urllib.request.urlopen(
                f"http://127.0.0.1:{w.port}/healthz", timeout=2)
    finally:
        w.terminate()
