"""Compiled engine vs interpreted reference: parity across networks and
partitioner schemes, compile-cache behaviour, int8 GEMM shape padding, and
the partitioner's objective validation."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.executor import (CompiledNetwork, cache_stats, clear_cache,
                                 compile_network, plan_signature)
from repro.core.graph import NETWORKS, bottleneck, fire, shuffle_unit
from repro.core.hetero import init_network, run_network
from repro.core.partitioner import candidates, partition_network
from repro.kernels.int8_gemm.ops import int8_gemm, int8_matmul
from repro.quant import quantize


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b),
                                                      1e-12))


def _run_both(mods, plans, res=32, batch=2, use_pallas=None):
    params = init_network(mods, jax.random.PRNGKey(0))
    c_in = mods[0].nodes[0].spec.c_in
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1),
                                (batch, res, res, c_in))
    eng = compile_network(mods, plans, use_pallas=use_pallas)
    out = eng(eng.prepare(params), x)
    ref = run_network(mods, params, x, plans)
    return out, ref


# --- whole-network parity: 3 networks x partitioner objectives -------------

@pytest.mark.parametrize("net", list(NETWORKS))
@pytest.mark.parametrize("objective,kw", [
    ("gpu_only", {}),
    ("paper", {}),
    ("paper", {"paper_faithful": True}),
    ("edp", {}),
])
def test_compiled_matches_interpreted(net, objective, kw):
    mods = NETWORKS[net]()
    plans = partition_network(mods, objective=objective, **kw)
    out, ref = _run_both(mods, plans)
    assert out.shape == ref.shape
    # fp32-only plans agree to XLA-reassociation noise.  Any FPGA placement
    # gets the loose bound: fused chains intentionally skip the intermediate
    # fake-quant (VMEM residency), and even re-quantizing paths can amplify
    # reassociation noise across int8 rounding boundaries over ~18 modules.
    quantized = any(v == "fpga" for p in plans for v in p.assign.values())
    assert _rel(out, ref) < (8e-2 if quantized else 1e-4)
    cos = float(jnp.sum(out * ref)
                / (jnp.linalg.norm(out) * jnp.linalg.norm(ref)))
    assert cos > 0.995


# --- per-scheme parity: every lowering rule exercised explicitly -----------

def _module_net(m):
    return [m]


def _plans_for_scheme(m, scheme):
    ps = [p for p in candidates(m) if p.scheme == scheme]
    assert ps, f"no {scheme} candidate for {m.kind}"
    return [ps[0]]


@pytest.mark.parametrize("scheme", ["gpu_only", "fpga_fused",
                                    "parallel_branch", "gconv_split"])
def test_fire_schemes(scheme):
    m = fire("f", 16, 64, 16, 64)
    out, ref = _run_both(_module_net(m), _plans_for_scheme(m, scheme), res=16)
    assert _rel(out, ref) < 8e-2


@pytest.mark.parametrize("scheme", ["gpu_only", "fpga_fused", "dwconv_split",
                                    "fused_layer"])
def test_bottleneck_schemes(scheme):
    m = bottleneck("b", 16, 24, 24, 1, 6)
    out, ref = _run_both(_module_net(m), _plans_for_scheme(m, scheme), res=16)
    assert _rel(out, ref) < 8e-2


@pytest.mark.parametrize("scheme", ["gpu_only", "fpga_fused", "dwconv_split",
                                    "fused_layer"])
def test_shuffle_unit_schemes(scheme):
    m = shuffle_unit("s", 16, 48, False)
    out, ref = _run_both(_module_net(m), _plans_for_scheme(m, scheme), res=16)
    assert _rel(out, ref) < 8e-2


def test_shuffle_down_parallel_branch():
    m = shuffle_unit("sd", 16, 48, True)
    out, ref = _run_both(_module_net(m),
                         _plans_for_scheme(m, "parallel_branch"), res=16)
    assert _rel(out, ref) < 8e-2


def test_fused_pair_pallas_interpret_matches_reference():
    """The Pallas fused_block path (interpret mode on CPU) agrees with the
    pure-XLA lowering of the same fused plan."""
    m = bottleneck("b", 8, 16, 16, 1, 6)
    plans = _plans_for_scheme(m, "fused_layer")
    out_p, ref = _run_both(_module_net(m), plans, res=8, use_pallas=True)
    out_x, _ = _run_both(_module_net(m), plans, res=8, use_pallas=False)
    assert _rel(out_p, out_x) < 1e-4
    assert _rel(out_p, ref) < 8e-2


# --- compile cache ---------------------------------------------------------

def test_cache_same_signature_no_recompile():
    clear_cache()
    mods = NETWORKS["mobilenetv2"]()
    plans = partition_network(mods, paper_faithful=True)
    e1 = compile_network(mods, plans)
    # a fresh, structurally identical (modules, plans) pair must hit
    mods2 = NETWORKS["mobilenetv2"]()
    plans2 = partition_network(mods2, paper_faithful=True)
    e2 = compile_network(mods2, plans2)
    assert e1 is e2
    assert plan_signature(mods, plans, e1.use_pallas) == \
        plan_signature(mods2, plans2, e2.use_pallas)
    stats = cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1

    # a different plan set must miss
    e3 = compile_network(mods, partition_network(mods, objective="gpu_only"))
    assert e3 is not e1
    assert cache_stats()["misses"] == 2


def test_cache_opt_out():
    clear_cache()
    mods = [fire("f", 8, 16, 4, 8)]
    e1 = compile_network(mods, None, cache=False)
    e2 = compile_network(mods, None, cache=False)
    assert e1 is not e2 and isinstance(e1, CompiledNetwork)
    assert cache_stats()["size"] == 0


# --- int8 GEMM arbitrary shapes (satellite) --------------------------------

@pytest.mark.parametrize("mkn", [(300, 64, 200), (37, 48, 65),
                                 (257, 128, 129), (512, 96, 512)])
def test_int8_gemm_pads_arbitrary_shapes(mkn):
    M, K, N = mkn
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    a_q, a_s = quantize(a)
    w_q, w_s = quantize(w, axis=-1)
    out = int8_gemm(a_q, w_q, a_s, w_s.reshape(-1), use_pallas=True)
    ref = int8_gemm(a_q, w_q, a_s, w_s.reshape(-1), use_pallas=False)
    assert out.shape == (M, N)
    assert _rel(out, ref) < 1e-6


def test_int8_matmul_odd_shape():
    a = jax.random.normal(jax.random.PRNGKey(2), (33, 48))
    w = jax.random.normal(jax.random.PRNGKey(3), (48, 70))
    out = int8_matmul(a, w)
    rel = float(jnp.abs(out - a @ w).max() / jnp.abs(a @ w).max())
    assert out.shape == (33, 70) and rel < 0.05


# --- partitioner objective validation (satellite) --------------------------

def test_partition_unknown_objective_raises():
    mods = NETWORKS["squeezenet"]()
    with pytest.raises(ValueError, match="unknown objective"):
        partition_network(mods, objective="nonsense")


def test_edp_objective_never_worsens_edp():
    for net, builder in NETWORKS.items():
        plans = partition_network(builder(), objective="edp")
        for p in plans:
            if p.scheme == "gpu_only":
                continue
            assert (p.cost.energy * p.cost.latency
                    < p.gpu_only.energy * p.gpu_only.latency), \
                f"{net}/{p.module}: edp plan worsens EDP"


def test_latency_objective_never_worsens_latency():
    for net, builder in NETWORKS.items():
        plans = partition_network(builder(), objective="latency")
        assert any(p.scheme != "gpu_only" for p in plans), \
            f"{net}: latency objective upgraded nothing"
        for p in plans:
            if p.scheme == "gpu_only":
                continue
            assert p.cost.latency < p.gpu_only.latency, \
                f"{net}/{p.module}: latency plan worsens latency"


def test_latency_objective_ranks_by_latency_saving_density():
    """Mirror of the edp ranking semantics: under a budget that only fits
    the single densest option, the greedy pass must pick the plan with the
    best latency saved per resident resource — not the best energy saving."""
    mods = NETWORKS["mobilenetv2"]()
    best, best_d = None, -1.0
    for m in mods:
        for p in candidates(m):
            if p.scheme == "gpu_only":
                continue
            saving = p.gpu_only.latency - p.cost.latency
            if saving <= 0:
                continue
            d = saving / max(p.res.macs + p.res.bytes / 64.0, 1.0)
            if d > best_d:
                best, best_d = p, d
    assert best is not None
    plans = partition_network(mods, objective="latency",
                              mac_budget=best.res.macs,
                              byte_budget=best.res.bytes)
    upgraded = [p for p in plans if p.scheme != "gpu_only"]
    assert len(upgraded) == 1
    assert upgraded[0].module == best.module
    assert upgraded[0].scheme == best.scheme
    assert upgraded[0].g_par == best.g_par
