"""Fault-tolerant serving: deterministic injection (``repro.runtime.faults``)
drives every failure path of ``HeteroServer`` in CI with no hardware —
bounded retries, typed rejections, FPGA-failure circuit-breaker failover to
the GPU-only plan with half-open probe recovery, straggler watchdog, and
graceful drain.  The request-level contract under test: every admitted
future resolves exactly once, and every served row bit-matches the batch-1
oracle of the plan that served it.

Oracle engines are always built and called OUTSIDE ``inject`` scopes: the
injection point is process-global, exactly like the engine cache.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executor import compile_network, compile_pipelined
from repro.core.graph import fire
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.runtime.faults import (FaultPlan, FaultRule, InjectedFault,
                                  fault_device, inject, trip)
from repro.serving import (DeadlineExceeded, HeteroServer, Overloaded,
                           ServerClosed, Shutdown)

HW = (8, 8)
C = 16


def _mods():
    return [fire("f", C, 16, 4, 8)]


def _images(n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return [0.5 * jax.random.normal(k, (*HW, C)) for k in ks]


# --- FaultPlan / FaultRule units -------------------------------------------

def test_rule_window_after_and_times_is_deterministic():
    plan = FaultPlan([FaultRule(op="dispatch", after=1, times=2)])
    plan.check("dispatch")                       # hit 1: skipped (after=1)
    for _ in range(2):                           # hits 2-3: fire
        with pytest.raises(InjectedFault):
            plan.check("dispatch")
    plan.check("dispatch")                       # hit 4: times exhausted
    r = plan.rules[0]
    assert (r.hits, r.fired) == (4, 2)
    assert [e.hit for e in plan.fired] == [2, 3]


def test_rule_device_matching_against_site_sets():
    plan = FaultPlan([FaultRule(op="dispatch", device="fpga", times=None)])
    plan.check("dispatch", device=("gpu",))      # GPU-only site: no match
    with pytest.raises(InjectedFault) as ei:
        plan.check("dispatch", device=("fpga", "gpu"))   # hybrid site
    assert ei.value.device == "fpga"
    # site reports no device: the rule's device is attribution only
    with pytest.raises(InjectedFault) as ei:
        plan.check("dispatch")
    assert fault_device(ei.value) == "fpga"


def test_rule_stage_matching():
    plan = FaultPlan([FaultRule(op="stage", stage=1, times=None)])
    plan.check("stage", device="gpu", stage=0)
    plan.check("dispatch", stage=1)              # wrong op
    with pytest.raises(InjectedFault) as ei:
        plan.check("stage", device="fpga", stage=1)
    assert (ei.value.stage, ei.value.device) == (1, "fpga")


def test_delay_rule_sleeps_instead_of_raising():
    plan = FaultPlan([FaultRule(op="dispatch", kind="delay",
                                delay_s=0.02, times=1)])
    t0 = time.monotonic()
    plan.check("dispatch")                       # sleeps
    assert time.monotonic() - t0 >= 0.02
    plan.check("dispatch")                       # exhausted: no sleep
    assert [e.kind for e in plan.fired] == ["delay"]


def test_seeded_bernoulli_is_reproducible():
    def pattern(seed):
        plan = FaultPlan([FaultRule(op="dispatch", p=0.3, times=None)],
                         seed=seed)
        out = []
        for _ in range(64):
            try:
                plan.check("dispatch")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    assert pattern(7) == pattern(7)
    assert any(pattern(7))                       # it does fire...
    assert not all(pattern(7))                   # ...and does not always


def test_trip_is_noop_without_installed_plan():
    trip("dispatch", device=("fpga",))           # must not raise
    with inject(FaultPlan([FaultRule(op="refresh", times=1)])) as plan:
        with pytest.raises(InjectedFault):
            trip("refresh")
    trip("refresh")                              # uninstalled on exit
    assert plan.fired[0].op == "refresh"


def test_fault_device_ignores_non_string_tags():
    assert fault_device(RuntimeError("plain")) is None
    e = RuntimeError("tagged")
    e.device = ("fpga", "gpu")                   # tuple: not an attribution
    assert fault_device(e) is None
    e.device = "fpga"
    assert fault_device(e) == "fpga"


# --- request-level guarantees ----------------------------------------------

@pytest.mark.faults
def test_submit_raises_server_closed_before_start_and_after_shutdown():
    server = HeteroServer(buckets=(1, 4))
    server.register("f", _mods(), None, input_hw=HW)
    x = _images(1)[0]
    with pytest.raises(ServerClosed, match="before start"):
        server.submit("f", x)
    # validation still precedes the state check
    with pytest.raises(KeyError, match="unregistered"):
        server.submit("nope", x)
    with pytest.raises(ValueError, match="expected an image"):
        server.submit("f", jnp.zeros((4, 4, C)))
    with server:
        server.submit("f", x).result(timeout=60)
    with pytest.raises(ServerClosed, match="after shutdown"):
        server.submit("f", x)
    with pytest.raises(ServerClosed, match="single-use"):
        server.start()


@pytest.mark.faults
def test_one_transient_dispatch_failure_is_retried_to_success():
    mods = _mods()
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0)
    server.register("f", mods, None, input_hw=HW)
    eng = compile_network(mods, None)
    prep = eng.prepare(server._entries["f"].params)
    imgs = _images(4, seed=1)
    plan = FaultPlan([FaultRule(op="dispatch", times=1)])
    with server:
        with inject(plan):
            futs = [server.submit("f", x) for x in imgs]
            outs = [f.result(timeout=60) for f in futs]
    assert plan.rules[0].fired == 1
    for x, out in zip(imgs, outs):
        assert bool(jnp.all(out == eng(prep, x[None])[0]))
    snap = server.metrics.snapshot()
    assert snap["retries"] >= 1
    assert snap["failed"] == 0


@pytest.mark.faults
def test_retry_budget_exhaustion_rejects_with_the_injected_error():
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0)
    server.register("f", _mods(), None, input_hw=HW)
    imgs = _images(3, seed=2)
    # always-failing dispatch with no device attribution: the breaker
    # (FPGA-only) never trips, so rows burn their one retry and reject
    plan = FaultPlan([FaultRule(op="dispatch", times=None)])
    with server:
        with inject(plan):
            futs = [server.submit("f", x) for x in imgs]
            for f in futs:
                with pytest.raises(InjectedFault):
                    f.result(timeout=60)
    snap = server.metrics.snapshot()
    assert snap["failed"] == len(imgs)
    assert snap["retries"] >= 1
    assert not server._pending                    # nothing left hanging


@pytest.mark.faults
def test_per_request_deadline_rejects_typed():
    server = HeteroServer(buckets=(4,), max_wait_ms=10000.0)
    server.register("f", _mods(), None, input_hw=HW)
    imgs = _images(2, seed=3)
    server.start()
    server._stop.set()                   # idle the drain loop...
    time.sleep(0.2)
    futs = [server.submit("f", x, deadline_ms=10.0) for x in imgs]
    ok = server.submit("f", imgs[0])     # no deadline: must be served
    time.sleep(0.05)                     # ...so the deadlines pass queued
    server.shutdown()
    for f in futs:
        with pytest.raises(DeadlineExceeded) as ei:
            f.result(timeout=60)
        assert ei.value.waited_s > ei.value.deadline_s
    assert ok.result(timeout=60) is not None
    assert server.metrics.snapshot()["deadline_exceeded"] == 2


@pytest.mark.faults
def test_queue_bound_sheds_with_overloaded():
    server = HeteroServer(buckets=(1,), max_wait_ms=10000.0, max_queue=2)
    server.register("f", _mods(), None, input_hw=HW)
    imgs = _images(3, seed=4)
    server.start()
    server._stop.set()                   # idle the drain loop: queue grows
    time.sleep(0.2)
    futs = [server.submit("f", imgs[0]), server.submit("f", imgs[1])]
    with pytest.raises(Overloaded) as ei:
        server.submit("f", imgs[2])
    assert ei.value.bound == 2
    server.shutdown()                    # admitted rows still drain
    for f in futs:
        assert f.result(timeout=60) is not None
    snap = server.metrics.snapshot()
    assert snap["shed"] == 1
    assert snap["submitted"] == 2        # shed requests never count


@pytest.mark.faults
def test_shutdown_under_permanent_failure_resolves_every_future():
    """Graceful drain with a dead engine: rows retry once, then reject —
    and the pending-future sweep guarantees nothing hangs."""
    server = HeteroServer(buckets=(1, 4), max_wait_ms=10000.0)
    server.register("f", _mods(), None, input_hw=HW)
    imgs = _images(6, seed=5)
    server.start()
    server._stop.set()
    time.sleep(0.2)
    futs = [server.submit("f", x) for x in imgs]
    with inject(FaultPlan([FaultRule(op="dispatch", times=None)])):
        server.shutdown()
    for f in futs:
        assert f.done()
        with pytest.raises((InjectedFault, Shutdown)):
            f.result(timeout=0)
    assert not server._pending


# --- failover + recovery (the acceptance path) ------------------------------

def _hybrid_setup(**server_kw):
    mods = _mods()
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0, **server_kw)
    server.register("f", mods, plans, params, input_hw=HW)
    hybrid = compile_network(mods, plans)
    h_prep = hybrid.prepare(params)
    gpu = compile_network(mods, None)
    g_prep = gpu.prepare(params)
    oracles = {"hybrid": lambda x: hybrid(h_prep, x[None])[0],
               "gpu": lambda x: gpu(g_prep, x[None])[0]}
    return server, oracles


@pytest.mark.faults
def test_fpga_failover_bitmatch_and_probe_recovery():
    """The tentpole acceptance test: consecutive FPGA-attributed dispatch
    failures trip the breaker, traffic redirects to the shadow-prepared
    GPU-only plan with ZERO lost futures, half-open probes recover the
    hybrid plan once the fault clears, and every served row bit-matches
    the batch-1 oracle of the plan that served it."""
    server, oracle = _hybrid_setup(breaker_threshold=2,
                                   probe_interval_s=0.03, recover_after=1)
    imgs = _images(10, seed=6)
    served = []
    # 3 firings: two dispatch failures (trip at threshold=2) + the first
    # half-open probe; the second probe finds the window exhausted -> heal
    plan = FaultPlan([FaultRule(op="dispatch", device="fpga", times=3)])
    with server:
        with inject(plan):
            for x in imgs[:4]:
                served.append((x, server.submit("f", x).result(timeout=60)))
            # ride through probe attempts until the breaker closes
            for x in imgs[4:]:
                served.append((x, server.submit("f", x).result(timeout=60)))
                if server.stats()["engines"]["f"]["mode"] == "primary":
                    break
                time.sleep(0.05)
        st = server.stats()["engines"]["f"]
        assert st["mode"] == "primary", "breaker never recovered"
        assert st["breaker"] == "closed"
        assert st["fallback_ready"]
        # post-recovery traffic serves on the hybrid plan again
        x = imgs[-1]
        out = server.submit("f", x).result(timeout=60)
        assert bool(jnp.all(out == oracle["hybrid"](x)))
    # zero lost futures, and every row bit-matches the plan that served it
    for x, out in served:
        h, g = oracle["hybrid"](x), oracle["gpu"](x)
        assert bool(jnp.all(out == h)) or bool(jnp.all(out == g))
    snap = server.metrics.snapshot()
    assert snap["failovers"] >= 1
    assert snap["recoveries"] >= 1
    assert snap["probes_ok"] >= 1
    assert snap["failed"] == 0
    assert snap["breakers"]["f"] == "closed"


@pytest.mark.faults
def test_pipelined_stage_fault_attributes_device_and_fails_over():
    """A fault injected at one FPGA stage of the pipelined engine carries
    its device tag out to the breaker; threshold=1 fails over on the first
    failure, so no request is ever lost."""
    mods = _mods()
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    pipe = compile_pipelined(mods, plans)
    fpga_stages = [s for s, st in enumerate(pipe.stages)
                   if st.device == "fpga"]
    assert fpga_stages, "fire module must map a stage to fpga"
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0,
                          breaker_threshold=1, probe_interval_s=60.0)
    server.register("f", mods, plans, params, input_hw=HW, pipelined=True)
    gpu = compile_network(mods, None)
    g_prep = gpu.prepare(params)
    imgs = _images(4, seed=7)
    plan = FaultPlan([FaultRule(op="stage", stage=fpga_stages[0],
                                times=None)])
    with server:
        with inject(plan):
            outs = [server.submit("f", x).result(timeout=60) for x in imgs]
    assert plan.fired and plan.fired[0].device == "fpga"
    for x, out in zip(imgs, outs):
        assert bool(jnp.all(out == gpu(g_prep, x[None])[0]))
    snap = server.metrics.snapshot()
    assert snap["failovers"] == 1
    assert snap["failed"] == 0
    assert server.stats()["engines"]["f"]["mode"] == "fallback"


# --- straggler watchdog + loop survival -------------------------------------

class _NeverReady:
    """Stands in for a device array that never lands."""

    def is_ready(self):
        return False


@pytest.mark.faults
def test_straggler_watchdog_counts_event_and_returns_original():
    server = HeteroServer(buckets=(1, 4), straggler_min_ms=1.0)
    server.register("f", _mods(), None, input_hw=HW)
    entry = server._entries["f"]
    for s in range(10):                   # establish a tiny rolling budget
        entry.monitor.record(s, 0.001)
    stuck = _NeverReady()
    out = server._watch(entry, np.zeros((1, *HW, C), np.float32), stuck)
    assert out is stuck                   # monolithic entry: no backup
    assert server.metrics.snapshot()["straggler_events"] == 1


@pytest.mark.faults
def test_straggler_backup_dispatch_bitmatches_for_pipelined_entry():
    mods = _mods()
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    server = HeteroServer(buckets=(1, 4), straggler_min_ms=1.0)
    server.register("f", mods, plans, params, input_hw=HW, pipelined=True)
    entry = server._entries["f"]
    for s in range(10):
        entry.monitor.record(s, 0.001)
    mono = compile_network(mods, plans)
    m_prep = mono.prepare(params)
    x = _images(1, seed=8)[0]
    xb = np.zeros((1, *HW, C), np.float32)
    xb[0] = np.asarray(x)
    out = server._watch(entry, xb, _NeverReady())
    assert not isinstance(out, _NeverReady)   # backup result won the race
    assert bool(jnp.all(jnp.asarray(out)[0] == mono(m_prep, x[None])[0]))
    snap = server.metrics.snapshot()
    assert snap["straggler_events"] == 1
    assert snap["backup_dispatches"] == 1


@pytest.mark.faults
def test_completion_loop_survives_unexpected_error():
    """An error past the dispatch point (satellite 2): the batch's futures
    resolve exceptionally, the errors counter ticks, and the loop keeps
    serving later traffic."""
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0, in_flight=2)
    server.register("f", _mods(), None, input_hw=HW)
    imgs = _images(2, seed=9)
    orig = server._complete
    state = {"armed": True}

    def boom(*a):
        if state["armed"]:
            state["armed"] = False
            raise RuntimeError("synthetic completion crash")
        return orig(*a)

    server._complete = boom
    with server:
        f0 = server.submit("f", imgs[0])
        with pytest.raises(RuntimeError, match="synthetic completion"):
            f0.result(timeout=60)
        f1 = server.submit("f", imgs[1])
        assert f1.result(timeout=60) is not None
    snap = server.metrics.snapshot()
    assert snap["errors"] == 1
    assert not server._pending


@pytest.mark.faults
def test_prepare_fault_surfaces_at_register():
    plan = FaultPlan([FaultRule(op="prepare", times=1)])
    server = HeteroServer(buckets=(1,))
    with inject(plan):
        with pytest.raises(InjectedFault):
            server.register("g", [fire("g", C, 16, 4, 8)], None,
                            input_hw=HW)


@pytest.mark.faults
def test_injected_delay_is_survivable_noise():
    """Latency injection never breaks correctness — it only slows."""
    mods = _mods()
    server = HeteroServer(buckets=(1, 4), max_wait_ms=1.0)
    server.register("f", mods, None, input_hw=HW)
    eng = compile_network(mods, None)
    prep = eng.prepare(server._entries["f"].params)
    imgs = _images(3, seed=10)
    plan = FaultPlan([FaultRule(op="dispatch", kind="delay",
                                delay_s=0.02, times=None)])
    with server:
        with inject(plan):
            futs = [server.submit("f", x) for x in imgs]
            outs = [f.result(timeout=60) for f in futs]
    for x, out in zip(imgs, outs):
        assert bool(jnp.all(out == eng(prep, x[None])[0]))
    assert server.metrics.snapshot()["failed"] == 0
