"""Validation against the paper's own claims (EXPERIMENTS.md §Reproduction).

The paper reports (abstract / Table I / Sec. V text — internally spread):
  SqueezeNet   21-28% energy reduction, ~same latency        (Fire modules)
  MobileNetV2  12-30% energy, 4-26% latency                  (bottlenecks)
  ShuffleNetV2 ~25% energy, ~21-35% latency                  (stages)
Our analytical models are calibrated to land in a broadened envelope and
preserve the orderings; exact-point matching is impossible without their
board (documented in DESIGN.md §5).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import costmodel as cm
from repro.core.costmodel import ConvSpec
from repro.core.graph import NETWORKS
from repro.core.hetero import init_network, run_network
from repro.core.partitioner import PAPER_SCHEMES, candidates, partition_network

ENVELOPES = {           # family-mean module gains (broad: model uncertainty)
    "squeezenet": ((1.10, 2.20), (0.90, 1.60)),
    "mobilenetv2": ((1.15, 2.60), (0.80, 1.50)),
    "shufflenetv2": ((1.10, 2.20), (0.90, 1.60)),
}


def family_mean_gains(net):
    es, ls = [], []
    for m in NETWORKS[net]():
        if m.kind in ("stem", "head"):
            continue
        cands = [p for p in candidates(m)
                 if p.scheme in PAPER_SCHEMES.get(m.kind, ())
                 and p.res.macs <= cm.FPGA.mac_budget]
        if not cands:
            continue
        best = min(cands, key=lambda p: p.cost.energy * p.cost.latency)
        es.append(best.energy_gain)
        ls.append(best.speedup)
    return sum(es) / len(es), sum(ls) / len(ls)


@pytest.mark.parametrize("net", list(ENVELOPES))
def test_module_gains_inside_paper_envelope(net):
    (e_lo, e_hi), (l_lo, l_hi) = ENVELOPES[net]
    e, lat = family_mean_gains(net)
    assert e_lo <= e <= e_hi, f"{net} energy gain {e:.2f}"
    assert l_lo <= lat <= l_hi, f"{net} speedup {lat:.2f}"


def test_every_family_has_positive_hetero_gain():
    for net in NETWORKS:
        e, _ = family_mean_gains(net)
        assert e > 1.05


def test_fig1_fpga_beats_gpu_on_small_convs():
    """Fig. 1: on 224x224x3 inputs the FPGA's energy advantage grows with
    the filter count ("this effect increases with the number of kernel
    filters") and is decisive from ~8 filters up; latency wins at the top
    end of the sweep."""
    for k in (3, 5):                       # Fig.1 sweeps conv kernel sizes
        ratios = []
        for n in (2, 8, 16, 64):
            spec = ConvSpec("conv", 224, 224, 3, n, k=k)
            g = cm.GPU.op_cost(spec)
            f = cm.FPGA.full_unroll_cost(spec)
            ratios.append(g.energy / f.energy)
            if n >= 8:
                assert f.energy < g.energy, (k, n)
        assert ratios == sorted(ratios), f"gap must grow with n (k={k})"
        assert ratios[-1] > 3.0            # decisive at 64 filters
    # latency win at the paper's quoted ceiling case: 64 filters of 5x5
    spec = ConvSpec("conv", 224, 224, 3, 64, k=5)
    assert cm.FPGA.full_unroll_cost(spec).latency \
        < cm.GPU.op_cost(spec).latency


def test_hetero_execution_matches_reference():
    """Plans are runnable and numerically faithful (int8 on FPGA nodes)."""
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, 224, 224, 3))
    for net, builder in NETWORKS.items():
        mods = builder()
        params = init_network(mods, jax.random.PRNGKey(0))
        ref = run_network(mods, params, x)
        plans = partition_network(mods, paper_faithful=True)
        het = run_network(mods, params, x, plans)
        cos = float(jnp.sum(ref * het)
                    / (jnp.linalg.norm(ref) * jnp.linalg.norm(het) + 1e-9))
        assert cos > 0.995, net


def test_comm_overhead_is_accounted():
    """A plan's cost includes PCIe: offloading with a free link would always
    win; with the real link some candidates must become inadmissible."""
    mods = NETWORKS["squeezenet"]()
    all_cands = [p for m in mods for p in candidates(m)
                 if p.scheme != "gpu_only"]
    worse_latency = [p for p in all_cands
                     if p.cost.latency > p.gpu_only.latency * 1.05]
    assert worse_latency, "PCIe cost never binding — comm model broken"
