"""Online re-partitioning (``repro.core.replan``): the measurement ->
fit -> repartition -> migrate loop.

Tier-1 half: the fitter and the decision policy are plain host
arithmetic, so convergence is tested synthetically — measurements are
generated from a "true" scaled cost model, no hardware and no threads.
The contract under test is the ISSUE's acceptance criterion: starting
from a cost model with the FPGA/GPU coefficients swapped, the replanner
migrates to within one boundary-edge of the oracle-optimal plan within a
bounded number of windows, and never flaps afterward.

Serving half (``-m faults``): a live ``HeteroServer`` with injected FPGA
stage delays migrates to the all-GPU plan under real traffic, and every
checked row bit-matches the batch-1 oracle of the plan generation that
served it.  Oracle engines are built and called OUTSIDE ``inject`` scopes.
"""
import jax
import numpy as np
import pytest

from repro.core.costmodel import CostScales
from repro.core.executor import compile_network, compile_pipelined
from repro.core.graph import NETWORKS, fire
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.core.replan import (Replanner, StageSample, assign_signature,
                               boundary_distance, carry_calibration,
                               cut_positions, fit_scales, stage_samples)
from repro.core.schedule import network_stage_components
from repro.runtime.faults import FaultPlan, FaultRule, inject
from repro.serving import HeteroServer


def _measure(mods, plans, truth, rng=None, noise=0.0):
    """Synthetic per-stage wall times: the model's own stage latencies
    under the TRUE scales, optionally jittered."""
    comps = network_stage_components(mods, plans)
    times = [sc.latency(truth) for sc in comps]
    if noise and rng is not None:
        times = [t * float(rng.uniform(1 - noise, 1 + noise))
                 for t in times]
    return comps, times


# --- fitter units ----------------------------------------------------------

def test_fit_scales_recovers_truth_from_stage_samples():
    mods = NETWORKS["mobilenetv2"]()
    plans = partition_network(mods, objective="latency")
    truth = CostScales(gpu=2.0, fpga=5.0, xfer=3.0)
    comps, times = _measure(mods, plans, truth)
    samples = stage_samples(comps, times)
    fit = fit_scales(samples, ridge=1e-3)
    # gpu is cleanly identified; fpga and xfer are collinear within one
    # plan (every FPGA stage pays PCIe), so only their stage sums are —
    # check the reconstruction, not each coefficient
    assert fit.gpu == pytest.approx(truth.gpu, rel=0.05)
    for sc, t in zip(comps, times):
        assert sc.latency(fit) == pytest.approx(t, rel=0.05)


def test_fit_scales_pins_unobserved_coefficients_at_prior():
    # an all-GPU window carries zero FPGA/transfer signal: those
    # coefficients must stay exactly where the prior (= accumulated
    # belief) left them instead of drifting to 1.0 or exploding
    samples = [StageSample(gpu_s=1e-3, fpga_s=0.0, xfer_s=0.0,
                           measured_s=3e-3)] * 8
    prior = CostScales(gpu=1.0, fpga=7.5, xfer=2.5)
    fit = fit_scales(samples, prior=prior)
    assert fit.gpu == pytest.approx(3.0, rel=0.05)
    assert fit.fpga == pytest.approx(7.5, rel=1e-6)
    assert fit.xfer == pytest.approx(2.5, rel=1e-6)


def test_fit_scales_empty_window_returns_prior_and_clamps():
    prior = CostScales(gpu=2.0, fpga=3.0, xfer=4.0)
    assert fit_scales([], prior=prior) == prior
    # degenerate negative solution clamps positive
    s = fit_scales([StageSample(1.0, 0.0, 0.0, -5.0)])
    assert s.gpu > 0


def test_stage_samples_collapse_for_monolithic_engines():
    mods = NETWORKS["squeezenet"]()
    plans = partition_network(mods, paper_faithful=True)
    comps = network_stage_components(mods, plans)
    assert len(comps) > 1
    # one total measurement -> one summed observation row
    rows = stage_samples(comps, [0.042], batch=2)
    assert len(rows) == 1
    assert rows[0].measured_s == pytest.approx(0.021)
    assert rows[0].gpu_s == pytest.approx(
        sum(sc.comp.latency for sc in comps if sc.device == "gpu"))
    assert rows[0].fpga_s == pytest.approx(
        sum(sc.comp.latency for sc in comps if sc.device == "fpga"))


# --- plan identity / distance ----------------------------------------------

def test_assign_signature_ignores_cost_but_not_routing():
    mods = NETWORKS["shufflenetv2"]()
    a = partition_network(mods, objective="latency")
    b = partition_network(mods, objective="latency",
                          scales=CostScales(gpu=1.0, fpga=1.0, xfer=1.0))
    assert assign_signature(a) == assign_signature(b)
    c = partition_network(mods, objective="gpu_only")
    assert assign_signature(a) != assign_signature(c)


def test_boundary_distance_counts_cut_edges():
    mods = NETWORKS["mobilenetv2"]()
    hybrid = partition_network(mods, objective="latency")
    gpu = partition_network(mods, objective="gpu_only")
    assert boundary_distance(mods, hybrid, hybrid) == 0
    assert boundary_distance(mods, gpu, None) == 0      # both cut-free
    d = boundary_distance(mods, hybrid, gpu)
    assert d == len(cut_positions(mods, hybrid)) > 0


def test_carry_calibration_preserves_live_choice():
    from dataclasses import replace
    mods = NETWORKS["mobilenetv2"]()
    old = partition_network(mods, paper_faithful=True)
    old = [replace(p, calibrate="pct99") for p in old]
    new = partition_network(mods, objective="gpu_only")
    carried = carry_calibration(old, new)
    by = {p.module: p for p in old}
    for p in carried:
        assert p.calibrate == by[p.module].calibrate


# --- the convergence contract ----------------------------------------------

def test_swapped_coefficients_converge_to_oracle_plan():
    """The acceptance criterion: belief says the FPGA is cheap and the
    GPU dear; reality is the opposite.  The replanner must fit reality
    from measured windows, migrate to within one boundary-edge of the
    oracle plan within N windows, and hold still afterward."""
    mods = NETWORKS["mobilenetv2"]()
    misfit = CostScales(gpu=8.0, fpga=1.0, xfer=1.0)    # swapped belief
    truth = CostScales(gpu=1.0, fpga=8.0, xfer=2.0)     # swapped reality
    plans = partition_network(mods, objective="latency", scales=misfit)
    oracle = partition_network(mods, objective="latency", scales=truth)
    assert boundary_distance(mods, plans, oracle) > 1   # genuinely wrong

    rep = Replanner(objective="latency", threshold=0.15, patience=2,
                    min_samples=2)
    rng = np.random.default_rng(0)
    migrated_at = None
    migrations = 0
    for w in range(14):                                  # N = 14 windows
        comps, times = _measure(mods, plans, truth, rng, noise=0.03)
        rep.observe("mbv2", (32, 32), plans, comps, times)
        d = rep.consider("mbv2", mods, plans)
        if d.migrate:
            migrations += 1
            plans = d.plans
            if migrated_at is None:
                migrated_at = w
    assert migrated_at is not None and migrated_at < 6
    assert boundary_distance(mods, plans, oracle) <= 1
    # post-migration stability: windows keep arriving, plan holds
    assert migrations == 1
    fit = rep.fitted("mbv2")
    assert fit.gpu == pytest.approx(truth.gpu, rel=0.1)
    snap = rep.snapshot()
    assert snap["networks"]["mbv2"]["migrations"] == 1
    assert len(snap["events"]) == 1
    assert snap["events"][0]["win"] >= 0.15


def test_hysteresis_patience_gates_migration():
    mods = NETWORKS["mobilenetv2"]()
    misfit = CostScales(gpu=8.0, fpga=1.0)
    truth = CostScales(gpu=1.0, fpga=8.0)
    plans = partition_network(mods, objective="latency", scales=misfit)
    rep = Replanner(objective="latency", threshold=0.15, patience=3,
                    min_samples=1)
    decisions = []
    for _w in range(3):
        comps, times = _measure(mods, plans, truth)
        rep.observe("mbv2", None, plans, comps, times)
        decisions.append(rep.consider("mbv2", mods, plans))
    # identical over-threshold windows: only the patience-th may migrate
    assert [d.migrate for d in decisions] == [False, False, True]
    assert decisions[0].win >= 0.15
    assert "hysteresis" in decisions[0].reason
    assert [d.streak for d in decisions] == [1, 2, 3]


def test_threshold_blocks_migration_and_resets_streak():
    mods = NETWORKS["mobilenetv2"]()
    misfit = CostScales(gpu=8.0, fpga=1.0)
    truth = CostScales(gpu=1.0, fpga=8.0)
    plans = partition_network(mods, objective="latency", scales=misfit)
    # threshold above any achievable win: the loop must never migrate
    rep = Replanner(objective="latency", threshold=0.99, patience=1,
                    min_samples=1)
    for _w in range(4):
        comps, times = _measure(mods, plans, truth)
        rep.observe("mbv2", None, plans, comps, times)
        d = rep.consider("mbv2", mods, plans)
        assert not d.migrate
        assert "below threshold" in d.reason
    assert rep.snapshot()["networks"]["mbv2"]["streak"] == 0


def test_consider_warms_up_before_deciding():
    mods = NETWORKS["squeezenet"]()
    plans = partition_network(mods, paper_faithful=True)
    rep = Replanner(min_samples=3)
    comps, times = _measure(mods, plans, CostScales())
    rep.observe("sq", None, plans, comps, times)
    d = rep.consider("sq", mods, plans)
    assert not d.migrate and "warming" in d.reason
    # sweeps from a DIFFERENT plan don't count toward the current plan's
    # warm-up quota (its measured baseline must come from its own rows)
    other = partition_network(mods, objective="gpu_only")
    for _ in range(5):
        rep.observe("sq", None, other, *_measure(mods, other, CostScales()))
    assert "warming" in rep.consider("sq", mods, plans).reason


def test_current_plan_optimal_is_a_no_op():
    mods = NETWORKS["shufflenetv2"]()
    truth = CostScales()                     # belief == reality
    plans = partition_network(mods, objective="latency")
    rep = Replanner(objective="latency", min_samples=1, patience=1)
    comps, times = _measure(mods, plans, truth)
    rep.observe("sh", None, plans, comps, times)
    d = rep.consider("sh", mods, plans)
    assert not d.migrate
    assert "optimal" in d.reason


# --- timed dispatch --------------------------------------------------------

def _fire_setup(pipelined):
    mods = [fire("f", 16, 16, 4, 8)]
    plans = partition_network(mods, paper_faithful=True)
    comp = compile_pipelined if pipelined else compile_network
    eng = comp(mods, plans)
    params = init_network(mods, jax.random.PRNGKey(0))
    return mods, plans, eng, eng.prepare(params)


def test_timed_call_pipelined_matches_call_and_stage_count():
    mods, plans, eng, prep = _fire_setup(pipelined=True)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 16))
    ref = np.asarray(eng(prep, x))
    out, times = eng.timed_call(prep, x)
    assert np.array_equal(np.asarray(out), ref)
    assert len(times) == len(eng.stages)
    assert all(t >= 0.0 for t in times)
    # aligned 1:1 with the model-side decomposition
    assert len(times) == len(network_stage_components(mods, plans))
    assert eng.exec_stats()["timed_calls"] == 1


def test_timed_call_monolithic_reports_one_segment():
    _mods, _plans, eng, prep = _fire_setup(pipelined=False)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 16))
    ref = np.asarray(eng(prep, x))
    out, times = eng.timed_call(prep, x)
    assert np.array_equal(np.asarray(out), ref)
    assert len(times) == 1 and times[0] > 0.0
    assert eng.exec_stats()["timed_calls"] == 1


# --- live serving migration (threaded; the faults CI job re-runs this) -----

@pytest.mark.faults
def test_server_migrates_under_injected_fpga_delays():
    """Injected per-stage FPGA delays make the hybrid plan measurably
    slow; the replanner must fit that, migrate the entry to the all-GPU
    plan, and every checked row must bit-match the batch-1 oracle of the
    plan generation that served it."""
    net = "mobilenetv2"
    mods = NETWORKS[net]()
    plans = partition_network(mods, paper_faithful=True)
    params = init_network(mods, jax.random.PRNGKey(0))
    res = 24
    imgs = [0.5 * jax.random.normal(k, (res, res, 3))
            for k in jax.random.split(jax.random.PRNGKey(1), 8)]

    rep = Replanner(objective="latency", threshold=0.15, patience=2,
                    min_samples=2)
    srv = HeteroServer(buckets=(8,), max_wait_ms=2.0, replanner=rep,
                       measure_every=1)
    srv.register(net, mods, plans, params, input_hw=(res, res),
                 pipelined=True)

    rule = FaultRule(op="stage", kind="delay", device="fpga",
                     delay_s=0.004, times=None)
    rounds = []                 # (gen_before, gen_after, plans_after, rows)
    with inject(FaultPlan([rule])):
        with srv:
            entry = srv._entries[net]
            for rnd in range(10):
                g0 = entry.plan_generation
                rows = [f.result()
                        for f in [srv.submit(net, x) for x in imgs]]
                rounds.append((g0, entry.plan_generation,
                               list(entry.plans), rows))
                devs = srv.stats()["engines"][net]["devices"]
                if devs == ("gpu",) and rnd >= 3:
                    break
            st = srv.stats()

    assert st["server"]["replans"] >= 1
    assert st["server"]["measured_batches"] >= 4
    assert st["engines"][net]["devices"] == ("gpu",)
    assert st["engines"][net]["plan_generation"] >= 1
    assert net in st["server"]["fitted"]
    assert st["replan"]["networks"][net]["migrations"] >= 1

    # per-generation bit-match: rows from rounds whose generation was
    # stable check against that generation's own monolithic oracle
    # (oracle calls OUTSIDE the inject scope)
    checked = 0
    for g0, g1, plans_after, rows in rounds:
        if g0 != g1:
            continue            # migration mid-round: generation ambiguous
        oracle = compile_network(mods, plans_after)
        oprep = oracle.prepare(params)
        for x, row in zip(imgs, rows):
            ref = np.asarray(oracle(oprep, np.asarray(x)[None]))[0]
            assert np.array_equal(row, ref)
            checked += 1
    assert checked >= 2 * len(imgs)     # at least one round on each plan
