"""Teacher-forced decode must reproduce forward logits exactly — validates
KV caches, ring buffers, recurrent states, MLA absorption, cross-attention
caches, and prefill->decode handoff for every architecture family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models.lm.model import (decode_cache_from_prefill, decode_step,
                                   forward, init_params, prefill)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = reduced(get_config(arch), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, P0 = 2, 24, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    extra = 0
    if cfg.vlm_patches:
        batch["image_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.vlm_patches, cfg.d_model))
        extra = cfg.vlm_patches
    if cfg.enc_dec:
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (B, 8, cfg.d_model))
    logits, _, _ = forward(cfg, params, batch)

    pb = dict(batch)
    pb["tokens"] = tokens[:, :P0]
    _, caches = prefill(cfg, params, pb)
    cache = decode_cache_from_prefill(cfg, caches, P0 + extra, S + extra)
    step = jax.jit(lambda p, c, t, l: decode_step(cfg, p, c, t, l))
    errs = []
    for t in range(P0, S):
        lg, cache = step(params, cache, tokens[:, t:t + 1],
                         jnp.asarray(t + extra, jnp.int32))
        errs.append(float(jnp.abs(lg[:, 0] - logits[:, t + extra]).max()))
    tol = 2e-4 if arch == "xlstm-125m" else 5e-5
    assert max(errs) < tol, f"{arch}: decode diverges {max(errs):.2e}"
