import os

# smoke tests and benches see ONE device; only launch/dryrun.py forces 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
