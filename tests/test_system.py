"""End-to-end behaviour: training reduces loss; the launchers run; the
hetero-partitioned CNN pipeline works as one system."""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config, reduced
from repro.core.graph import NETWORKS
from repro.core.hetero import init_network, run_network
from repro.core.partitioner import partition_network, summarize
from repro.data import synthetic_batches
from repro.models.lm import model as lm
from repro.optim import make_optimizer, wsd_schedule
from repro.train.steps import TrainState, make_train_step


def test_training_reduces_loss():
    cfg = reduced(get_config("llama3-8b"), n_layers=2, d_model=64, n_heads=2,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype="float32")
    opt = make_optimizer("adamw", lr=wsd_schedule(3e-3, warmup=10))
    step = jax.jit(make_train_step(cfg, opt))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    gen = synthetic_batches(cfg.vocab, 8, 64)
    losses = []
    for s in range(40):
        state, metrics = step(state, gen(s))
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_microbatched_step_matches_full_batch():
    cfg = reduced(get_config("llama3-8b"), n_layers=2, d_model=64, n_heads=2,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype="float32")
    opt = make_optimizer("adamw")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab)}
    s1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, opt, microbatches=4))(state, batch)
    # same gradient in exact arithmetic; fp32 accumulate keeps them close
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_adafactor_trains():
    cfg = reduced(get_config("mistral-large-123b"), n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, d_ff=128, vocab=128,
                  dtype="float32")
    opt = make_optimizer("adafactor", lr=wsd_schedule(2e-2, warmup=5))
    step = jax.jit(make_train_step(cfg, opt))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    gen = synthetic_batches(cfg.vocab, 8, 64)
    losses = [float(step(state, gen(0))[1]["loss"])]
    for s in range(30):
        state, metrics = step(state, gen(s))
    losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_partitioned_networks_end_to_end():
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 224, 224, 3))
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        s = summarize(plans)
        assert s["energy_gain"] > 1.0
        params = init_network(mods, jax.random.PRNGKey(0))
        out = run_network(mods, params, x, plans)
        assert out.shape == (2, 1000)
        assert bool(jnp.isfinite(out).all())


def test_train_launcher_end_to_end(tmp_path):
    from repro.launch.train import main
    loss = main(["--arch", "starcoder2-3b", "--steps", "6", "--batch", "2",
                 "--seq", "32", "--ckpt-dir", str(tmp_path)])
    assert np.isfinite(loss)


def test_serve_launcher_end_to_end():
    from repro.launch.serve import main
    outputs = main(["--arch", "qwen2-moe-a2.7b", "--requests", "2",
                    "--prompt-len", "4", "--gen", "4"])
    assert len(outputs) == 2
    assert all(len(v) == 4 for v in outputs.values())
