"""Pass-pipeline tests: chain-fusion grouping, generalized fused-chain
kernel parity (stride-2 depthwise, pw-dw-pw branches), and fused-chain
coverage of the three paper networks."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.executor import compile_network
from repro.core.graph import NETWORKS, bottleneck, shuffle_unit
from repro.core.hetero import init_network, run_network
from repro.core.partitioner import (candidates, fused_chain_coverage,
                                    partition_network)
from repro.core.passes import build_ir, chain_groups
from repro.kernels.fused_block.ops import fused_chain


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b),
                                                      1e-12))


def _scheme_plan(m, scheme):
    ps = [p for p in candidates(m) if p.scheme == scheme]
    assert ps, f"no {scheme} candidate for {m.kind}"
    return ps[0]


# --- chain grouping --------------------------------------------------------

def test_bottleneck_stride2_fuses_as_pair():
    m = bottleneck("b", 16, 24, 32, 2, 6)          # stride-2 dw
    plan = _scheme_plan(m, "fused_layer")
    groups = [g for g in chain_groups(m, plan) if len(g) > 1]
    assert [[n.name for n in g] for g in groups] == [["dw", "pw_proj"]]
    ir = build_ir(m, plan, use_pallas=False)
    assert len(ir.chains) == 1 and ir.chains[0].stride == 2


def test_shuffle_unit_pw_dw_pw_fuses_as_triple():
    m = shuffle_unit("s", 16, 48, False)
    plan = _scheme_plan(m, "fused_layer")
    groups = [g for g in chain_groups(m, plan) if len(g) > 1]
    assert [[n.name for n in g] for g in groups] == \
        [["b2_pw1", "b2_dw", "b2_pw2"]]
    ir = build_ir(m, plan, use_pallas=False)
    chain = ir.chains[0]
    assert chain.lead is not None and chain.stride == 1


def test_shuffle_down_fpga_fused_forms_two_chains():
    m = shuffle_unit("sd", 16, 48, True)
    plan = _scheme_plan(m, "fpga_fused")
    groups = [[n.name for n in g] for g in chain_groups(m, plan)
              if len(g) > 1]
    assert groups == [["b1_dw", "b1_pw"],
                      ["b2_pw1", "b2_dw", "b2_pw2"]]


def test_full_bottleneck_expand_chain_fuses_as_triple():
    m = bottleneck("b", 16, 24, 24, 1, 6)
    plan = _scheme_plan(m, "fpga_fused")            # pw_exp, dw, pw_proj
    groups = [[n.name for n in g] for g in chain_groups(m, plan)
              if len(g) > 1]
    assert groups == [["pw_exp", "dw", "pw_proj"]]


def test_paper_networks_reach_pair_level_coverage():
    """Every FPGA fused chain in the three paper networks lowers through
    the fusion pass with >= pair-level coverage: no dw->pw adjacency is
    left unfused inside any plan's fused tuple."""
    for net, builder in NETWORKS.items():
        mods = builder()
        for plans in (partition_network(mods, paper_faithful=True),
                      partition_network(mods, objective="edp")):
            plan_by = {p.module: p for p in plans}
            for m in mods:
                p = plan_by[m.name]
                if not p.fused:
                    continue
                groups = chain_groups(m, p)
                fused_names = {n.name for g in groups for n in g
                               if len(g) > 1}
                for g in groups:
                    for a, b in zip(g, g[1:]):
                        assert a.name in fused_names, (net, m.name, a.name)
                        assert b.name in fused_names, (net, m.name, b.name)


# --- parity: new fusion shapes vs the interpreted oracle -------------------

def _force_fused_plans(mods, scheme="fused_layer"):
    plans = []
    for m in mods:
        cands = [p for p in candidates(m) if p.scheme == scheme]
        if not cands:
            cands = [p for p in candidates(m) if p.scheme == "gpu_only"]
        plans.append(cands[0])
    return plans


@pytest.mark.parametrize("net", ["mobilenetv2", "shufflenetv2"])
@pytest.mark.parametrize("batch", [1, 4])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_chain_network_parity(net, batch, use_pallas):
    """Stride-2 depthwise chains (MBv2 down-bottlenecks) and pw-dw-pw
    branches (ShuffleNetV2 units) bit-match the interpreted oracle within
    the quantized tolerance, batch 1 and batched."""
    mods = NETWORKS[net]()
    plans = _force_fused_plans(mods)
    n_chains = sum(
        len(build_ir(m, p, use_pallas).chains)
        for m, p in zip(mods, plans))
    assert n_chains > 0, "plans formed no fused chains — test is vacuous"
    params = init_network(mods, jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (batch, 32, 32, 3))
    eng = compile_network(mods, plans, use_pallas=use_pallas)
    out = eng(eng.prepare(params), x)
    ref = run_network(mods, params, x, plans)
    assert out.shape == ref.shape
    assert _rel(out, ref) < 8e-2
    cos = float(jnp.sum(out * ref)
                / (jnp.linalg.norm(out) * jnp.linalg.norm(ref)))
    assert cos > 0.995


def test_stride2_chain_pallas_matches_xla_lowering():
    m = bottleneck("b", 8, 16, 24, 2, 6)
    plans = [_scheme_plan(m, "fused_layer")]
    params = init_network([m], jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 16))
    outs = {}
    for up in (True, False):
        eng = compile_network([m], plans, use_pallas=up)
        outs[up] = eng(eng.prepare(params), x)
    assert _rel(outs[True], outs[False]) < 1e-4


# --- fused_chain kernel odd shapes -----------------------------------------

@pytest.mark.parametrize("hw,stride,lead", [
    ((9, 7), 2, False), ((8, 8), 1, True), ((11, 9), 2, True)])
def test_fused_chain_kernel_odd_shapes(hw, stride, lead):
    H, W = hw
    C, Cm, Co = 8, 12, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    x = jax.random.normal(ks[0], (2, H, W, C))
    lw = 0.3 * jax.random.normal(ks[1], (C, Cm)) if lead else None
    lb = 0.1 * jax.random.normal(ks[2], (Cm,)) if lead else None
    cmid = Cm if lead else C
    dw = 0.3 * jax.random.normal(ks[3], (3, 3, cmid))
    db = 0.1 * jax.random.normal(ks[4], (cmid,))
    pw = 0.3 * jax.random.normal(ks[5], (cmid, Co))
    pb = 0.1 * jax.random.normal(ks[6], (Co,))
    out = fused_chain(x, lw, lb, dw, db, pw, pb, stride=stride,
                      act_lead="relu", act_dw="none", use_pallas=True)
    ref = fused_chain(x, lw, lb, dw, db, pw, pb, stride=stride,
                      act_lead="relu", act_dw="none", use_pallas=False)
    Ho, Wo = -(-H // stride), -(-W // stride)
    assert out.shape == (2, Ho, Wo, Co)
    assert float(jnp.abs(out - ref).max()) < 1e-4


# --- coverage accounting ---------------------------------------------------

def test_fused_chain_coverage_counts_paper_networks():
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        cov = fused_chain_coverage(mods, plans)
        assert 0.0 <= cov["coverage"] <= 1.0
        assert cov["fused_nodes"] <= cov["fpga_nodes"]
        forced = _force_fused_plans(mods)
        cov_forced = fused_chain_coverage(mods, forced)
        if cov_forced["fpga_nodes"]:
            assert cov_forced["coverage"] > 0.9, (net, cov_forced)
