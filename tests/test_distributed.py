"""Distribution correctness that needs multiple (host) devices — run in
subprocesses so the main test session keeps a single device.

Covers: MoE expert-parallel dispatch vs the dense oracle, elastic restore
across topologies, and sharded-vs-single-device train-step equivalence.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_ep_matches_dense_oracle():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.base import MoEConfig
        from repro.configs import ShardingPolicy
        from repro.models.lm.moe import moe_schema, moe_dense, moe_ep
        from repro.models.lm.common import init_from_schema
        from repro.models.lm.sharding import AxisRules, use_rules

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             devices=jax.devices()[:8])
        m = MoEConfig(n_routed=8, top_k=2, d_ff_expert=32, n_shared=1,
                      d_ff_shared=32, capacity_factor=4.0,
                      ep_axes=("model",), dispatch="ep")
        d = 16
        p = init_from_schema(moe_schema(d, m, 4), jax.random.PRNGKey(0),
                             jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, d)) * 0.5
        y_ref, aux_ref = moe_dense(p, x, m)
        pol = ShardingPolicy()
        rules = AxisRules(mesh, pol, m)
        with mesh, use_rules(rules):
            y_ep, aux_ep = jax.jit(lambda p_, x_: moe_ep(p_, x_, m))(p, x)
        err = float(jnp.abs(y_ref - y_ep).max())
        print("err", err, "aux", float(aux_ref), float(aux_ep))
        assert err < 1e-4, err
        assert abs(float(aux_ref) - float(aux_ep)) < 1e-4
    """)


def test_sharded_train_step_matches_single_device():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.models.lm import model as lm
        from repro.models.lm.sharding import AxisRules, use_rules
        from repro.optim import make_optimizer
        from repro.train.steps import TrainState, make_train_step
        from repro.launch.specs import shardings_of
        import dataclasses

        cfg = reduced(get_config("llama3-8b"), dtype="float32")
        cfg = dataclasses.replace(cfg, policy=dataclasses.replace(
            cfg.policy, seq_parallel=True, fsdp=True))
        opt = make_optimizer("adamw")
        step = make_train_step(cfg, opt)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        state = TrainState(jnp.zeros((), jnp.int32), params,
                           opt.init(params))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)}
        # single device reference
        s1, m1 = jax.jit(step)(state, batch)
        # sharded over a (2, 4) mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             devices=jax.devices()[:8])
        rules = AxisRules(mesh, cfg.policy, cfg.moe)
        with mesh, use_rules(rules):
            s2, m2 = jax.jit(step)(state, batch)
        print("loss", float(m1["loss"]), float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         s1.params, s2.params)
        assert max(jax.tree.leaves(d)) < 1e-4
    """)


def test_elastic_restore_across_topologies(tmp_path):
    run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager

        state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                  "step": jnp.asarray(3)}}
        mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
        sh4 = {{"w": NamedSharding(mesh4, P("data", None)),
                "step": NamedSharding(mesh4, P())}}
        state4 = jax.tree.map(jax.device_put, state, sh4)
        ck = CheckpointManager(r"{tmp_path}")
        ck.save(3, state4, blocking=True)

        mesh8 = jax.make_mesh((8,), ("data",), devices=jax.devices()[:8])
        sh8 = {{"w": NamedSharding(mesh8, P(None, "data")),
                "step": NamedSharding(mesh8, P())}}
        restored, step = ck.restore(None, state, sh8)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert restored["w"].sharding.spec == P(None, "data")
        print("elastic restore ok", step)
    """)


def test_multipod_mesh_constructs():
    run_py("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.size == 256 and m1.axis_names == ("data", "model")
        assert m2.devices.size == 512 and m2.axis_names == ("pod", "data",
                                                            "model")
        print("meshes ok")
    """, devices=512)


def test_moe_ep2_hierarchical_matches_dense_oracle():
    run_py("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs.base import MoEConfig
        from repro.configs import ShardingPolicy
        from repro.models.lm.moe import moe_schema, moe_dense, moe_ep
        from repro.models.lm.common import init_from_schema
        from repro.models.lm.sharding import AxisRules, use_rules

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             devices=jax.devices()[:8])
        m = MoEConfig(n_routed=8, top_k=2, d_ff_expert=32, n_shared=0,
                      capacity_factor=4.0, ep_axes=("data", "model"),
                      dispatch="ep2")
        d = 16
        p = init_from_schema(moe_schema(d, m, 8), jax.random.PRNGKey(0),
                             jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, d)) * 0.5
        y_ref, aux_ref = moe_dense(p, x, m)
        rules = AxisRules(mesh, ShardingPolicy(), m)
        with mesh, use_rules(rules):
            y_ep, aux_ep = jax.jit(lambda p_, x_: moe_ep(p_, x_, m))(p, x)
        err = float(jnp.abs(y_ref - y_ep).max())
        print("ep2 err", err)
        assert err < 1e-4, err
        assert abs(float(aux_ref) - float(aux_ep)) < 1e-4
    """)
