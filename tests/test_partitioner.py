"""Partitioner invariants — hypothesis property tests on the paper's core."""
import pytest

pytest.importorskip("hypothesis")  # optional extra; suite stays green without it

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import costmodel as cm
from repro.core.costmodel import ConvSpec
from repro.core.graph import NETWORKS, fire
from repro.core.partitioner import candidates, partition_network
from repro.core.schedule import split_spec_in

spec_st = st.builds(
    ConvSpec,
    kind=st.sampled_from(["conv", "pwconv", "dwconv"]),
    h=st.sampled_from([7, 14, 28, 56, 112]),
    w=st.sampled_from([7, 14, 28, 56, 112]),
    c_in=st.integers(3, 256),
    c_out=st.integers(8, 256),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
)


@given(spec_st)
@settings(max_examples=60, deadline=None)
def test_costs_positive_and_energy_consistent(spec):
    g = cm.GPU.op_cost(spec)
    f = cm.FPGA.op_cost(spec)
    assert g.latency > 0 and g.energy > 0
    assert f.latency > 0 and f.energy > 0
    # dynamic MAC energy never exceeds total FPGA energy
    assert f.energy >= spec.macs * cm.FPGA.mac_energy


@given(spec_st, st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_fpga_gpar_speeds_up_never_changes_mac_energy(spec, g_par):
    c1 = cm.FPGA.op_cost(spec, 1)
    cg = cm.FPGA.op_cost(spec, g_par)
    assert cg.latency <= c1.latency + 1e-12
    # same MACs executed -> dynamic energy identical; static scales with time
    assert cg.energy <= c1.energy + 1e-12


@given(spec_st, st.floats(0.1, 0.9))
@settings(max_examples=40, deadline=None)
def test_gconv_split_conserves_channels_and_macs(spec, frac):
    if spec.c_in < 4:
        return
    f, g = split_spec_in(spec, frac)
    assert f.c_in + g.c_in == spec.c_in
    assert f.c_in >= 1 and g.c_in >= 1
    if spec.kind != "dwconv":
        assert abs((f.macs + g.macs) - spec.macs) / spec.macs < 1e-6


@pytest.mark.parametrize("net", list(NETWORKS))
def test_network_plans_respect_budgets_and_latency(net):
    mods = NETWORKS[net]()
    plans = partition_network(mods, objective="paper", latency_slack=1.05)
    tot_macs = sum(p.res.macs for p in plans)
    tot_bytes = sum(p.res.bytes for p in plans)
    assert tot_macs <= cm.FPGA.mac_budget
    assert tot_bytes <= cm.FPGA.onchip_bytes
    for p in plans:
        if p.scheme != "gpu_only":
            assert p.cost.latency <= p.gpu_only.latency * 1.05 + 1e-9
            assert p.cost.energy < p.gpu_only.energy


def test_candidates_include_paper_schemes():
    m = fire("fire_t", 28, 128, 32, 128)
    schemes = {p.scheme for p in candidates(m)}
    assert {"gpu_only", "parallel_branch", "gconv_split",
            "fpga_fused"} <= schemes


def test_fig1_full_unroll_ceiling():
    """Paper Fig.1: 64 filters of 5x5 on 224x224x3 fit; 128 do not."""
    ok = ConvSpec("conv", 224, 224, 3, 64, k=5)
    over = ConvSpec("conv", 224, 224, 3, 128, k=5)
    assert cm.FPGA.fits_full_unroll(ok)
    assert not cm.FPGA.fits_full_unroll(over)


def test_objective_modes_order():
    mods = NETWORKS["mobilenetv2"]()
    for objective in ("paper", "latency", "edp"):
        plans = partition_network(mods, objective=objective)
        assert len(plans) == len(mods)
    gpu = partition_network(mods, objective="gpu_only")
    assert all(p.scheme == "gpu_only" for p in gpu)
