"""Wire-decode fuzzing: NO body a client can send makes the decoders
raise anything but ``WireDecodeError`` (a typed 400 on the wire) — and
at the door, a volley of malformed requests on ONE keep-alive socket
answers every request with a typed 4xx and leaves the connection sane
(the next well-formed request still gets its row).

Three layers:

  * deterministic corpus tests (tier-1, no server): every malformed
    JSON-base64 body and binary tensor frame in the corpus raises
    ``WireDecodeError``, never ``TypeError``/``struct.error``/
    ``OverflowError``/raw ``ValueError`` from numpy;
  * framing parity (tier-1): binary and base64 framings of the same
    array decode bit-identical, for every allowlisted dtype, including
    big-endian inputs (normalized to little-endian on the wire);
  * door fuzz (``frontend`` marker): the malformed corpus thrown at a
    live ``FrontDoor`` over one persistent connection — zero 500s, all
    typed 4xx, socket survives (the PR-10 acceptance criterion).

A hypothesis suite extends the corpus with generated garbage when
hypothesis is installed (the CI frontend job); the deterministic corpus
keeps the guarantee tested in environments without it.
"""
import base64
import concurrent.futures
import http.client
import json
import socket
import struct

import numpy as np
import pytest

from repro.frontend import FrontDoor, LocalBackend, ServerThread, wire
from repro.serving.metrics import ServerMetrics

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _b64(n: int) -> str:
    return base64.b64encode(b"\x00" * n).decode()


def _good() -> dict:
    return {"shape": [2, 3], "dtype": "<f4", "data": _b64(24)}


# every entry must raise WireDecodeError — nothing else
BAD_ARRAY_BODIES = [
    [1, 2, 3],                                     # not an object
    "just a string",
    None,
    {},                                            # missing fields
    {"shape": [2], "dtype": "<f4"},                # no data
    {**_good(), "dtype": "float99"},               # unknown dtype name
    {**_good(), "dtype": "<f9"},
    {**_good(), "dtype": "object"},                # never executable dtypes
    {**_good(), "dtype": "O"},
    {**_good(), "dtype": "|S8"},
    {**_good(), "dtype": "complex64"},             # not in the allowlist
    {**_good(), "dtype": 123},
    {**_good(), "dtype": None},
    {**_good(), "shape": "nope"},                  # non-list shapes
    {**_good(), "shape": 6},
    {**_good(), "shape": {"n": 6}},
    {**_good(), "shape": [2, "3"]},                # non-int dims
    {**_good(), "shape": [2.5, 4]},
    {**_good(), "shape": [True, 6]},               # bool is not a dim
    {**_good(), "shape": [-1, 4]},                 # negative dims
    {**_good(), "shape": [2 ** 31, 2 ** 31]},      # shape overflow
    {**_good(), "shape": [1] * 17},                # ndim bomb
    {**_good(), "data": 123},                      # non-string data
    {**_good(), "data": "!!not-base64!!"},         # invalid base64
    {**_good(), "data": _b64(23)},                 # truncated payload
    {**_good(), "data": _b64(25)},                 # overlong payload
    {"shape": [2, 3], "dtype": "<f4", "data": ""},
]

_H = struct.Struct("<4sBBH")
BAD_TENSOR_FRAMES = [
    b"",                                           # empty
    b"XT0",                                        # truncated magic
    b"NOPE" + b"\x00" * 16,                        # wrong magic
    _H.pack(b"XT01", 200, 1, 0) + struct.pack("<I", 1) + b"\x00" * 4,
    _H.pack(b"XT01", 9, 20, 0) + b"\x00" * 80,     # ndim bomb
    _H.pack(b"XT01", 9, 2, 0) + struct.pack("<I", 2),   # truncated shape
    _H.pack(b"XT01", 9, 1, 0) + struct.pack("<I", 3) + b"\x00" * 8,
    _H.pack(b"XT01", 9, 1, 0) + struct.pack("<I", 3) + b"\x00" * 16,
    _H.pack(b"XT01", 9, 2, 0)                      # u32 dims that overflow
    + struct.pack("<2I", 0xFFFFFFFF, 0xFFFFFFFF),  # the byte-size bound
]


@pytest.mark.parametrize("body", BAD_ARRAY_BODIES,
                         ids=range(len(BAD_ARRAY_BODIES)))
def test_malformed_array_bodies_raise_typed(body):
    with pytest.raises(wire.WireDecodeError):
        wire.decode_array(body)
    status, reply, _h = wire.error_reply(wire.WireDecodeError("x"))
    assert status == 400 and reply["error"] == "bad_request"
    assert reply["retryable"] is False


@pytest.mark.parametrize("frame", BAD_TENSOR_FRAMES,
                         ids=range(len(BAD_TENSOR_FRAMES)))
def test_malformed_tensor_frames_raise_typed(frame):
    with pytest.raises(wire.WireDecodeError):
        wire.decode_tensor(frame)


def test_tensor_frames_reject_non_bytes():
    for bad in ("a string", 123, {"a": 1}, [1, 2], None):
        with pytest.raises(wire.WireDecodeError):
            wire.decode_tensor(bad)


# --- framing parity ---------------------------------------------------------

def test_binary_and_base64_framings_are_bit_identical():
    rng = np.random.RandomState(0)
    for name in wire.WIRE_DTYPES:
        x = (rng.randn(3, 4, 5) * 50).astype(name)
        via_json = wire.decode_array(wire.encode_array(x))
        via_bin = wire.decode_tensor(wire.encode_tensor(x))
        assert via_json.tobytes() == via_bin.tobytes() == x.tobytes(), name
        assert via_json.shape == via_bin.shape == x.shape
        assert via_json.dtype == via_bin.dtype == x.dtype


def test_encode_pins_little_endian_and_decode_byteswaps():
    x = np.arange(6, dtype=np.float32).reshape(2, 3)
    be = x.astype(">f4")
    # a big-endian INPUT array is byteswapped on encode, not emitted raw
    for enc in (wire.encode_array(be), wire.encode_array(x)):
        assert enc["dtype"] == "<f4"
        assert base64.b64decode(enc["data"]) == x.astype("<f4").tobytes()
    # an explicit big-endian wire body decodes byteswapped-to-native
    d = {"shape": [2, 3], "dtype": ">f4", "data":
         base64.b64encode(be.tobytes()).decode()}
    y = wire.decode_array(d)
    assert np.array_equal(y, x) and y.dtype == np.dtype("float32")
    # both framings agree byte-for-byte on the big-endian input too
    assert wire.decode_tensor(wire.encode_tensor(be)).tobytes() \
        == x.astype("<f4").tobytes()


def test_zero_size_arrays_cross_both_framings():
    for shape in ((0,), (0, 3), (2, 0, 4)):
        x = np.zeros(shape, dtype=np.float32)
        assert wire.decode_array(wire.encode_array(x)).shape == shape
        assert wire.decode_tensor(wire.encode_tensor(x)).shape == shape


def test_unsupported_dtype_is_rejected_at_encode():
    with pytest.raises(wire.WireDecodeError):
        wire.encode_array(np.zeros(2, dtype=np.complex64))
    with pytest.raises(wire.WireDecodeError):
        wire.encode_tensor(np.array(["a", "b"]))


# --- hypothesis extension (runs where hypothesis is installed) --------------

if HAVE_HYPOTHESIS:
    json_scalars = st.one_of(st.none(), st.booleans(),
                             st.integers(-2 ** 63, 2 ** 63),
                             st.floats(allow_nan=False), st.text(max_size=8))

    @settings(max_examples=200, deadline=None)
    @given(st.dictionaries(
        st.sampled_from(["shape", "dtype", "data", "x"]),
        st.one_of(json_scalars, st.lists(json_scalars, max_size=6))))
    def test_fuzzed_array_bodies_never_escape_typed(d):
        try:
            out = wire.decode_array(d)
        except wire.WireDecodeError:
            return
        assert isinstance(out, np.ndarray)   # only other legal outcome

    @settings(max_examples=200, deadline=None)
    @given(st.binary(max_size=256))
    def test_fuzzed_tensor_frames_never_escape_typed(buf):
        try:
            out = wire.decode_tensor(buf)
        except wire.WireDecodeError:
            return
        assert isinstance(out, np.ndarray)

    @settings(max_examples=100, deadline=None)
    @given(st.sampled_from(wire.WIRE_DTYPES),
           st.lists(st.integers(0, 5), min_size=0, max_size=4),
           st.integers(0, 2 ** 32))
    def test_roundtrip_parity_property(name, shape, seed):
        rng = np.random.RandomState(seed % (2 ** 32))
        x = (rng.randn(*shape) * 100).astype(name)
        a = wire.decode_array(wire.encode_array(x))
        b = wire.decode_tensor(wire.encode_tensor(x))
        assert a.tobytes() == b.tobytes() == x.tobytes()
        assert a.shape == b.shape == x.shape


# --- the door under fire (frontend marker: sockets, no jax compile) ---------

class _FakeServer:
    """A ``HeteroServer`` stand-in: real ``ServerMetrics``, instant rows
    — so the door fuzz exercises the REAL ``LocalBackend``/``FrontDoor``
    decode-and-answer path without paying a compile."""

    def __init__(self):
        self.state = "running"
        self.metrics = ServerMetrics()

    def submit(self, name, x, *, priority=1, deadline_ms=None):
        if name != "tiny":
            raise KeyError(f"unknown network {name!r}")
        fut = concurrent.futures.Future()
        fut.set_result(np.asarray(x, dtype=np.float32).reshape(-1)[:4]
                       .copy())
        return fut

    def shutdown(self, budget_s):
        self.state = "closed"


def _fuzz_door():
    return ServerThread(FrontDoor(LocalBackend(_FakeServer()))).start()


def _volley_bodies():
    """(body_bytes, headers) for every malformed request in the corpus,
    in both framings."""
    out = []
    for bad in BAD_ARRAY_BODIES:
        out.append((json.dumps({"network": "tiny",
                                **(bad if isinstance(bad, dict) else {}),
                                "_": bad if not isinstance(bad, dict)
                                else None}).encode(),
                    {"Content-Type": "application/json"}))
    out.append((b"this is not json {", {"Content-Type":
                                        "application/json"}))
    out.append((b"[1, 2, 3]", {"Content-Type": "application/json"}))
    for frame in BAD_TENSOR_FRAMES:
        out.append((frame, {"Content-Type": wire.TENSOR_CONTENT_TYPE,
                            "X-Network": "tiny"}))
    # binary frame with no X-Network, and with a junk priority header
    out.append((wire.encode_tensor(np.zeros(4, np.float32)),
                {"Content-Type": wire.TENSOR_CONTENT_TYPE}))
    out.append((wire.encode_tensor(np.zeros(4, np.float32)),
                {"Content-Type": wire.TENSOR_CONTENT_TYPE,
                 "X-Network": "tiny", "X-Deadline-Ms": "soon"}))
    return out


@pytest.mark.frontend
def test_malformed_volley_is_all_typed_4xx_and_socket_survives():
    h = _fuzz_door()
    try:
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=30)
        statuses = []
        for body, headers in _volley_bodies():
            conn.request("POST", "/v1/infer", body=body, headers=headers)
            r = conn.getresponse()
            reply = json.loads(r.read())
            statuses.append(r.status)
            assert 400 <= r.status < 500, (r.status, reply)
            assert reply["retryable"] is False
            assert "Traceback" not in json.dumps(reply)
        assert statuses, "empty volley"
        # the same socket still serves a well-formed request
        x = np.arange(8, dtype=np.float32)
        body, headers = wire.infer_request("tiny", x)
        conn.request("POST", "/v1/infer", body=body, headers=headers)
        r = conn.getresponse()
        assert r.status == 200
        row = wire.decode_array(json.loads(r.read())["result"])
        assert np.array_equal(row, x[:4])
        assert h.door.connections == 1, "a 4xx must not burn the socket"
        conn.close()
    finally:
        h.stop(drain=False)


@pytest.mark.frontend
def test_wrong_content_length_stays_typed():
    """A Content-Length shorter than the body truncates the JSON parse:
    typed 400, and the response still arrives on the raw socket."""
    h = _fuzz_door()
    try:
        payload = json.dumps(wire.infer_payload(
            "tiny", np.zeros(4, np.float32))).encode()
        with socket.create_connection(("127.0.0.1", h.port),
                                      timeout=10) as s:
            head = (f"POST /v1/infer HTTP/1.1\r\nHost: x\r\n"
                    f"Content-Type: application/json\r\n"
                    f"Content-Length: {len(payload) // 2}\r\n"
                    f"Connection: close\r\n\r\n").encode()
            s.sendall(head + payload[:len(payload) // 2])
            reply = b""
            while b"\r\n\r\n" not in reply:
                chunk = s.recv(4096)
                if not chunk:
                    break
                reply += chunk
        assert b" 400 " in reply.split(b"\r\n", 1)[0]
        assert b"bad_request" in reply or b"Content-Length" in reply
    finally:
        h.stop(drain=False)


@pytest.mark.frontend
def test_oversize_content_length_is_413_and_closes():
    h = _fuzz_door()
    try:
        with socket.create_connection(("127.0.0.1", h.port),
                                      timeout=10) as s:
            s.sendall((f"POST /v1/infer HTTP/1.1\r\nHost: x\r\n"
                       f"Content-Length: {wire.MAX_BODY_BYTES + 1}\r\n"
                       f"\r\n").encode())
            reply = s.recv(65536)
            assert b" 413 " in reply.split(b"\r\n", 1)[0]
            assert b"Connection: close" in reply
    finally:
        h.stop(drain=False)


@pytest.mark.frontend
def test_bad_requests_counter_tracks_the_failure_class():
    h = _fuzz_door()
    try:
        bad = json.dumps({"network": "tiny", "shape": [4], "dtype": "<f4",
                          "data": _b64(9)}).encode()
        for _ in range(3):
            conn = http.client.HTTPConnection("127.0.0.1", h.port,
                                              timeout=10)
            conn.request("POST", "/v1/infer", body=bad,
                         headers={"Content-Type": "application/json"})
            assert conn.getresponse().status == 400
            conn.close()
        conn = http.client.HTTPConnection("127.0.0.1", h.port, timeout=10)
        conn.request("GET", "/metrics")
        snap = json.loads(conn.getresponse().read())
        conn.close()
        assert snap["bad_requests"] >= 3
    finally:
        h.stop(drain=False)
