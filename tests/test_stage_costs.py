"""Edge cases of the stage-cost decomposition (``schedule.stage_components``
/ ``plan_stage_costs`` / ``network_stage_components``) and the pipeline
makespan model (``pipelined_cost``): single-device plans, empty and 1-node
networks, and the sum identities the online fitter (``repro.core.replan``)
relies on — the stage decomposition must account for exactly the cost the
monolithic model charges, no more, no less.
"""
import pytest

from repro.core.costmodel import Cost, ZERO, CostScales
from repro.core.graph import NETWORKS, ModuleGraph, fire
from repro.core.partitioner import ACT_BYTES, partition_network
from repro.core.schedule import (Plan, fpga_chain_cost, gpu_cost,
                                 module_gpu_only, network_stage_components,
                                 pipelined_cost, plan_stage_costs,
                                 stage_components)


def _solo():
    """A 1-node network: the fire module's squeeze conv on its own."""
    n = fire("f", 16, 16, 4, 8).nodes[0]
    return ModuleGraph("solo", "stem", [n], output=n.name)


# --- single-device plans -> one stage --------------------------------------

def test_planless_module_is_one_gpu_stage():
    m = fire("f", 16, 16, 4, 8)
    stages = plan_stage_costs(m, None)
    assert len(stages) == 1
    dev, cost = stages[0]
    assert dev == "gpu"
    assert cost.latency == pytest.approx(module_gpu_only(m).latency)
    assert cost.energy == pytest.approx(module_gpu_only(m).energy)


def test_all_gpu_plan_collapses_to_one_stage():
    m = fire("f", 16, 16, 4, 8)
    plan = Plan(module=m.name, kind=m.kind, scheme="gpu_only",
                assign={n.name: "gpu" for n in m.nodes})
    stages = plan_stage_costs(m, plan)
    assert len(stages) == 1
    assert stages[0][0] == "gpu"
    assert stages[0][1].latency == pytest.approx(
        module_gpu_only(m).latency)


def test_all_fpga_plan_is_one_stage_paying_pcie_once():
    m = _solo()
    plan = Plan(module=m.name, kind=m.kind, scheme="fpga",
                assign={n.name: "fpga" for n in m.nodes})
    comps = stage_components(m, plan)
    assert len(comps) == 1 and comps[0].device == "fpga"
    n = m.nodes[0]
    expect = fpga_chain_cost([n], n.spec.in_bytes(1), n.spec.out_bytes(1))
    assert comps[0].cost().latency == pytest.approx(expect.latency)
    assert comps[0].xfer.latency > 0          # honest-accounting PCIe


def test_single_stage_pipeline_has_no_overlap_win():
    # one stage cannot overlap anything: makespan == n * serial, exactly
    stage = Cost(2e-3, 5e-3)
    for n in (1, 4, 33):
        got = pipelined_cost([stage], n)
        assert got.latency == pytest.approx(n * stage.latency)
        assert got.energy == pytest.approx(n * stage.energy)


# --- empty / 1-node networks -----------------------------------------------

def test_empty_network_decomposition_is_a_free_gpu_stage():
    comps = network_stage_components([], None)
    assert [sc.device for sc in comps] == ["gpu"]
    assert comps[0].cost() == ZERO


def test_pipelined_cost_of_no_stages_is_zero():
    assert pipelined_cost([], 1) == ZERO
    assert pipelined_cost([], 16) == ZERO


def test_one_node_network_sums_to_monolithic():
    m = _solo()
    comps = network_stage_components([m], None)
    assert sum(sc.latency() for sc in comps) == pytest.approx(
        module_gpu_only(m).latency)
    assert sum(sc.cost().energy for sc in comps) == pytest.approx(
        module_gpu_only(m).energy)


# --- sum identities --------------------------------------------------------

def test_stage_sum_matches_gpu_monolithic_per_module():
    # under a hybrid plan the GPU stages alone must sum to the gpu_cost of
    # exactly the nodes the plan left on the GPU (no double counting)
    mods = NETWORKS["mobilenetv2"]()
    plans = partition_network(mods, paper_faithful=True)
    by = {p.module: p for p in plans}
    for m in mods:
        p = by[m.name]
        comps = stage_components(m, p, ACT_BYTES)
        gpu_nodes = [n for n in m.nodes
                     if not (p.assign.get(n.name) == "fpga"
                             or n.name in p.gconv)]
        got = sum((sc.cost() for sc in comps if sc.device == "gpu"),
                  ZERO)
        assert got.latency == pytest.approx(
            gpu_cost(gpu_nodes).latency, rel=1e-9, abs=1e-15)


def test_network_merge_preserves_totals():
    # merging segments across module boundaries must not change the
    # serial latency/energy total — only the stage count
    mods = NETWORKS["squeezenet"]()
    plans = partition_network(mods, paper_faithful=True)
    by = {p.module: p for p in plans}
    per_module = [sc for m in mods
                  for sc in stage_components(m, by.get(m.name), ACT_BYTES)]
    merged = network_stage_components(mods, plans, ACT_BYTES)
    assert len(merged) <= len(per_module) + 1
    assert sum(sc.latency() for sc in merged) == pytest.approx(
        sum(sc.latency() for sc in per_module))
    assert sum(sc.cost().energy for sc in merged) == pytest.approx(
        sum(sc.cost().energy for sc in per_module))
    # devices strictly alternate after the merge
    devs = [sc.device for sc in merged]
    assert all(a != b for a, b in zip(devs, devs[1:]))


def test_pipeline_fill_equals_serial_sum():
    # n=1: the fill IS the serial schedule — pipelining a single input
    # must price identically to not pipelining it
    mods = NETWORKS["shufflenetv2"]()
    plans = partition_network(mods, paper_faithful=True)
    stages = [sc.cost() for sc in network_stage_components(mods, plans)]
    assert pipelined_cost(stages, 1).latency == pytest.approx(
        sum(c.latency for c in stages))
    # n>1: fill + (n-1) beats of the slowest stage, and overlap never
    # beats the physics of the slowest stage
    n = 8
    got = pipelined_cost(stages, n)
    beat = max(c.latency for c in stages)
    assert got.latency == pytest.approx(
        sum(c.latency for c in stages) + (n - 1) * beat)
    assert got.latency >= n * beat
    assert got.latency <= n * sum(c.latency for c in stages)
    assert got.energy == pytest.approx(
        n * sum(c.energy for c in stages))


def test_scales_touch_latency_only():
    mods = NETWORKS["mobilenetv2"]()
    plans = partition_network(mods, paper_faithful=True)
    s = CostScales(gpu=2.0, fpga=3.0, xfer=5.0)
    for sc in network_stage_components(mods, plans):
        scaled, ident = sc.cost(s), sc.cost()
        assert scaled.energy == pytest.approx(ident.energy)
        if sc.device == "gpu":
            assert scaled.latency == pytest.approx(
                ident.latency * 2.0)       # gpu stages carry no xfer term
        else:
            assert scaled.latency == pytest.approx(
                3.0 * sc.comp.latency + 5.0 * sc.xfer.latency)
    # identity scales reproduce the unscaled paper model bit-for-bit
    m = mods[0]
    assert plan_stage_costs(m, None, scales=CostScales()) == \
        plan_stage_costs(m, None)
