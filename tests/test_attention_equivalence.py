"""Property tests: every attention execution strategy computes the SAME
function — chunked flash, hierarchical decomposition, banded local, and
GQA with expanded KV all reduce to plain masked softmax attention."""
import pytest

pytest.importorskip("hypothesis")  # optional extra; suite stays green without it

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
from hypothesis import given, settings

from repro.models.lm.attention import gqa_attention


def _ref(q, k, v, causal=True, window=None):
    B, S, H, D = q.shape
    Kh = k.shape[2]
    g = H // Kh
    kf = jnp.repeat(k, g, axis=2)
    vf = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / jnp.sqrt(jnp.float32(D))
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


def _qkv(seed, B, S, H, Kh, D):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, D)),
            jax.random.normal(ks[1], (B, S, Kh, D)),
            jax.random.normal(ks[2], (B, S, Kh, D)))


@given(st.integers(0, 1000), st.sampled_from([64, 128, 256]),
       st.sampled_from([(4, 4), (4, 2), (8, 2)]))
@settings(max_examples=12, deadline=None)
def test_chunked_equals_reference(seed, S, heads):
    H, Kh = heads
    q, k, v = _qkv(seed, 2, S, H, Kh, 16)
    out = gqa_attention(q, k, v, causal=True, impl="chunked",
                        q_chunk=32, kv_chunk=32)
    assert float(jnp.abs(out - _ref(q, k, v)).max()) < 1e-4


@pytest.mark.parametrize("levels", [1, 2, 3])
@pytest.mark.parametrize("S", [128, 256])
def test_hierarchical_equals_plain(levels, S):
    q, k, v = _qkv(7, 2, S, 4, 2, 16)
    plain = gqa_attention(q, k, v, causal=True, impl="chunked",
                          q_chunk=32, kv_chunk=32)
    hier = gqa_attention(q, k, v, causal=True, impl="chunked",
                         q_chunk=32, kv_chunk=32, hierarchy_levels=levels)
    assert float(jnp.abs(plain - hier).max()) < 1e-4


@pytest.mark.parametrize("window", [32, 64])
def test_local_banded_equals_masked_reference(window):
    S = 256
    q, k, v = _qkv(11, 2, S, 4, 1, 16)
    out = gqa_attention(q, k, v, causal=True, window=window, impl="local")
    ref = _ref(q, k, v, causal=True, window=window)
    assert float(jnp.abs(out - ref).max()) < 1e-4


def test_expanded_kv_equals_gqa():
    """jnp.repeat-expanded KV (the §Perf cell-1 change) is semantically
    exactly GQA."""
    q, k, v = _qkv(13, 2, 128, 8, 2, 16)
    gqa = gqa_attention(q, k, v, causal=True, impl="chunked")
    kf, vf = jnp.repeat(k, 4, axis=2), jnp.repeat(v, 4, axis=2)
    mha = gqa_attention(q, kf, vf, causal=True, impl="chunked")
    assert float(jnp.abs(gqa - mha).max()) < 1e-5
