"""Pipelined stage execution: stage-cut correctness (0 / 1 / many device
boundaries), pipelined-vs-monolithic bit-match across networks, schemes and
batch sizes, depth-k in-flight ordering under deadline flush, input-buffer
donation accounting, and the pipelined cost estimate."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core.costmodel import pipelined_latency
from repro.core.executor import (compile_network, compile_pipelined,
                                 plan_signature)
from repro.core.graph import NETWORKS, bottleneck, fire, shuffle_unit
from repro.core.hetero import init_network
from repro.core.partitioner import (candidates, partition_network,
                                    pipelined_summary)
from repro.core.schedule import pipelined_cost, plan_stage_costs
from repro.serving import HeteroServer

RES = 24


def _scheme_plans(m, scheme):
    ps = [p for p in candidates(m) if p.scheme == scheme]
    assert ps, f"no {scheme} candidate for {m.kind}"
    return [ps[0]]


def _engines(mods, plans):
    mono = compile_network(mods, plans, use_pallas=False)
    pipe = compile_pipelined(mods, plans, use_pallas=False)
    params = init_network(mods, jax.random.PRNGKey(0))
    return mono, pipe, mono.prepare(params)


def _x(mods, batch, res=16, seed=1):
    c_in = mods[0].nodes[0].spec.c_in
    return 0.5 * jax.random.normal(jax.random.PRNGKey(seed),
                                   (batch, res, res, c_in))


# --- stage-cut correctness: 0 / 1 / many boundaries ------------------------

def test_zero_boundaries_single_stage():
    """An all-GPU plan (and plans=None) has no device edges to cut at —
    the pipeline degenerates to one stage."""
    mods = [fire("f", 16, 64, 16, 64)]
    pipe = compile_pipelined(mods, None, use_pallas=False)
    assert len(pipe.stages) == 1
    plans = partition_network(NETWORKS["squeezenet"](),
                              objective="gpu_only")
    pipe2 = compile_pipelined(NETWORKS["squeezenet"](), plans,
                              use_pallas=False)
    assert len(pipe2.stages) == 1
    assert pipe2.stages[0].device == "gpu"


def test_one_boundary_two_stages():
    """fpga_fused fire: all convs FPGA, concat on GPU -> exactly one
    FPGA->GPU edge, two stages."""
    m = fire("f", 16, 64, 16, 64)
    pipe = compile_pipelined([m], _scheme_plans(m, "fpga_fused"),
                             use_pallas=False)
    assert [s.device for s in pipe.stages] == ["fpga", "gpu"]


def test_many_boundaries_alternate_and_merge():
    """Full paper-faithful MobileNetV2: many cuts; stages must strictly
    alternate devices (adjacent same-device segments merge, including
    across module boundaries)."""
    mods = NETWORKS["mobilenetv2"]()
    plans = partition_network(mods, paper_faithful=True)
    pipe = compile_pipelined(mods, plans, use_pallas=False)
    devices = [s.device for s in pipe.stages]
    assert len(devices) > 4
    assert all(a != b for a, b in zip(devices, devices[1:]))
    assert "fpga" in devices and "gpu" in devices


def test_stage_envs_carry_exact_liveness():
    """Each stage's declared live_out is its successor's live_in, and the
    final stage yields only the network output."""
    mods = NETWORKS["shufflenetv2"]()
    plans = partition_network(mods, paper_faithful=True)
    pipe = compile_pipelined(mods, plans, use_pallas=False)
    for a, b in zip(pipe.stages, pipe.stages[1:]):
        assert a.live_out == b.live_in
    assert pipe.stages[-1].live_out == ("__out",)


# --- bit-match vs the monolithic engine ------------------------------------

@pytest.mark.parametrize("net", list(NETWORKS))
@pytest.mark.parametrize("batch", [1, 4, 32])
def test_network_pipelined_bitmatch(net, batch):
    mods = NETWORKS[net]()
    plans = partition_network(mods, paper_faithful=True)
    mono, pipe, prep = _engines(mods, plans)
    x = _x(mods, batch, res=RES)
    assert bool(jnp.all(mono(prep, x) == pipe(prep, x)))


SCHEME_CASES = [
    ("fire", lambda: fire("f", 16, 64, 16, 64),
     ["gpu_only", "fpga_fused", "parallel_branch", "gconv_split"]),
    ("bottleneck", lambda: bottleneck("b", 16, 24, 24, 1, 6),
     ["gpu_only", "fpga_fused", "dwconv_split", "fused_layer"]),
    ("shuffle_unit", lambda: shuffle_unit("s", 16, 48, False),
     ["fpga_fused", "dwconv_split", "fused_layer"]),
    ("shuffle_unit_down", lambda: shuffle_unit("sd", 16, 48, True),
     ["parallel_branch"]),
]


@pytest.mark.parametrize("kind,builder,schemes", SCHEME_CASES,
                         ids=[c[0] for c in SCHEME_CASES])
def test_scheme_pipelined_bitmatch(kind, builder, schemes):
    for scheme in schemes:
        m = builder()
        plans = _scheme_plans(m, scheme)
        mono, pipe, prep = _engines([m], plans)
        for batch in (1, 4):
            x = _x([m], batch, seed=batch)
            assert bool(jnp.all(mono(prep, x) == pipe(prep, x))), \
                f"{kind}/{scheme} batch {batch}"


def test_run_many_matches_per_batch_calls_any_depth():
    mods = NETWORKS["mobilenetv2"]()
    plans = partition_network(mods, paper_faithful=True)
    mono, pipe, prep = _engines(mods, plans)
    xs = [_x(mods, 2, res=RES, seed=i) for i in range(5)]
    refs = [mono(prep, x) for x in xs]
    for depth in (1, 2, 4):
        outs = pipe.run_many(prep, xs, depth=depth)
        assert len(outs) == len(xs)
        for o, r in zip(outs, refs):
            assert bool(jnp.all(o == r))


def test_pipelined_caller_input_never_donated():
    """Inter-stage envs are donated, but the caller's input array must
    survive both __call__ and run_many."""
    m = bottleneck("b", 16, 24, 24, 1, 6)
    plans = _scheme_plans(m, "dwconv_split")
    _mono, pipe, prep = _engines([m], plans)
    x = _x([m], 2)
    pipe(prep, x)
    pipe.run_many(prep, [x, x], depth=2)
    assert bool(jnp.all(x == x))          # would raise if x were deleted
    stats = pipe.exec_stats()
    assert stats["stages"] >= 3
    assert stats["donated_calls"] >= 1 and stats["donated_bytes"] > 0


def test_pipelined_signature_and_cache_separate_from_monolithic():
    mods = [fire("f", 8, 16, 4, 8)]
    mono = compile_network(mods, None, use_pallas=False)
    pipe = compile_pipelined(mods, None, use_pallas=False)
    assert pipe is not mono
    assert pipe.signature != mono.signature
    assert pipe.signature[0] == "pipelined"
    assert pipe.signature[1:] == plan_signature(mods, None, False)
    assert compile_pipelined(mods, None, use_pallas=False) is pipe


def test_pipelined_with_calibrated_plans_bitmatch():
    from dataclasses import replace
    mods = NETWORKS["mobilenetv2"]()
    plans = [replace(p, calibrate=True)
             for p in partition_network(mods, paper_faithful=True)]
    mono = compile_network(mods, plans, use_pallas=False)
    pipe = compile_pipelined(mods, plans, use_pallas=False)
    params = init_network(mods, jax.random.PRNGKey(0))
    calib = _x(mods, 4, res=RES, seed=9)
    prep = mono.prepare(params, calib)
    x = _x(mods, 3, res=RES)
    assert bool(jnp.all(mono(prep, x) == pipe(prep, x)))


# --- monolithic donation (serving hot path) --------------------------------

def test_donated_call_same_bits_and_consumes_buffer():
    m = fire("f", 8, 16, 4, 8)
    eng = compile_network([m], None, use_pallas=False)
    params = init_network([m], jax.random.PRNGKey(0))
    prep = eng.prepare(params)
    x = _x([m], 2, res=8)
    ref = eng(prep, x)
    xd = jnp.array(x)                     # engine-owned copy to donate
    out = eng(prep, xd, donate=True)
    assert bool(jnp.all(out == ref))
    stats = eng.exec_stats()
    assert stats["donated_calls"] == 1
    assert stats["donated_bytes"] == x.nbytes
    # the non-donating path must leave caller arrays untouched
    assert bool(jnp.all(x == x))


# --- serving: in-flight depth ----------------------------------------------

def _serve_case(in_flight, max_wait_ms=15.0, pipelined=False):
    m = bottleneck("b", 16, 24, 24, 1, 6)
    plans = _scheme_plans(m, "dwconv_split")
    params = init_network([m], jax.random.PRNGKey(1))
    server = HeteroServer(buckets=(1, 4), max_wait_ms=max_wait_ms,
                          in_flight=in_flight)
    server.register("b", [m], plans, params, input_hw=(16, 16),
                    pipelined=pipelined)
    eng = compile_network([m], plans)
    return server, eng, eng.prepare(params)


def test_in_flight_ordering_under_deadline_flush():
    """Trickled submissions force deadline flushes; with depth-3 dispatch
    every future must still resolve to its own request's row (FIFO
    completion preserves per-request ordering)."""
    server, eng, prep = _serve_case(in_flight=3, max_wait_ms=5.0)
    imgs = [jax.random.normal(jax.random.PRNGKey(i), (16, 16, 24))
            for i in range(12)]
    with server:
        futs = []
        for i, x in enumerate(imgs):
            futs.append(server.submit("b", x))
            if i % 3 == 2:
                time.sleep(0.012)        # let the deadline fire mid-stream
        outs = [f.result(timeout=60) for f in futs]
    for x, out in zip(imgs, outs):
        assert bool(jnp.all(out == eng(prep, x[None])[0]))
    snap = server.metrics.snapshot()
    assert snap["completed"] == len(imgs) and snap["failed"] == 0
    assert snap["deadline_flushes"] >= 1


def test_in_flight_shutdown_drains_pending_completions():
    server, eng, prep = _serve_case(in_flight=4, max_wait_ms=2.0)
    imgs = [jax.random.normal(jax.random.PRNGKey(50 + i), (16, 16, 24))
            for i in range(10)]
    server.start()
    futs = [server.submit("b", x) for x in imgs]
    server.shutdown()
    for x, f in zip(imgs, futs):
        assert bool(jnp.all(f.result(timeout=60)
                            == eng(prep, x[None])[0]))


def test_pipelined_serving_bitmatch():
    """register(pipelined=True) serves through the stage engine; rows must
    still bit-match batch-1 monolithic calls."""
    server, eng, prep = _serve_case(in_flight=2, pipelined=True)
    assert server.stats()["engines"]["b"]["pipelined"]
    imgs = [jax.random.normal(jax.random.PRNGKey(80 + i), (16, 16, 24))
            for i in range(6)]
    with server:
        futs = [server.submit("b", x) for x in imgs]
        outs = [f.result(timeout=60) for f in futs]
    for x, out in zip(imgs, outs):
        assert bool(jnp.all(out == eng(prep, x[None])[0]))


# --- pipelined cost estimate -----------------------------------------------

def test_pipelined_latency_fill_plus_beats():
    assert pipelined_latency([], 5) == 0.0
    assert pipelined_latency([2.0, 1.0], 1) == pytest.approx(3.0)
    # fill (3) + 3 extra beats of the slowest stage (2 each)
    assert pipelined_latency([2.0, 1.0], 4) == pytest.approx(9.0)


def test_plan_stage_costs_match_cut_rule():
    m = bottleneck("b", 16, 24, 24, 1, 6)     # residual module
    plans = _scheme_plans(m, "dwconv_split")  # fpga, gpu, fpga + res add
    segs = plan_stage_costs(m, plans[0])
    assert [d for d, _c in segs] == ["fpga", "gpu", "fpga", "gpu"]
    assert [d for d, _c in plan_stage_costs(m, None)] == ["gpu", "gpu"]
    total = pipelined_cost([c for _d, c in segs], 1)
    assert total.latency == pytest.approx(
        sum(c.latency for _d, c in segs))


def test_pipelined_summary_matches_cut_for_fpga_tail_and_residual():
    """Modules ending on FPGA nodes (and residual modules) hand back to
    the GPU in the executable cut — the cost model must count those
    stages too, not just conv-node segments."""
    m = bottleneck("b", 16, 24, 24, 1, 6)
    for scheme in ("fpga_fused", "fused_layer"):
        plans = _scheme_plans(m, scheme)
        pipe = compile_pipelined([m], plans, use_pallas=False)
        s = pipelined_summary([m], plans)
        assert s["n_stages"] == len(pipe.stages), scheme


def test_pipelined_summary_prices_overlap():
    """Steady-state beat <= serial walk, so overlap_speedup >= 1; the
    stage count must agree with the executable stage cut."""
    for net, builder in NETWORKS.items():
        mods = builder()
        plans = partition_network(mods, paper_faithful=True)
        s = pipelined_summary(mods, plans, n_inflight=8)
        assert s["overlap_speedup"] >= 1.0
        assert s["steady_ms_per_input"] <= s["serial_ms_per_input"] + 1e-9
        pipe = compile_pipelined(mods, plans, use_pallas=False)
        assert s["n_stages"] == len(pipe.stages)
