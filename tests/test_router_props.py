"""Property: under ANY interleaving of requests, worker kills, worker
restarts and a final drain, the router answers every request EXACTLY
once — a 200 whose row bit-matches the batch-1 oracle, or a typed wire
error — and never loses, duplicates, or double-answers one.

Runs on in-process ``LocalWorker``s (same ``LocalBackend`` request
semantics as a worker process, no spawn cost) so hypothesis can afford
many interleavings; the subprocess transport itself is covered by the
e2e tests in ``test_frontend.py``.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")

import asyncio

import jax
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.executor import compile_network
from repro.core.graph import fire
from repro.core.hetero import init_network
from repro.core.partitioner import partition_network
from repro.frontend import LocalWorker, Router, build_server, wire

HW = (8, 8)
C = 16
SPEC = {"networks": [{"kind": "fire", "name": "tiny", "hw": list(HW),
                      "c_in": C, "squeeze": 4, "expand": 8, "seed": 0}],
        "server": {"max_wait_ms": 1.0}}
TYPED = {"overloaded", "deadline_exceeded", "server_closed", "shutdown",
         "worker_unreachable", "no_healthy_worker", "internal"}

_ORACLE = {}


def _oracle_row():
    if "row" not in _ORACLE:
        mods = [fire("tiny", HW[0], C, 4, 8)]
        eng = compile_network(mods, partition_network(mods))
        prep = eng.prepare(init_network(mods, jax.random.PRNGKey(0)))
        x = np.asarray(0.5 * jax.random.normal(jax.random.PRNGKey(7),
                                               (*HW, C)), dtype=np.float32)
        _ORACLE["x"] = x
        _ORACLE["row"] = np.asarray(eng(prep, x[None])[0])
    return _ORACLE["x"], _ORACLE["row"]


# op alphabet: issue a request / kill worker i / restart worker i;
# drain always runs once at the end of the schedule
_OPS = st.lists(
    st.one_of(st.just(("req",)),
              st.tuples(st.just("kill"), st.integers(0, 1)),
              st.tuples(st.just("restart"), st.integers(0, 1))),
    min_size=4, max_size=14)


@settings(max_examples=10, deadline=None)
@given(ops=_OPS)
@pytest.mark.frontend
def test_no_request_lost_duplicated_or_answered_twice(ops):
    x, ref = _oracle_row()
    payload = wire.infer_payload("tiny", x)

    async def run():
        workers = [LocalWorker(f"w{i}", lambda: build_server(SPEC))
                   for i in range(2)]
        router = Router(workers, auto_restart=False, eject_after=1,
                        reinstate_after=1, probe_interval_s=0.01,
                        retry_backoff_s=0.0, seed=17)
        await router.start()
        answers = []                       # exactly one entry per request

        async def one_request():
            status, body, _h = await router.infer(payload)
            answers.append((status, body))

        pending = []
        for op in ops:
            if op[0] == "req":
                pending.append(asyncio.ensure_future(one_request()))
            elif op[0] == "kill":
                workers[op[1]].crash()
            elif op[0] == "restart" and not workers[op[1]].alive():
                await workers[op[1]].restart()
            await asyncio.sleep(0)         # let the loop interleave
        # requests issued against a live router must all settle ...
        await asyncio.wait_for(asyncio.gather(*pending), 120)
        # ... and drain must fence, settle, and never hang
        status, body, _h = await asyncio.wait_for(router.drain(10.0), 30)
        assert status == 200 and body["drained"]
        assert router._outstanding == 0
        status, body, _h = router.admit() or (None, None, None)
        assert status == 503 and body["error"] == "shutdown", \
            "post-drain admission was not fenced"
        return len([op for op in ops if op[0] == "req"]), answers, router

    n_requests, answers, router = asyncio.run(run())
    # exactly one answer per request: none lost, none answered twice
    assert len(answers) == n_requests
    for status, body in answers:
        if status == 200:
            got = wire.decode_array(body["result"])
            assert np.array_equal(got, ref), \
                "a retried/failed-over request changed its answer"
        else:
            # failures cross the wire typed, never as tracebacks
            assert isinstance(body, dict) and body["error"] in TYPED, body
    # a retry is bounded to ONE re-issue per request
    assert router.counters["retries"] <= n_requests


@pytest.mark.frontend
def test_ejection_and_probe_reinstatement_cycle():
    """Deterministic breaker walk: kill -> ejected (probe failures),
    restart -> reinstated (probe passes), requests flow to it again."""

    async def run():
        workers = [LocalWorker(f"w{i}", lambda: build_server(SPEC))
                   for i in range(2)]
        router = Router(workers, auto_restart=False, eject_after=2,
                        reinstate_after=2, probe_interval_s=0.01,
                        retry_backoff_s=0.0)
        await router.start()
        try:
            workers[0].crash()
            for _ in range(200):
                if workers[0].state == "ejected":
                    break
                await asyncio.sleep(0.01)
            assert workers[0].state == "ejected"
            assert router.counters["ejections"] >= 1
            assert router._pick() is workers[1]

            await workers[0].restart()
            for _ in range(200):
                if workers[0].state == "healthy":
                    break
                await asyncio.sleep(0.01)
            assert workers[0].state == "healthy"
            assert router.counters["reinstatements"] >= 1
        finally:
            await router.drain(10.0)

    asyncio.run(run())
