"""Property-based batcher tests: random interleavings of submits across
networks, resolutions and priorities — with drains interleaved at random
points — never lose, duplicate, or reorder a request within its lane, and
every flushed group fits a valid bucket-ladder entry.

Optional suite: skips cleanly when hypothesis is absent (the ``property``
extra), like the other property-based files.  Also part of the
``pytest -m serving`` stress job.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.serving import DynamicBatcher, LaneKey, Request, pick_bucket

LADDERS = {"a": (1, 4, 8), "b": (2, 8)}

# one submit: (network, resolution, priority); "drain" pops one group
_submit = st.tuples(st.sampled_from(sorted(LADDERS)),
                    st.sampled_from([(8, 8), (16, 16)]),
                    st.integers(min_value=0, max_value=2))
_ops = st.lists(st.one_of(_submit, st.just("drain")), max_size=80)


def _drain_one(b, groups):
    got = b.wait_ready(timeout=0.1, buckets_by=LADDERS)
    assert got is not None, "pending requests but nothing flushable"
    lane, reqs, _by_deadline = got
    assert reqs, "empty flush group"
    groups.append((lane, reqs))


@pytest.mark.serving
@settings(max_examples=60, deadline=None)
@given(ops=_ops)
def test_random_interleavings_exactly_once_in_lane_order(ops):
    # max_wait_s=0 makes every lane instantly deadline-eligible, so the
    # scheduling policy (EDF + full-bucket preemption) is exercised on
    # every drain without wall-clock sleeps
    b = DynamicBatcher(max_wait_s=0.0, max_batch=8)
    submitted, groups = [], []
    for op in ops:
        if op == "drain":
            if b.pending():
                _drain_one(b, groups)
            continue
        net, res, prio = op
        r = Request(net, len(submitted), res=res, priority=prio)
        submitted.append(r)
        b.put(r)
    while b.pending():
        _drain_one(b, groups)
    flushed = [r for _lane, reqs in groups for r in reqs]
    # no request lost, none duplicated (identity by unique sequence id)
    assert sorted(r.x for r in flushed) == list(range(len(submitted)))
    for lane, reqs in groups:
        # a group never mixes lanes...
        assert all(r.lane == lane for r in reqs)
        # ...and always fits a valid ladder entry
        ladder = LADDERS[lane.network]
        assert len(reqs) <= min(b.max_batch, ladder[-1])
        assert pick_bucket(len(reqs), ladder) in ladder
    # within every lane, flush order preserves submission order
    for lane in {r.lane for r in submitted}:
        got = [r.x for _l, reqs in groups if _l == lane for r in reqs]
        want = [r.x for r in submitted if r.lane == lane]
        assert got == want


@pytest.mark.serving
@settings(max_examples=40, deadline=None)
@given(counts=st.lists(st.integers(min_value=1, max_value=20),
                       min_size=1, max_size=6),
       ladder=st.sampled_from([(1, 4, 8), (2, 8), (1, 4, 8, 32), (4,)]))
def test_deadline_take_always_yields_valid_buckets(counts, ladder):
    """The pad-vs-split sizing never exceeds the ladder cap, always makes
    progress, and always lands on a real bucket."""
    for n in counts:
        n = min(n, ladder[-1])
        take = DynamicBatcher._deadline_take(n, ladder)
        assert 1 <= take <= n
        cover = pick_bucket(take, ladder)
        assert cover in ladder
        # the split rule's promise: at most half the covering bucket is
        # pad — unless every queued request was taken (nothing to split
        # to: no smaller bucket exists below n)
        assert cover - take <= cover // 2 or take == n


@pytest.mark.serving
@settings(max_examples=40, deadline=None)
@given(prios=st.lists(st.integers(min_value=0, max_value=3),
                      min_size=2, max_size=12))
def test_drain_all_returns_every_lane_exactly_once(prios):
    b = DynamicBatcher(max_wait_s=10.0, max_batch=8)
    for i, p in enumerate(prios):
        b.put(Request("n", i, res=(8, 8), priority=p))
    out = b.drain_all()
    assert b.pending() == 0
    assert {lane for lane, _ in out} \
        == {LaneKey("n", (8, 8), p) for p in prios}
    assert sorted(r.x for _lane, reqs in out for r in reqs) \
        == list(range(len(prios)))
