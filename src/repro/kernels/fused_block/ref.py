"""Pure-jnp oracle for the fused dw3x3 + pw1x1 bottleneck tail."""
import jax
import jax.numpy as jnp


def fused_dw_pw(x, dw_w, dw_b, pw_w, pw_b):
    """x (B,H,W,C); dw_w (3,3,C); pw_w (C,Co).  relu6 between stages."""
    y = jax.lax.conv_general_dilated(
        x, dw_w[..., None, :], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])
    y = jnp.clip(y + dw_b, 0.0, 6.0)
    out = jnp.einsum("bhwc,co->bhwo", y, pw_w,
                     preferred_element_type=jnp.float32)
    return (out + pw_b).astype(x.dtype)
