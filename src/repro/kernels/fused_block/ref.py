"""Pure-jnp oracles for the fused FPGA-chain kernels.

``fused_dw_pw`` is the original dw3x3(relu6)+pw1x1 pair oracle; the
generalized ``fused_chain`` covers every chain shape the fusion pass emits:
an optional leading pw1x1 (with its own activation), a dw3x3 at stride 1 or
2 (activation none/relu/relu6), and a trailing pw1x1 whose activation the
caller applies.
"""
import jax
import jax.numpy as jnp


def _act(x, kind: str):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    return x


def fused_dw_pw(x, dw_w, dw_b, pw_w, pw_b):
    """x (B,H,W,C); dw_w (3,3,C); pw_w (C,Co).  relu6 between stages."""
    return fused_chain(x, None, None, dw_w, dw_b, pw_w, pw_b,
                       stride=1, act_lead="none", act_dw="relu6")


def fused_chain(x, lead_w, lead_b, dw_w, dw_b, pw_w, pw_b, *,
                stride: int = 1, act_lead: str = "none",
                act_dw: str = "relu6"):
    """[pw1x1+act_lead] -> dw3x3/stride+act_dw -> pw1x1 (no trailing act).

    x (B,H,W,C); lead_w (C,Cm) or None; dw_w (3,3,Cm); pw_w (Cm,Co).
    """
    if lead_w is not None:
        x = _act(jnp.einsum("bhwc,co->bhwo", x, lead_w,
                            preferred_element_type=jnp.float32)
                 + lead_b, act_lead).astype(x.dtype)
    y = jax.lax.conv_general_dilated(
        x, dw_w[..., None, :], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])
    y = _act(y + dw_b, act_dw)
    out = jnp.einsum("bhwc,co->bhwo", y, pw_w,
                     preferred_element_type=jnp.float32)
    return (out + pw_b).astype(x.dtype)
