"""Pallas TPU kernel: fused dw3x3 + ReLU6 + pw1x1 — the DHM analogue.

DHM's insight re-expressed for the TPU memory hierarchy: the depthwise
intermediate NEVER touches HBM — it is produced and consumed inside VMEM,
exactly like DHM keeps inter-layer feature maps inside the FPGA fabric.
Grid is (batch,); each program streams one feature map through both stages.
The pointwise stage hits the MXU with an (H*W, C) x (C, Co) matmul whose
dims are padded to 128 multiples by the wrapper (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xp_ref, dww_ref, dwb_ref, pww_ref, pwb_ref, out_ref):
    # xp: (1, H+2, W+2, C) pre-padded input block in VMEM
    xp = xp_ref[0]
    H = out_ref.shape[1]
    W = out_ref.shape[2]
    dww = dww_ref[...]
    acc = jnp.zeros((H, W, xp.shape[-1]), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            acc += xp[dy:dy + H, dx:dx + W, :].astype(jnp.float32) \
                * dww[dy, dx][None, None, :]
    h = jnp.clip(acc + dwb_ref[...][None, None, :], 0.0, 6.0)
    # pointwise: (H*W, C) @ (C, Co) on the MXU, fp32 accumulation
    hw = h.reshape(H * W, -1).astype(xp.dtype)
    out = jnp.dot(hw, pww_ref[...], preferred_element_type=jnp.float32)
    out = out + pwb_ref[...][None, :]
    out_ref[0] = out.reshape(H, W, -1).astype(out_ref.dtype)


def fused_dw_pw_pallas(x, dw_w, dw_b, pw_w, pw_b, *, interpret=False):
    """x (B,H,W,C) -> (B,H,W,Co); intermediates stay in VMEM."""
    B, H, W, C = x.shape
    Co = pw_w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H + 2, W + 2, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((3, 3, C), lambda b: (0, 0, 0)),
            pl.BlockSpec((C,), lambda b: (0,)),
            pl.BlockSpec((C, Co), lambda b: (0, 0)),
            pl.BlockSpec((Co,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, H, W, Co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Co), x.dtype),
        interpret=interpret,
    )(xp, dw_w, dw_b, pw_w, pw_b)
