"""Pallas TPU kernel: fused FPGA-chain execution — the DHM analogue.

DHM's insight re-expressed for the TPU memory hierarchy: every intermediate
of a fused chain is produced and consumed inside VMEM — it never touches
HBM — exactly like DHM keeps inter-layer feature maps inside the FPGA
fabric.  Grid is (batch,); each program streams one feature map through the
whole chain.

Chain shapes (all static, burned into the kernel at trace time):

  * optional leading pw1x1 (+ its activation) — the ShuffleNetV2
    pw-dw-pw working branch, or MobileNetV2's expand+dw+project tail;
  * dw3x3 at stride 1 or 2 (+ activation none/relu/relu6) — stride-2
    covers the down-sampling stages that previously lowered node-by-node;
  * trailing pw1x1 on the MXU ((Ho*Wo, C) x (C, Co) matmul); its
    activation is applied by the caller.

The kernel takes the UNPADDED input block and SAME-pads the depthwise
input in VMEM (padding must happen after the leading pointwise stage:
``act(0 @ w + b)`` is not zero at pad positions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _act(x, kind: str):
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    return x


def _chain_kernel(refs, *, has_lead: bool, stride: int, act_lead: str,
                  act_dw: str):
    if has_lead:
        x_ref, lw_ref, lb_ref, dww_ref, dwb_ref, pww_ref, pwb_ref, out_ref \
            = refs
    else:
        x_ref, dww_ref, dwb_ref, pww_ref, pwb_ref, out_ref = refs
    x = x_ref[0]                            # (H, W, C) unpadded, in VMEM
    H, W = x.shape[0], x.shape[1]
    Ho, Wo = out_ref.shape[1], out_ref.shape[2]
    if has_lead:
        h = jnp.dot(x.reshape(H * W, -1), lw_ref[...],
                    preferred_element_type=jnp.float32)
        h = _act(h + lb_ref[...][None, :], act_lead)
        h = h.reshape(H, W, -1)
    else:
        h = x.astype(jnp.float32)
    # SAME pad for the 3x3/stride window (XLA's lo=total//2 split)
    ph = max((Ho - 1) * stride + 3 - H, 0)
    pw = max((Wo - 1) * stride + 3 - W, 0)
    hp = jnp.pad(h, ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2),
                     (0, 0)))
    dww = dww_ref[...]
    acc = jnp.zeros((Ho, Wo, hp.shape[-1]), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            sl = hp[dy:dy + (Ho - 1) * stride + 1:stride,
                    dx:dx + (Wo - 1) * stride + 1:stride, :]
            acc += sl * dww[dy, dx][None, None, :]
    h2 = _act(acc + dwb_ref[...][None, None, :], act_dw)
    # pointwise: (Ho*Wo, C) @ (C, Co) on the MXU, fp32 accumulation
    hw = h2.reshape(Ho * Wo, -1).astype(x.dtype)
    out = jnp.dot(hw, pww_ref[...], preferred_element_type=jnp.float32)
    out = out + pwb_ref[...][None, :]
    out_ref[0] = out.reshape(Ho, Wo, -1).astype(out_ref.dtype)


def fused_chain_pallas(x, lead_w, lead_b, dw_w, dw_b, pw_w, pw_b, *,
                       stride: int = 1, act_lead: str = "none",
                       act_dw: str = "relu6", interpret=False):
    """x (B,H,W,C) -> (B,Ho,Wo,Co); intermediates stay in VMEM.

    ``lead_w``/``lead_b`` may be None (plain dw+pw pair)."""
    B, H, W, C = x.shape
    Ho, Wo = -(-H // stride), -(-W // stride)
    Cm = dw_w.shape[-1]
    Co = pw_w.shape[-1]
    has_lead = lead_w is not None
    kernel = functools.partial(
        lambda *refs, **kw: _chain_kernel(refs, **kw),
        has_lead=has_lead, stride=stride, act_lead=act_lead, act_dw=act_dw)
    in_specs = [pl.BlockSpec((1, H, W, C), lambda b: (b, 0, 0, 0))]
    args = [x]
    if has_lead:
        in_specs += [pl.BlockSpec((C, Cm), lambda b: (0, 0)),
                     pl.BlockSpec((Cm,), lambda b: (0,))]
        args += [lead_w, lead_b]
    in_specs += [
        pl.BlockSpec((3, 3, Cm), lambda b: (0, 0, 0)),
        pl.BlockSpec((Cm,), lambda b: (0,)),
        pl.BlockSpec((Cm, Co), lambda b: (0, 0)),
        pl.BlockSpec((Co,), lambda b: (0,)),
    ]
    args += [dw_w, dw_b, pw_w, pw_b]
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Ho, Wo, Co), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, Co), x.dtype),
        interpret=interpret,
    )(*args)


def fused_dw_pw_pallas(x, dw_w, dw_b, pw_w, pw_b, *, interpret=False):
    """Back-compat wrapper: the original dw3x3(relu6)+pw1x1 pair."""
    return fused_chain_pallas(x, None, None, dw_w, dw_b, pw_w, pw_b,
                              stride=1, act_dw="relu6", interpret=interpret)
