"""jit'd public wrapper for the fused bottleneck-tail kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_block.kernel import fused_dw_pw_pallas
from repro.kernels.fused_block.ref import fused_dw_pw


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("use_pallas",))
def fused_block(x, dw_w, dw_b, pw_w, pw_b, use_pallas: bool = True):
    if not use_pallas:
        return fused_dw_pw(x, dw_w, dw_b, pw_w, pw_b)
    return fused_dw_pw_pallas(x, dw_w, dw_b, pw_w, pw_b,
                              interpret=_on_cpu())
