"""jit'd public wrappers for the fused FPGA-chain kernels.

``fused_chain`` is the generalized entry the backend-lowering pass uses:
optional leading pw1x1, dw3x3 at stride 1/2, trailing pw1x1 — activations
between stages are static kernel parameters.  ``fused_block`` keeps the
original dw3x3(relu6)+pw1x1 pair API.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_block.kernel import (fused_chain_pallas,
                                              fused_dw_pw_pallas)
from repro.kernels.fused_block.ref import fused_chain as fused_chain_ref
from repro.kernels.fused_block.ref import fused_dw_pw


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("use_pallas",))
def fused_block(x, dw_w, dw_b, pw_w, pw_b, use_pallas: bool = True):
    if not use_pallas:
        return fused_dw_pw(x, dw_w, dw_b, pw_w, pw_b)
    return fused_dw_pw_pallas(x, dw_w, dw_b, pw_w, pw_b,
                              interpret=_on_cpu())


@partial(jax.jit, static_argnames=("stride", "act_lead", "act_dw",
                                   "use_pallas"))
def fused_chain(x, lead_w, lead_b, dw_w, dw_b, pw_w, pw_b, *,
                stride: int = 1, act_lead: str = "none",
                act_dw: str = "relu6", use_pallas: bool = True):
    """[pw1x1+act_lead] -> dw3x3/stride+act_dw -> pw1x1 (trailing act is
    the caller's).  ``lead_w``/``lead_b`` None = plain dw+pw pair."""
    if not use_pallas:
        return fused_chain_ref(x, lead_w, lead_b, dw_w, dw_b, pw_w, pw_b,
                               stride=stride, act_lead=act_lead,
                               act_dw=act_dw)
    return fused_chain_pallas(x, lead_w, lead_b, dw_w, dw_b, pw_w, pw_b,
                              stride=stride, act_lead=act_lead,
                              act_dw=act_dw, interpret=_on_cpu())
