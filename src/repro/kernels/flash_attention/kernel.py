"""Pallas TPU flash attention (block-wise online softmax).

Grid (B, H, nq): each program owns one q tile in VMEM and streams kv tiles
with a fori_loop, carrying (acc, m, l).  Causal pruning is STRUCTURAL: the
loop bound is the q tile's last row, so later kv tiles are never touched —
unlike masked-dense XLA attention this does ~S^2/2 work, and the tiles are
128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, *, kv_tile: int, causal: bool,
            scale: float):
    q = q_ref[0, 0]                           # (TQ, D)
    TQ, D = q.shape
    S = k_ref.shape[2]
    i = pl.program_id(2)
    q_start = i * TQ

    n_kv = S // kv_tile
    if causal:
        # only kv tiles that intersect [0, q_start + TQ)
        n_live = jnp.minimum((q_start + TQ + kv_tile - 1) // kv_tile, n_kv)
    else:
        n_live = n_kv

    def body(j, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice(k_ref[0, 0], (j * kv_tile, 0),
                                  (kv_tile, D))
        v = jax.lax.dynamic_slice(v_ref[0, 0], (j * kv_tile, 0),
                                  (kv_tile, D))
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (TQ, kv_tile), 0)
            kpos = j * kv_tile + jax.lax.broadcasted_iota(
                jnp.int32, (TQ, kv_tile), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        return acc * corr[:, None] + pv, m_new, l

    acc = jnp.zeros((TQ, D), jnp.float32)
    m = jnp.full((TQ,), NEG_INF, jnp.float32)
    l = jnp.zeros((TQ,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc, m, l))
    out_ref[0, 0] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(
        out_ref.dtype)


def flash_attention_pallas(q, k, v, *, q_tile=256, kv_tile=256, causal=True,
                           interpret=False):
    """q,k,v (B,H,S,D) -> (B,H,S,D)."""
    B, H, S, D = q.shape
    q_tile = min(q_tile, S)
    kv_tile = min(kv_tile, S)
    assert S % q_tile == 0 and S % kv_tile == 0
    scale = 1.0 / (D ** 0.5)
    kern = functools.partial(_kernel, kv_tile=kv_tile, causal=causal,
                             scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, H, S // q_tile),
        in_specs=[
            pl.BlockSpec((1, 1, q_tile, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_tile, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
