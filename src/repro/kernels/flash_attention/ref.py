"""Oracle: plain softmax causal attention."""
import jax.numpy as jnp
import jax


def attention(q, k, v, causal=True):
    """q,k,v (B,H,S,D) -> (B,H,S,D)."""
    S = q.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
