"""jit'd wrapper for the Pallas flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention as attention_ref


@partial(jax.jit, static_argnames=("causal", "use_pallas"))
def flash_attention(q, k, v, causal: bool = True, use_pallas: bool = True):
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=jax.default_backend() == "cpu")
