"""Oracle: int8 x int8 -> int32 -> f32 requantized GEMM."""
import jax
import jax.numpy as jnp


def int8_gemm(x_q, w_q, x_scale, w_scale):
    """x_q (M,K) int8; w_q (K,N) int8; scales f32 (scalar / (1,N))."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * x_scale * jnp.asarray(w_scale).reshape(1, -1)
