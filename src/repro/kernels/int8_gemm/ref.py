"""Oracle: int8 x int8 -> int32 -> f32 requantized GEMM.

The CPU fast path runs the integer GEMM **in fp32**: int8 products are
integers <= 127*127, so every partial sum of a K-chunk stays an exactly
representable integer below 2^24 and no add ever rounds — the fp32 gemm is
bit-identical to the int32 accumulate for ANY summation order or blocking
(hence batch-invariant, which ``repro.serving`` relies on) while hitting
the platform's optimized fp32 kernels instead of XLA:CPU's scalar s8 dot.
K is split into <=1024-wide chunks whose exact fp32 partials are combined
in int32, extending exactness to arbitrary K.
"""
import jax.numpy as jnp

# 1024 * 127 * 127 = 16.5M < 2^24: any partial sum within a chunk is exact
_K_CHUNK = 1024


def int8_gemm(x_q, w_q, x_scale, w_scale):
    """x_q (M,K) int8; w_q (K,N) int8; x_scale f32 scalar or per-row
    (M,1); w_scale scalar or per-channel (1,N)."""
    K = x_q.shape[1]
    xf = x_q.astype(jnp.float32)
    wf = w_q.astype(jnp.float32)
    if K <= _K_CHUNK:
        acc = xf @ wf                       # exact: all partials < 2^24
    else:
        tot = None
        for k0 in range(0, K, _K_CHUNK):
            part = (xf[:, k0:k0 + _K_CHUNK]
                    @ wf[k0:k0 + _K_CHUNK]).astype(jnp.int32)
            tot = part if tot is None else tot + part
        acc = tot.astype(jnp.float32)
    return acc * x_scale * jnp.asarray(w_scale).reshape(1, -1)
