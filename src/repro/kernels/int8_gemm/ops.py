"""jit'd wrappers: int8 GEMM for arbitrary shapes (serving path building
block).  ``int8_gemm`` takes pre-quantized operands — the compiled engine
calls it with weights quantized once at compile time; ``int8_matmul`` is the
quantize-on-the-fly convenience wrapper."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.int8_gemm.kernel import int8_gemm_pallas
from repro.kernels.int8_gemm.ref import int8_gemm as int8_gemm_ref
from repro.quant import quantize


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


@partial(jax.jit, static_argnames=("use_pallas", "tm", "tn"))
def int8_gemm(x_q, w_q, x_scale, w_scale, use_pallas: bool = True,
              tm: int = 256, tn: int = 256):
    """x_q (M,K) int8 @ w_q (K,N) int8 -> (M,N) f32 requantized.

    ``x_scale`` is a scalar (per-tensor) or an (M,)/(M,1) per-row vector —
    per-request scales keep batched serving numerics identical to batch-1.
    The Pallas kernel requires M/N to be tile multiples; arbitrary shapes
    are zero-padded up to the tile grid here and the result sliced back.
    """
    M = x_q.shape[0]
    xs = jnp.asarray(x_scale, jnp.float32)
    xs = xs.reshape(()) if xs.size == 1 else xs.reshape(-1, 1)
    if not use_pallas:
        return int8_gemm_ref(x_q, w_q, xs,
                             jnp.asarray(w_scale).reshape(1, -1))
    N = w_q.shape[1]
    tm = min(tm, M)
    tn = min(tn, N)
    mp, np_ = _ceil_to(M, tm), _ceil_to(N, tn)
    xp = jnp.pad(x_q, ((0, mp - M), (0, 0)))
    wp = jnp.pad(w_q, ((0, 0), (0, np_ - N)))
    ws = jnp.pad(jnp.asarray(w_scale, jnp.float32).reshape(-1),
                 (0, np_ - N))
    xs_rows = jnp.pad(jnp.broadcast_to(xs.reshape(-1, 1), (M, 1)),
                      ((0, mp - M), (0, 0)))
    out = int8_gemm_pallas(xp, wp, xs_rows, ws, tm=tm, tn=tn,
                           interpret=jax.default_backend() == "cpu")
    return out[:M, :N]


@partial(jax.jit, static_argnames=("use_pallas",))
def int8_matmul(x, w, use_pallas: bool = True):
    """f32/bf16 x (M,K) @ w (K,N) through the int8 fixed-point path."""
    x_q, x_s = quantize(x)
    w_q, w_s = quantize(w, axis=-1)
    return int8_gemm(x_q, w_q, x_s, w_s.reshape(-1), use_pallas=use_pallas)
