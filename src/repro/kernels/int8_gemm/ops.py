"""jit'd wrapper: quantize + int8 GEMM (serving path building block)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.int8_gemm.kernel import int8_gemm_pallas
from repro.kernels.int8_gemm.ref import int8_gemm as int8_gemm_ref
from repro.quant import quantize


@partial(jax.jit, static_argnames=("use_pallas",))
def int8_matmul(x, w, use_pallas: bool = True):
    """f32/bf16 x (M,K) @ w (K,N) through the int8 fixed-point path."""
    x_q, x_s = quantize(x)
    w_q, w_s = quantize(w, axis=-1)
    if not use_pallas:
        return int8_gemm_ref(x_q, w_q, x_s, w_s.reshape(1, -1))
    return int8_gemm_pallas(x_q, w_q, x_s, w_s.reshape(-1),
                            interpret=jax.default_backend() == "cpu")
