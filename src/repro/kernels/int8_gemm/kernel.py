"""Pallas TPU kernel: 8-bit fixed-point GEMM (the paper's number format).

MXU-native int8: tiles are (TM x K) x (K x TN) with int32 accumulation and
a fused f32 requantize on the way out.  Tile sizes are multiples of 128 so
the systolic array is fully fed; K stays resident per tile pair (weights
"close to the compute", DHM-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, xs_ref, ws_ref, out_ref):
    acc = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    out_ref[...] = (acc.astype(jnp.float32)
                    * xs_ref[...][:, None] * ws_ref[...][None, :])


def int8_gemm_pallas(x_q, w_q, x_scale, w_scale, *, tm=256, tn=256,
                     interpret=False):
    """``x_scale`` may be a scalar (per-tensor) or an (M,)/(M,1) per-row
    vector — the serving path quantizes activations per request so batching
    cannot change any request's numerics; each row tile carries its own
    scale slice, mirroring the per-channel ``w_scale`` tile."""
    M, K = x_q.shape
    N = w_q.shape[1]
    tm = min(tm, M)
    tn = min(tn, N)
    assert M % tm == 0 and N % tn == 0, (M, N, tm, tn)
    ws = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32).reshape(-1),
                          (N,))
    xs = jnp.broadcast_to(jnp.asarray(x_scale, jnp.float32).reshape(-1, 1),
                          (M, 1)).reshape(-1)
    return pl.pallas_call(
        _kernel,
        grid=(M // tm, N // tn),
        in_specs=[
            pl.BlockSpec((tm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tm,), lambda i, j: (i,)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x_q, w_q, xs, ws)
