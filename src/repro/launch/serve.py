"""Serving launcher: batched prefill + decode with a continuous-batching
style slot scheduler.  ``python -m repro.launch.serve --arch <id>``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_host_mesh
from repro.models.lm import model as lm
from repro.models.lm.sharding import AxisRules, use_rules


class SlotServer:
    """Fixed-slot batch server: admits requests into free slots, decodes all
    active slots in lockstep, retires finished ones (continuous batching at
    slot granularity)."""

    def __init__(self, cfg, params, slots: int, smax: int):
        self.cfg, self.params = cfg, params
        self.slots, self.smax = slots, smax
        self.cache = lm.init_cache(cfg, slots, smax)
        self.active = np.zeros(slots, bool)
        self.lengths = np.zeros(slots, np.int32)
        self.outputs: dict[int, list] = {}
        self._decode = jax.jit(
            lambda p, c, t, l: lm.decode_step(cfg, p, c, t, l))

    def admit(self, rid: int, prompt: np.ndarray, slot: int):
        # per-slot prefill via single-token steps (shared-cache simplicity)
        self.active[slot] = True
        self.outputs[rid] = []
        self._slot_rid = getattr(self, "_slot_rid", {})
        self._slot_rid[slot] = rid
        for t, tok in enumerate(prompt):
            self.step_token(slot, int(tok), t)
        self.lengths[slot] = len(prompt)

    def step_token(self, slot, tok, pos):
        tokens = np.zeros((self.slots, 1), np.int32)
        tokens[slot, 0] = tok
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(pos, jnp.int32))
        return np.asarray(logits[slot, 0])

    def decode_round(self, greedy=True):
        """One synchronized decode step for every active slot."""
        for slot in np.where(self.active)[0]:
            rid = self._slot_rid[slot]
            prev = self.outputs[rid][-1] if self.outputs[rid] else 1
            logits = self.step_token(slot, prev, int(self.lengths[slot]))
            nxt = int(np.argmax(logits[:self.cfg.vocab]))
            self.outputs[rid].append(nxt)
            self.lengths[slot] += 1
            if self.lengths[slot] >= self.smax - 1:
                self.active[slot] = False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch), dtype="float32")
    mesh = make_host_mesh()
    rules = AxisRules(mesh, cfg.policy, cfg.moe)
    with mesh, use_rules(rules):
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        srv = SlotServer(cfg, params, slots=args.requests, smax=64)
        rng = np.random.default_rng(0)
        t0 = time.time()
        for rid in range(args.requests):
            prompt = rng.integers(0, cfg.vocab, size=args.prompt_len)
            srv.admit(rid, prompt, slot=rid)
        for _ in range(args.gen):
            srv.decode_round()
        dt = time.time() - t0
    tok = sum(len(v) for v in srv.outputs.values())
    print(f"[serve] arch={cfg.name} requests={args.requests} "
          f"generated={tok} tokens in {dt:.1f}s")
    return srv.outputs


if __name__ == "__main__":
    main()
