"""Static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE (no trip-count
multiplication), which under-reports scanned-layer models by n_layers x.
This analyzer walks the HLO text, multiplies through while trip counts
(extracted from the loop condition's comparison constant), and reports:

  - flops               dot/convolution FLOPs, per device
  - bytes               operand+output bytes of every top-level instruction
                        (fusion = one node: the standard HLO traffic model)
  - collective_bytes    per collective opcode, operand-side bytes
  - collective_counts   op counts (trip-multiplied)

All numbers are PER DEVICE (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def shape_bytes(s: str) -> int:
    """Total bytes of a shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(s: str) -> list[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    var: str
    shape: str
    opcode: str
    operands: list[str]
    attrs: str
    raw_ops: str = ""


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)   # var -> shape str
    instrs: list = field(default_factory=list)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALLED = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
_CONST = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                for p in m.group(2).split(","):
                    p = p.strip()
                    if ":" in p:
                        v, s = p.split(":", 1)
                        cur.params[v.strip().lstrip("%")] = s.strip()
                continue
        else:
            if line.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                var, shape, opcode, ops, attrs = m.groups()
                cur.instrs.append(Instr(var, shape, opcode,
                                        _OPERAND.findall(ops), attrs, ops))
    if cur is not None:
        comps[cur.name] = cur
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult

    @property
    def total_coll_bytes(self):
        return sum(self.coll_bytes.values())


_SKIP_BYTES = {"tuple", "get-tuple-element", "parameter", "constant",
               "bitcast", "after-all", "copy-start", "copy-done",
               "partition-id", "replica-id", "iota"}

# HBM-traffic model: count operand+output bytes ONLY at fusion boundaries
# and for data-movement/compute ops a TPU cannot fuse away.  The CPU backend
# fuses far less than TPU, so counting every top-level elementwise op would
# overstate traffic by orders of magnitude.
_BYTES_OPS = {"dot", "convolution", "fusion", "custom-call",
              "dynamic-update-slice", "dynamic-slice", "gather", "scatter",
              "sort", "all-gather", "all-reduce", "reduce-scatter",
              "all-to-all", "collective-permute", "all-gather-start",
              "all-reduce-start", "collective-permute-start"}


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: dict[str, Cost] = {}
        # var shapes per computation for dot flop computation
        self._shapes: dict[str, dict[str, str]] = {}
        for name, c in self.comps.items():
            sh = dict(c.params)
            for i in c.instrs:
                sh[i.var] = i.shape
            self._shapes[name] = sh

    def _dot_flops(self, comp: Computation, i: Instr) -> float:
        out = 1
        for d in shape_dims(i.shape):
            out *= d
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", i.attrs)
        if not m or not i.operands:
            return 2.0 * out
        lhs_shape = self._shapes[comp.name].get(i.operands[0], "")
        dims = shape_dims(lhs_shape)
        k = 1
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(dims):
                k *= dims[int(idx)]
        return 2.0 * out * k

    def _conv_flops(self, comp: Computation, i: Instr) -> float:
        out = 1
        for d in shape_dims(i.shape):
            out *= d
        if len(i.operands) < 2:
            return 2.0 * out
        ker = shape_dims(self._shapes[comp.name].get(i.operands[1], ""))
        k = 1
        for d in ker[:-1]:      # all but output-feature dim (approx)
            k *= d
        return 2.0 * out * k

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = Cost()      # break cycles defensively
        comp = self.comps.get(comp_name)
        c = Cost()
        if comp is None:
            return c
        shapes = self._shapes[comp_name]
        for i in comp.instrs:
            if i.opcode == "while":
                called = dict(
                    (k, v) for k, v in re.findall(
                        r"(condition|body)=%?([\w\.\-]+)", i.attrs))
                trips = self._while_trips(called.get("condition", ""))
                if "body" in called:
                    c.add(self.cost_of(called["body"]), trips)
                if "condition" in called:
                    c.add(self.cost_of(called["condition"]), trips)
                continue
            if i.opcode in ("call", "fusion", "conditional", "async-start"):
                # bytes at the boundary; recurse for flops/collectives
                if i.opcode in _BYTES_OPS:
                    c.bytes += self._io_bytes(i, shapes)
                for sub in _CALLED.findall(i.attrs):
                    subc = self.cost_of(sub)
                    c.flops += subc.flops
                    for k, v in subc.coll_bytes.items():
                        c.coll_bytes[k] += v
                    for k, v in subc.coll_counts.items():
                        c.coll_counts[k] += v
                continue
            if i.opcode == "dot":
                c.flops += self._dot_flops(comp, i)
            elif i.opcode == "convolution":
                c.flops += self._conv_flops(comp, i)
            for coll in COLLECTIVES:
                if i.opcode == coll or i.opcode == f"{coll}-start":
                    b = sum(shape_bytes(shapes.get(o, ""))
                            for o in i.operands)
                    if coll == "all-gather":
                        b = shape_bytes(i.shape)     # output side
                    c.coll_bytes[coll] += b
                    c.coll_counts[coll] += 1
                    break
            if i.opcode in _BYTES_OPS:
                c.bytes += self._io_bytes(i, shapes)
        self._memo[comp_name] = c
        return c

    def _io_bytes(self, i: Instr, shapes: dict) -> float:
        """HBM traffic of one instruction.

        Slicing ops move only the slice (the big operand is resident: a
        dynamic-slice of loop-carried stacked weights reads slice bytes per
        iteration, not the whole stack).  Fusion/dot operands are capped at
        8x the output so reductions still count their input but phantom
        whole-stack operands of slicing fusions do not.
        """
        out = shape_bytes(i.shape)
        if i.opcode in ("dynamic-slice", "gather"):
            return 2.0 * out
        if i.opcode == "dynamic-update-slice":
            upd = (shape_bytes(shapes.get(i.operands[1], ""))
                   if len(i.operands) > 1 else out)
            return 2.0 * upd
        if i.opcode == "scatter":
            upd = (shape_bytes(shapes.get(i.operands[2], ""))
                   if len(i.operands) > 2 else out)
            return 2.0 * upd
        cap = 8.0 * max(out, 1)
        return out + sum(min(shape_bytes(shapes.get(o, "")), cap)
                         for o in i.operands)

    def _while_trips(self, cond_name: str) -> int:
        """Max s32 scalar constant in the loop condition (+ callees).

        Our loops are jax.lax.scan lowerings: cond is `i < N` with N a
        literal s32 constant — take the largest one found.
        """
        best = 1
        seen, stack = set(), [cond_name]
        while stack:
            n = stack.pop()
            if n in seen or n not in self.comps:
                continue
            seen.add(n)
            for i in self.comps[n].instrs:
                if i.opcode == "constant" and i.shape.startswith("s32[]"):
                    m = re.match(r"\s*(\d+)\s*$", i.raw_ops)
                    if m:
                        best = max(best, int(m.group(1)))
                stack.extend(_CALLED.findall(i.attrs))
        return best

    def entry_cost(self) -> Cost:
        entry = None
        for name, c in self.comps.items():
            if "main" in name or name.startswith("entry"):
                entry = name
        if entry is None:
            entry = list(self.comps)[-1]
        return self.cost_of(entry)


def analyze(text: str) -> dict:
    a = HloAnalyzer(text)
    c = a.entry_cost()
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "collective_bytes": dict(c.coll_bytes),
        "collective_counts": dict(c.coll_counts),
    }
