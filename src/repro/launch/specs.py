"""Per-cell (arch x shape x mesh) lowering specs: the step function, its
ShapeDtypeStruct arguments, and explicit in/out shardings.

Nothing here allocates device memory: params/opt-state/cache shapes come
from ``jax.eval_shape``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.lm import model as lm
from repro.models.lm.sharding import AxisRules, use_rules
from repro.optim import make_optimizer
from repro.train.steps import (TrainState, make_decode_fn, make_prefill_fn,
                               make_train_step)

# Microbatch counts for train_4k chosen so saved activations fit HBM
# (per-layer remat checkpoints scale with tokens/microbatch).
TRAIN_MICROBATCHES = {
    # (microbatches, accum_dtype).  671B: microbatches=1 — a fp32 (or even
    # bf16) gradient accumulator alone is 2.7 (1.35) TB; without one,
    # params+grads bf16 = 2.7 TB of the pod's 4 TB and the cell closes.
    "mistral-large-123b": (8, "bfloat16"),
    "deepseek-v3-671b": (1, "bfloat16"),
    "qwen2.5-32b": (4, "float32"),
    "llama3-8b": (2, "float32"),
    "recurrentgemma-9b": (2, "float32"),
    "qwen2-moe-a2.7b": (2, "float32"),
    "seamless-m4t-large-v2": (2, "float32"),
    "xlstm-125m": (4, "float32"),
    "starcoder2-3b": (2, "float32"),
    "internvl2-1b": (2, "float32"),
}


def _is_axes(x):
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)


def shardings_of(axes_tree, rules: AxisRules, mesh):
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, rules.spec(*axes)),
        axes_tree, is_leaf=_is_axes)


def batch_struct(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStructs + logical axes for one input batch."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    axes = {"tokens": ("batch", None)}
    if cfg.vlm_patches:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vlm_patches, cfg.d_model), dt)
        axes["image_embeds"] = ("batch", None, None)
    if cfg.enc_dec:
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, max(S // cfg.enc_ratio, 8), cfg.d_model), dt)
        axes["frames"] = ("batch", None, None)
    return batch, axes


def make_rules(cfg: ModelConfig, mesh, shape: ShapeSpec | None = None):
    import dataclasses
    policy = cfg.policy
    rules = AxisRules(mesh, policy, cfg.moe)
    if shape is not None:
        # longest prefix of the policy batch axes that divides global_batch
        axes = tuple(a for a in policy.batch_axes if a in mesh.axis_names)
        while axes:
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if shape.global_batch % n == 0:
                break
            axes = axes[:-1]
        if "model" in policy.batch_axes and "model" not in axes:
            # batch can't cover the model axis for this shape: give it back
            # to tensor-style sharding instead of idling 15/16 of the pod
            policy = dataclasses.replace(
                policy, batch_axes=tuple(a for a in policy.batch_axes
                                         if a != "model"))
            rules = AxisRules(mesh, policy, cfg.moe)
        rules.table["batch"] = axes or None      # e.g. long_500k batch=1
        if shape.kind in ("decode",):
            rules.table["seq_sp"] = None
    return rules


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def build_train_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     hierarchy_levels: int = 0):
    """Returns (fn, args, in_shard, out_shard, rules)."""
    rules = make_rules(cfg, mesh, shape)
    opt = make_optimizer(cfg.optimizer)
    mb, accum = TRAIN_MICROBATCHES.get(cfg.name, (1, "float32"))
    step_fn = make_train_step(cfg, opt, microbatches=mb,
                              hierarchy_levels=hierarchy_levels,
                              accum_dtype=jnp.dtype(accum))

    p_shapes = params_struct(cfg)
    opt_shapes = jax.eval_shape(opt.init, p_shapes)
    state = TrainState(jax.ShapeDtypeStruct((), jnp.int32), p_shapes,
                       opt_shapes)
    batch, batch_axes = batch_struct(cfg, shape)

    p_axes = lm.param_axes(cfg)
    state_axes = TrainState((), p_axes, opt.state_axes(p_axes, p_shapes))
    state_shard = shardings_of(state_axes, rules, mesh)
    batch_shard = shardings_of(batch_axes, rules, mesh)
    metrics_shard = {"loss": NamedSharding(mesh, P()),
                     "aux": NamedSharding(mesh, P())}
    return (step_fn, (state, batch), (state_shard, batch_shard),
            (state_shard, metrics_shard), rules)


def build_prefill_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                       hierarchy_levels: int = 0):
    rules = make_rules(cfg, mesh, shape)
    fn = make_prefill_fn(cfg, hierarchy_levels)
    p_shapes = params_struct(cfg)
    batch, batch_axes = batch_struct(cfg, shape)
    p_axes = lm.param_axes(cfg)
    param_shard = shardings_of(p_axes, rules, mesh)
    batch_shard = shardings_of(batch_axes, rules, mesh)
    # out: (last logits, caches) — same layout rules as the decode cache
    c_axes = _prefill_cache_axes(cfg)
    out_shard = (NamedSharding(mesh, rules.spec("batch", None, "vocab")),
                 shardings_of(c_axes, rules, mesh))
    return fn, (p_shapes, batch), (param_shard, batch_shard), out_shard, rules


def _prefill_cache_axes(cfg: ModelConfig):
    """Prefill caches mirror decode cache axes minus ring-buffer pos."""
    axes = lm.cache_axes(cfg)

    def strip(node):
        if isinstance(node, dict) and "pos" in node:
            node = {k: v for k, v in node.items() if k != "pos"}
        if isinstance(node, dict):
            return {k: strip(v) for k, v in node.items()}
        return node

    return strip(axes)


def build_decode_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    rules = make_rules(cfg, mesh, shape)
    fn = make_decode_fn(cfg)
    B, S = shape.global_batch, shape.seq_len
    enc_len = max(S // cfg.enc_ratio, 8) if cfg.enc_dec else 0
    p_shapes = params_struct(cfg)
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, S, enc_len))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    clen = jax.ShapeDtypeStruct((), jnp.int32)

    p_axes = lm.param_axes(cfg)
    c_axes = lm.cache_axes(cfg)
    param_shard = shardings_of(p_axes, rules, mesh)
    cache_shard = shardings_of(c_axes, rules, mesh)
    tok_shard = NamedSharding(mesh, rules.spec("batch", None))
    clen_shard = NamedSharding(mesh, P())
    out_shard = (NamedSharding(mesh, rules.spec("batch", None, "vocab")),
                 cache_shard)
    return (fn, (p_shapes, cache_shapes, token, clen),
            (param_shard, cache_shard, tok_shard, clen_shard),
            out_shard, rules)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, **kw):
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, **kw)
    if shape.kind == "decode":
        return build_decode_cell(cfg, shape, mesh)
    raise ValueError(shape.kind)


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, **kw):
    """Trace + lower one cell under its mesh/rules.  Returns jax Lowered."""
    fn, args, in_shard, out_shard, rules = build_cell(cfg, shape, mesh, **kw)
    # donate the mutable aggregate (train state / decode cache) so outputs
    # alias inputs — on real hardware this halves resident state
    donate = ()
    if shape.kind == "train":
        donate = (0,)
    elif shape.kind == "decode":
        donate = (1,)
    with mesh, use_rules(rules):
        jf = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard,
                     donate_argnums=donate)
        return jf.lower(*args)
