"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the available devices (reduced config by default so it
executes on CPU; ``--full`` uses the production config — only sensible on a
real slice).  Fault tolerance on by default: checkpoints every
``--save-every`` steps, resumes from the latest checkpoint in --ckpt-dir.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.checkpoint import CheckpointManager
from repro.data import synthetic_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.lm import model as lm
from repro.models.lm.sharding import AxisRules, use_rules
from repro.optim import make_optimizer
from repro.runtime.resilience import FaultTolerantLoop, StragglerMonitor
from repro.train.steps import TrainState, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="production config (needs a real slice)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg, dtype="float32")
    mesh = (make_production_mesh() if args.full and
            len(jax.devices()) >= 256 else make_host_mesh())
    rules = AxisRules(mesh, cfg.policy, cfg.moe)
    opt = make_optimizer(cfg.optimizer, lr=args.lr)
    step_fn = make_train_step(cfg, opt, microbatches=args.microbatches)

    extras = {}
    if cfg.vlm_patches:
        extras["image_embeds"] = lambda r: r.normal(
            0, 0.02, (args.batch, cfg.vlm_patches, cfg.d_model)).astype(
                np.float32)
    if cfg.enc_dec:
        extras["frames"] = lambda r: r.normal(
            0, 0.02, (args.batch, max(args.seq // cfg.enc_ratio, 8),
                      cfg.d_model)).astype(np.float32)
    gen = synthetic_batches(cfg.vocab, args.batch, args.seq, extras=extras)

    with mesh, use_rules(rules):
        state = TrainState(jnp.zeros((), jnp.int32),
                           lm.init_params(cfg, jax.random.PRNGKey(0)), None)
        state = TrainState(state.step, state.params,
                           opt.init(state.params))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        ckpt = CheckpointManager(args.ckpt_dir)
        mon = StragglerMonitor()
        loop = FaultTolerantLoop(jit_step, ckpt, args.save_every, mon)
        t0 = time.time()
        state, metrics = loop.run(state, gen, args.steps,
                                  crash_at=args.crash_at)
        dt = time.time() - t0
    print(f"[train] arch={cfg.name} steps={args.steps} "
          f"final_loss={float(metrics['loss']):.4f} "
          f"wall={dt:.1f}s stragglers={len(mon.flagged)}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
