import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: jax.jit(step).lower(ShapeDtypeStructs).compile() must succeed
on the production mesh; we record memory_analysis(), cost_analysis(), and the
trip-count-aware HLO analysis (FLOPs / bytes / collective bytes per device)
into a JSON file consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--out dir]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES, cell_applicable, get_config
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import lower_cell

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = RESULTS, hierarchy_levels: int = 0,
             tag: str = "", overrides: dict | None = None) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        pol = {k[7:]: v for k, v in overrides.items()
               if k.startswith("policy.")}
        moe = {k[4:]: v for k, v in overrides.items() if k.startswith("moe.")}
        top = {k: v for k, v in overrides.items() if "." not in k}
        if pol:
            cfg = dataclasses.replace(
                cfg, policy=dataclasses.replace(cfg.policy, **pol))
        if moe and cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **moe))
        if top:
            cfg = dataclasses.replace(cfg, **top)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    ok, reason = cell_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "tag": tag, "hierarchy_levels": hierarchy_levels}
    if not ok:
        rec.update(status="skipped", reason=reason)
        _write(out_dir, cell_id, rec)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        kw = {}
        if shape.kind in ("train", "prefill") and hierarchy_levels:
            kw["hierarchy_levels"] = hierarchy_levels
        lowered = lower_cell(cfg, shape, mesh, **kw)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze(compiled.as_text())
        n_dev = mesh.devices.size
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_devices=n_dev,
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_per_device": (ma.argument_size_in_bytes
                                          + ma.temp_size_in_bytes),
            },
            xla_cost={"flops_per_call": ca.get("flops", 0.0),
                      "bytes_accessed": ca.get("bytes accessed", 0.0)},
            hlo=hlo,
            model_flops=_model_flops(cfg, shape),
        )
    except Exception as e:  # noqa: BLE001 — any failure is a bug to record
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(out_dir, cell_id, rec)
    return rec


def _model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch       # decode: one token per seq


def _write(out_dir: Path, cell_id: str, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(out_dir / f"{cell_id}.json", "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=Path, default=RESULTS)
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--hierarchy-levels", type=int, default=0)
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (policy.x / moe.x / x)")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        elif v.lstrip("-").isdigit():
            overrides[k] = int(v)
        else:
            try:
                overrides[k] = float(v)
            except ValueError:
                overrides[k] = v
    if args.microbatches is not None:
        from repro.launch import specs
        for a in ASSIGNED_ARCHS:
            mb, acc = specs.TRAIN_MICROBATCHES.get(a, (1, "float32"))
            specs.TRAIN_MICROBATCHES[a] = (args.microbatches, acc)

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape}__{mesh_name}" + (
                    f"__{args.tag}" if args.tag else "")
                if args.skip_done and (args.out / f"{cell}.json").exists():
                    prev = json.loads((args.out / f"{cell}.json").read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip] {cell}: {prev['status']}")
                        continue
                print(f"[run ] {cell} ...", flush=True)
                rec = run_cell(arch, shape, mp, args.out,
                               args.hierarchy_levels, args.tag, overrides)
                msg = rec["status"]
                if rec["status"] == "ok":
                    peak = rec["memory"]["peak_bytes_per_device"] / 2**30
                    msg += (f" peak={peak:.2f}GiB/dev "
                            f"flops/dev={rec['hlo']['flops_per_device']:.3e} "
                            f"coll={sum(rec['hlo']['collective_bytes'].values()):.3e}B "
                            f"compile={rec['compile_s']}s")
                elif rec["status"] == "error":
                    msg += f" {rec['error'][:200]}"
                print(f"[done] {cell}: {msg}", flush=True)


if __name__ == "__main__":
    main()
