"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod-slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis rides
the DCI links and composes with ``data`` for batch parallelism (lowest
inter-pod traffic: gradient all-reduce once per step).

``shape=`` overrides the pod-scale defaults for small deployments: the
serving layer builds data-only replica meshes (e.g. ``shape=(4,)`` on a
host forced to 8 devices) without needing 256 chips.  Axis names are
inferred from the rank — ``("data",)``, ``("data", "model")``,
``("pod", "data", "model")`` — so downstream code can always address the
``data`` axis by name.

Defined as functions so importing this module never touches jax device
state (device count is locked on first jax init).
"""
from __future__ import annotations

import jax

_AXES_BY_RANK = {1: ("data",), 2: ("data", "model"),
                 3: ("pod", "data", "model")}


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple | None = None):
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    shape = tuple(int(s) for s in shape)
    if len(shape) not in _AXES_BY_RANK or any(s < 1 for s in shape):
        raise ValueError(f"mesh shape must be 1-3 positive axis sizes, "
                         f"got {shape!r}")
    axes = _AXES_BY_RANK[len(shape)]
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh():
    """Single-device mesh for smoke tests (1, 1)."""
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def replica_shardings(mesh) -> list:
    """One fully-replicated ``NamedSharding`` per ``data``-axis index of
    ``mesh`` — the placement list a ``ReplicaSet`` stripes prepared
    parameters over.  Each entry is a single-slice submesh (one device for
    a data-only mesh; that slice's model/pod devices otherwise) with an
    empty ``PartitionSpec``, so committing a tree to it pins every leaf to
    that replica's devices and jit dispatches the whole batch there."""
    import numpy as np
    if "data" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'data' axis: {mesh.axis_names}")
    axis = mesh.axis_names.index("data")
    devs = np.asarray(mesh.devices)
    out = []
    for r in range(devs.shape[axis]):
        sub = np.expand_dims(np.take(devs, r, axis=axis), axis)
        submesh = jax.sharding.Mesh(sub, mesh.axis_names)
        out.append(jax.sharding.NamedSharding(
            submesh, jax.sharding.PartitionSpec()))
    return out
