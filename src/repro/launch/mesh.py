"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod-slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis rides
the DCI links and composes with ``data`` for batch parallelism (lowest
inter-pod traffic: gradient all-reduce once per step).

Defined as functions so importing this module never touches jax device
state (device count is locked on first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh():
    """Single-device mesh for smoke tests (1, 1)."""
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
