"""Typed serving errors: every failure a caller can see has a name.

The request-level contract is that **every future issued by ``submit``
resolves exactly once** — with a logits row or with one of these typed
errors — and that admission failures raise synchronously (backpressure
the caller can act on immediately).

Every class carries three stable class attributes so transports above the
in-process server (``repro.frontend``) can map failures without
``isinstance`` ladders:

  * ``code`` — a stable machine-readable identifier, serialized on the
    wire and kept backward compatible;
  * ``retryable`` — True when the request was definitely NOT served
    (shed, closed, or swept before dispatch), so a router may safely
    re-issue it elsewhere without risking a second answer;
  * ``wire_status`` — the HTTP status the front door responds with
    (429 reject-with-backpressure, 503 unavailable, 504 too late).
"""
from __future__ import annotations


class ServingError(RuntimeError):
    """Base class for all typed serving failures."""

    code = "serving_error"
    retryable = False
    wire_status = 500


class ServerClosed(ServingError):
    """``submit`` on a server that is not running: not yet started, or
    already shut down.  Raised synchronously — no future is issued, so a
    router may retry the request on another worker."""

    code = "server_closed"
    retryable = True
    wire_status = 503


class Overloaded(ServingError):
    """Load shed: the request's lane is at its queue-depth bound, or an
    admission gate above the server (token bucket, pending bound) refused
    it.  Raised synchronously at ``submit`` (reject-with-backpressure)
    instead of buffering without bound.  ``lane`` and ``bound`` identify
    the queue; ``lane_label`` is the human-readable shedding lane (e.g.
    ``"mbv2@96x96/p1"``), carried so metrics and wire responses can name
    the saturated lane without re-deriving it."""

    code = "overloaded"
    retryable = True
    wire_status = 429

    def __init__(self, msg: str, *, lane=None, bound: int | None = None,
                 label: str | None = None):
        super().__init__(msg)
        self.lane = lane
        self.bound = bound
        self.lane_label = label if label is not None else (
            str(lane) if lane is not None else None)


class DeadlineExceeded(ServingError):
    """The request's per-request deadline passed before its batch was
    dispatched — late work is rejected, not served.  NOT retryable: the
    deadline has passed everywhere, and re-issuing could double-serve a
    row whose first attempt is still racing the sweep."""

    code = "deadline_exceeded"
    retryable = False
    wire_status = 504

    def __init__(self, msg: str, *, waited_s: float = 0.0,
                 deadline_s: float = 0.0):
        super().__init__(msg)
        self.waited_s = waited_s
        self.deadline_s = deadline_s


class Shutdown(ServingError):
    """The server shut down before this request could be served.  Every
    still-pending future resolves with this — a drain never hangs.  The
    row was swept, not served, so another worker may retry it."""

    code = "shutdown"
    retryable = True
    wire_status = 503
