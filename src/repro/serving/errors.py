"""Typed serving errors: every failure a caller can see has a name.

The request-level contract is that **every future issued by ``submit``
resolves exactly once** — with a logits row or with one of these typed
errors — and that admission failures raise synchronously (backpressure
the caller can act on immediately).
"""
from __future__ import annotations


class ServingError(RuntimeError):
    """Base class for all typed serving failures."""


class ServerClosed(ServingError):
    """``submit`` on a server that is not running: not yet started, or
    already shut down.  Raised synchronously — no future is issued."""


class Overloaded(ServingError):
    """Load shed: the request's lane is at its queue-depth bound.  Raised
    synchronously at ``submit`` (reject-with-backpressure) instead of
    buffering without bound.  ``lane`` and ``bound`` identify the queue."""

    def __init__(self, msg: str, *, lane=None, bound: int | None = None):
        super().__init__(msg)
        self.lane = lane
        self.bound = bound


class DeadlineExceeded(ServingError):
    """The request's per-request deadline passed before its batch was
    dispatched — late work is rejected, not served."""

    def __init__(self, msg: str, *, waited_s: float = 0.0,
                 deadline_s: float = 0.0):
        super().__init__(msg)
        self.waited_s = waited_s
        self.deadline_s = deadline_s


class Shutdown(ServingError):
    """The server shut down before this request could be served.  Every
    still-pending future resolves with this — a drain never hangs."""
