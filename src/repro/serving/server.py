"""HeteroServer: batched multi-plan serving on the compiled engine.

The deployment half of the paper's argument: per-layer FPGA-GPU gains only
matter if the serving loop preserves them.  ``HeteroServer`` keeps one
compiled engine per registered (modules, plans) pair resident — SqueezeNet,
MobileNetV2 and ShuffleNetV2 plans simultaneously, keyed by the PR-1 plan
signature — admits single-image requests into a dynamic batcher, and
dispatches padded bucket-sized batches from a background drain thread.

    server = HeteroServer(buckets=(1, 4, 8, 32), max_wait_ms=2.0,
                          in_flight=4)
    server.register("mbv2", mods, plans, params, input_hw=(96, 96))
    with server:                        # starts the drain loop
        fut = server.submit("mbv2", image)        # returns immediately
        logits = fut.result()                     # de-batched row

``in_flight`` is the dispatch depth.  At 1 (the pre-pipelining behaviour)
the drain loop host-blocks on every batch: pad, compute, de-batch, repeat —
fully serialized.  At k > 1 the drain loop leans on JAX's async dispatch
and submits batches without ``block_until_ready()``, gating only on the
(k-1)-th oldest unfinished computation BEFORE the next dispatch; a
completion thread blocks on results in FIFO order, de-batches, and
resolves futures as they land.  So padding and de-batching of
neighbouring batches overlap device compute instead of gating it, and
per-request ordering is preserved by construction (single dispatcher,
single FIFO completion queue).  k = 2 keeps computations serialized and
overlaps only host work (pad of batch i+1, de-batch of batch i-1, future
resolution) with batch i's compute; k > 2 additionally admits concurrent
computations — a win where per-op parallelism cannot fill the hardware
(small feature maps, depthwise-heavy nets, genuinely distinct devices)
and a cache-thrashing wash on large maps that already saturate a shared
host (measured in ``benchmarks/run.py pipeline``).  Dispatched batch
buffers are donated to the engine (the drain loop owns them and never
reads them back): one input copy saved per batch.

Guarantees:
  * results are bit-identical to ``compile_network`` called one request at
    a time — the engine is batch-invariant, padding rows are inert, and
    neither donation nor in-flight depth changes any computed value;
  * every bucket shape is compile-warmed at register time, so no live
    request pays a jit trace;
  * a ``clear_cache()`` in ``repro.core.executor`` does not break a live
    server: the drain loop notices the stale engine and transparently
    recompiles (counted in ``stats()['recompiles']``).

``register(..., pipelined=True)`` serves a network through the
stage-pipelined engine (``compile_pipelined``) instead of the monolithic
one — same bits, device hand-offs exposed for overlap.
"""
from __future__ import annotations

import queue
import threading
import time

import jax
import numpy as np

from repro.core.executor import compile_network, compile_pipelined
from repro.core.hetero import init_network
from repro.serving.batcher import (DEFAULT_BUCKETS, DynamicBatcher, Request,
                                   pad_batch, pick_bucket)
from repro.serving.metrics import ServerMetrics


class _Entry:
    """One registered network: engine + prepared params + bucket policy."""

    def __init__(self, name, mods, plans, params, input_hw, buckets,
                 use_pallas, calib_x=None, pipelined=False):
        self.name = name
        self.mods = mods
        self.plans = plans
        self.params = params
        self.input_hw = tuple(input_hw)
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self.calib_x = calib_x
        self.pipelined = pipelined
        self._compile = compile_pipelined if pipelined else compile_network
        self.engine = self._compile(mods, plans, use_pallas=use_pallas)
        if self.engine.needs_calibration and calib_x is None:
            raise ValueError(
                f"{name}: plans request calibration (Plan.calibrate=True) "
                f"— register(..., calib_x=batch) is required")
        self.prepared = self.engine.prepare(params, calib_x)
        self.c_in = mods[0].nodes[0].spec.c_in

    def input_shape(self, batch: int) -> tuple:
        return (batch, *self.input_hw, self.c_in)

    def warmup(self) -> dict:
        # warm the donating variant: it is what the dispatch path calls
        return self.engine.warmup(
            self.prepared, [self.input_shape(b) for b in self.buckets],
            donate=True)

    def refresh(self):
        """Re-acquire the engine after an executor cache clear (re-running
        calibration from the stored batch when the plans need it)."""
        self.engine = self._compile(self.mods, self.plans,
                                    use_pallas=self.use_pallas)
        self.prepared = self.engine.prepare(self.params, self.calib_x)
        self.warmup()


class HeteroServer:
    """Async dynamic-batching server over ``repro.core.executor``."""

    def __init__(self, *, buckets=DEFAULT_BUCKETS, max_wait_ms: float = 2.0,
                 use_pallas: bool | None = None, in_flight: int = 1):
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self.in_flight = max(1, int(in_flight))
        self._batcher = DynamicBatcher(max_wait_s=max_wait_ms * 1e-3,
                                       max_batch=self.buckets[-1])
        self._entries: dict[str, _Entry] = {}
        self._caps: dict[str, tuple] = {}      # per-network bucket ladder
        self.metrics = ServerMetrics()
        self._thread: threading.Thread | None = None
        self._cthread: threading.Thread | None = None
        # dispatched-but-unresolved batches, FIFO to the completion thread
        self._completions: queue.Queue | None = (
            queue.Queue() if self.in_flight > 1 else None)
        # async results the dispatcher has not yet gated on (depth window)
        self._outstanding: list = []
        self._stop = threading.Event()
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------

    def register(self, name: str, mods, plans=None, params=None, *,
                 input_hw=(96, 96), buckets=None, warm: bool = True,
                 use_pallas: bool | None = None, calib_x=None,
                 pipelined: bool = False) -> dict:
        """Compile, prepare and bucket-warm a network under ``name``.

        ``buckets`` overrides the server-wide bucket ladder (per-network
        policy: e.g. cap a cache-thrashing workload at batch 8).
        ``calib_x`` is the calibration batch for plans that freeze
        activation scales at prepare time (``Plan.calibrate``) — required
        for such plans, ignored otherwise.  Calibrated and uncalibrated
        plans carry different plan signatures, so mixed registrations
        never share an engine.  ``pipelined=True`` serves through the
        stage-pipelined engine (bit-identical results; device hand-offs
        exposed for overlap).  Returns the engine's exec stats after
        warm-up (one trace per bucket)."""
        if params is None:
            params = init_network(mods, jax.random.PRNGKey(0))
        if use_pallas is None:
            use_pallas = self.use_pallas    # server-wide default
        entry = _Entry(name, mods, plans, params,
                       input_hw, buckets or self.buckets, use_pallas,
                       calib_x=calib_x, pipelined=pipelined)
        with self._lock:
            self._entries[name] = entry
            self._caps[name] = entry.buckets
        return entry.warmup() if warm else entry.engine.exec_stats()

    def networks(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "HeteroServer":
        if self._thread is not None:
            return self
        self._stop.clear()
        if self._completions is not None:
            self._cthread = threading.Thread(target=self._completion_loop,
                                             name="hetero-serve-complete",
                                             daemon=True)
            self._cthread.start()
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="hetero-serve-drain",
                                        daemon=True)
        self._thread.start()
        return self

    def shutdown(self, timeout: float = 10.0) -> None:
        """Stop the drain loop after flushing everything still queued (and,
        at in_flight > 1, after every dispatched batch completed)."""
        if self._thread is None:
            return
        self._stop.set()
        self._batcher.put(Request("__wake__", None))   # unblock wait_ready
        self._thread.join(timeout)
        if self._thread.is_alive():
            # drain thread still mid-flush (e.g. a long recompile): leave
            # the completion thread running so its batches still resolve;
            # a later shutdown() retries the join
            return
        self._thread = None
        for name, reqs in self._batcher.drain_all():
            reqs = [r for r in reqs if r.network != "__wake__"]
            if not reqs:
                continue
            # a backlog can exceed the largest bucket — flush in chunks
            cap = self._caps.get(name, self.buckets)[-1]
            for i in range(0, len(reqs), cap):
                self._flush(name, reqs[i:i + cap], by_deadline=True)
        if self._cthread is not None:
            self._completions.put(None)                # completion sentinel
            self._cthread.join(timeout)
            self._cthread = None

    def __enter__(self) -> "HeteroServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path ------------------------------------------------------

    def submit(self, name: str, x):
        """Admit one image; returns a ``concurrent.futures.Future`` whose
        result is that request's logits row."""
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"unregistered network {name!r}; "
                           f"registered: {self.networks()}")
        x = np.asarray(x) if not hasattr(x, "shape") else x
        if tuple(x.shape) == entry.input_shape(1):
            x = x[0]
        want = entry.input_shape(1)[1:]
        if tuple(x.shape) != want:
            raise ValueError(f"{name}: expected image of shape {want} "
                             f"(or (1, *shape)), got {tuple(x.shape)}")
        req = Request(name, x)
        self.metrics.record_submit(now=time.monotonic())
        self._batcher.put(req)
        return req.future

    def submit_many(self, name: str, images) -> list:
        return [self.submit(name, x) for x in images]

    # -- drain loop --------------------------------------------------------

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            got = self._batcher.wait_ready(timeout=0.05,
                                           buckets_by=self._caps)
            if got is None:
                continue
            name, reqs, by_deadline = got
            reqs = [r for r in reqs if r.network != "__wake__"]
            if reqs:
                self._flush(name, reqs, by_deadline)

    def _flush(self, name: str, reqs, by_deadline: bool) -> None:
        """Dispatch one batch.  At in_flight == 1 this also completes it
        inline (the fully-serialized pre-pipelining loop); otherwise the
        async result is handed to the completion thread and this thread
        immediately returns to batching — padding of batch i+1 overlaps
        device compute of batch i."""
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:                     # unregistered mid-flight
            for r in reqs:
                r.future.set_exception(KeyError(name))
            self.metrics.record_failure(len(reqs))
            return
        try:
            if not entry.engine.is_current():
                # executor cache was cleared under us: rebuild, stay live
                entry.refresh()
                self.metrics.record_recompile()
            bucket = pick_bucket(len(reqs), entry.buckets)
            xb = pad_batch([r.x for r in reqs], bucket)
            if self._completions is not None:
                # depth gate BEFORE dispatch: this batch is padded and
                # ready while at most (in_flight - 1) computations are
                # still unfinished — at in_flight=2 compute stays
                # serialized and only host work overlaps it
                while len(self._outstanding) >= self.in_flight - 1:
                    jax.block_until_ready(self._outstanding.pop(0))
            # xb is drain-loop-owned and never read after dispatch: donate
            # its buffer (exec_stats counts the copies saved)
            out = entry.engine(entry.prepared, xb, donate=True)
            if self._completions is not None:
                self._outstanding.append(out)
                self._completions.put((reqs, bucket, by_deadline, out))
            else:
                self._complete(reqs, bucket, by_deadline, out)
        except Exception as e:                # pragma: no cover - defensive
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            self.metrics.record_failure(len(reqs))

    def _complete(self, reqs, bucket: int, by_deadline: bool, out) -> None:
        """Resolve one dispatched batch: block until the device result
        lands, de-batch, fulfil futures."""
        try:
            jax.block_until_ready(out)
            # one host copy, then de-batch as numpy views — per-row device
            # slices would pay 1 dispatch per request
            rows = np.asarray(out)
            now = time.monotonic()
            lats = [now - r.t_enqueue for r in reqs]
            for i, r in enumerate(reqs):
                r.future.set_result(rows[i])
            self.metrics.record_batch(len(reqs), bucket, lats, by_deadline,
                                      now=now)
        except Exception as e:                # pragma: no cover - defensive
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            self.metrics.record_failure(len(reqs))

    def _completion_loop(self) -> None:
        """FIFO completion path (in_flight > 1): batches resolve in
        dispatch order, so per-request ordering survives pipelining."""
        while True:
            item = self._completions.get()
            if item is None:                  # shutdown sentinel
                return
            self._complete(*item)

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """Server metrics + per-engine exec/trace stats + executor cache."""
        from repro.core.executor import cache_stats
        with self._lock:
            engines = {name: {**e.engine.exec_stats(),
                              "current": e.engine.is_current(),
                              "pipelined": e.pipelined,
                              "buckets": e.buckets}
                       for name, e in self._entries.items()}
        return {"server": self.metrics.snapshot(),
                "in_flight": self.in_flight, "engines": engines,
                "executor_cache": cache_stats()}
