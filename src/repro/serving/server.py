"""HeteroServer: batched multi-plan, multi-resolution QoS serving.

The deployment half of the paper's argument: per-layer FPGA-GPU gains only
matter if the serving loop preserves them.  ``HeteroServer`` keeps one
compiled engine per registered (modules, plans) pair resident — SqueezeNet,
MobileNetV2 and ShuffleNetV2 plans simultaneously, keyed by the PR-1 plan
signature — admits single-image requests into a multi-lane dynamic batcher,
and dispatches padded bucket-sized batches from a background drain thread.

    server = HeteroServer(buckets=(1, 4, 8, 32), max_wait_ms=2.0,
                          in_flight=4)
    server.register("mbv2", mods, plans, params,
                    input_hw=[(96, 96), (64, 64)])    # one lane set per res
    with server:                        # starts the drain loop
        fut = server.submit("mbv2", image)            # returns immediately
        hot = server.submit("mbv2", image, priority=0)   # deadline-critical
        logits = fut.result()                         # de-batched row

**Multi-resolution lanes.**  ``register(..., input_hw=...)`` accepts one
(H, W) or a list of them; every (network, resolution, priority) triple is
its own batching lane, so batches never mix input shapes and each
(resolution, bucket) pair is a separately warmed resident jit trace —
compiled programs for all registered resolutions stay resident
side-by-side.  ``submit`` infers the lane from the image's shape.

**Priority lanes.**  ``submit(..., priority=0)`` routes to the
deadline-critical lane: its deadline is a fraction (default 1/4) of the
bulk max-wait, so urgent requests preempt bulk traffic at flush time,
while deadline flushes stay earliest-deadline-first overall — the
starvation guard that keeps every bulk lane's wait bounded even under a
saturated high-priority lane (``repro.serving.batcher``).

**In-flight-aware admission.**  Deadline flushes are gated on downstream
occupancy: while ``in_flight`` batches are still unfinished, a partial
bucket would only queue behind them, so the batcher keeps accumulating
(up to a hard deadline) and flushes a fuller batch when a slot frees.
Full buckets are never deferred.

**Prepared-parameter hot-swap.**  ``swap_params(net, params)`` prepares
the new weights on a shadow handle (the expensive half, outside the
server lock; serialized against stale-engine recompiles)
and then atomically redirects dispatch to it — the queue is never
drained.  Batches already dispatched finish on the old parameter
generation; every batch flushed after the swap returns uses the new one
(``repro.core.executor.PreparedParams`` stamps the generation, and
``stats()``/``metrics`` record the swap).  Bit-match contract across a
swap: every served row equals a batch-1 engine call under exactly ONE
parameter generation — generations never mix inside a batch, and requests
submitted after ``swap_params`` returns are guaranteed the new one.

Guarantees:
  * results are bit-identical to ``compile_network`` called one request at
    a time — the engine is batch-invariant, padding rows are inert, and
    neither donation, in-flight depth, lane, nor priority changes any
    computed value;
  * every (bucket, resolution) shape is compile-warmed at register time,
    so no live request pays a jit trace;
  * a ``clear_cache()`` in ``repro.core.executor`` does not break a live
    server: the drain loop notices the stale engine and transparently
    recompiles (counted in ``stats()['recompiles']``).

``register(..., pipelined=True)`` serves a network through the
stage-pipelined engine (``compile_pipelined``) instead of the monolithic
one — same bits, device hand-offs exposed for overlap.
"""
from __future__ import annotations

import queue
import threading
import time

import jax
import numpy as np

from repro.core.executor import compile_network, compile_pipelined
from repro.core.hetero import init_network
from repro.serving.batcher import (DEFAULT_BUCKETS, DEFAULT_PRIORITY,
                                   DynamicBatcher, LaneKey, Request,
                                   pad_batch, pick_bucket)
from repro.serving.metrics import ServerMetrics


def _normalize_resolutions(input_hw) -> tuple:
    """Accept a single (H, W) pair or an iterable of pairs."""
    hw = tuple(input_hw)
    if hw and all(isinstance(v, int) for v in hw):
        hw = (hw,)
    res = tuple(tuple(int(v) for v in r) for r in hw)
    if not res or any(len(r) != 2 for r in res):
        raise ValueError(f"input_hw must be (H, W) or a list of (H, W) "
                         f"pairs, got {input_hw!r}")
    if len(set(res)) != len(res):
        raise ValueError(f"duplicate resolutions in input_hw: {input_hw!r}")
    return res


def lane_label(lane: LaneKey) -> str:
    """Human-readable lane name for the metrics snapshot."""
    res = "x".join(str(v) for v in lane.res) if lane.res else "?"
    return f"{lane.network}@{res}/p{lane.priority}"


class _Entry:
    """One registered network: engine + prepared params + bucket policy +
    the set of admitted input resolutions."""

    def __init__(self, name, mods, plans, params, input_hw, buckets,
                 use_pallas, calib_x=None, pipelined=False):
        self.name = name
        self.mods = mods
        self.plans = plans
        self.params = params
        self.resolutions = _normalize_resolutions(input_hw)
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self.calib_x = calib_x
        self.pipelined = pipelined
        self._compile = compile_pipelined if pipelined else compile_network
        self.engine = self._compile(mods, plans, use_pallas=use_pallas)
        if self.engine.needs_calibration and calib_x is None:
            raise ValueError(
                f"{name}: plans request calibration (Plan.calibrate=True) "
                f"— register(..., calib_x=batch) is required")
        self.prepared = self.engine.prepare(params, calib_x)
        self.c_in = mods[0].nodes[0].spec.c_in
        # serializes swap_params against refresh: a stale-engine recompile
        # must never finish AFTER a swap it started BEFORE and silently
        # revert the served parameters to the pre-swap generation
        self.swap_lock = threading.Lock()

    def input_shape(self, batch: int, res: tuple | None = None) -> tuple:
        return (batch, *(res or self.resolutions[0]), self.c_in)

    def match_res(self, shape: tuple) -> tuple | None:
        """The registered resolution an (H, W, C) image shape belongs to."""
        for r in self.resolutions:
            if tuple(shape) == (*r, self.c_in):
                return r
        return None

    def warmup(self) -> dict:
        # warm the donating variant: it is what the dispatch path calls
        return self.engine.warmup(
            self.prepared,
            [self.input_shape(b, r)
             for r in self.resolutions for b in self.buckets],
            donate=True)

    def refresh(self):
        """Re-acquire the engine after an executor cache clear (re-running
        calibration from the stored batch when the plans need it).  Keeps
        the CURRENT params, and holds ``swap_lock`` end to end so a
        concurrent ``swap_params`` either completes before the recompile
        reads ``self.params`` or lands after it — a hot-swap that raced
        the clear always survives."""
        with self.swap_lock:
            self.engine = self._compile(self.mods, self.plans,
                                        use_pallas=self.use_pallas)
            self.prepared = self.engine.prepare(self.params, self.calib_x)
            self.warmup()


class HeteroServer:
    """Async dynamic-batching server over ``repro.core.executor``."""

    def __init__(self, *, buckets=DEFAULT_BUCKETS, max_wait_ms: float = 2.0,
                 use_pallas: bool | None = None, in_flight: int = 1):
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self.in_flight = max(1, int(in_flight))
        self._batcher = DynamicBatcher(max_wait_s=max_wait_ms * 1e-3,
                                       max_batch=self.buckets[-1])
        self._entries: dict[str, _Entry] = {}
        self._caps: dict[str, tuple] = {}      # per-network bucket ladder
        self.metrics = ServerMetrics()
        self._thread: threading.Thread | None = None
        self._cthread: threading.Thread | None = None
        # dispatched-but-unresolved batches, FIFO to the completion thread
        self._completions: queue.Queue | None = (
            queue.Queue() if self.in_flight > 1 else None)
        # async results the dispatcher has not yet gated on (depth window)
        self._outstanding: list = []
        # dispatched-but-uncompleted batch count: the admission signal the
        # batcher's deadline deferral reads (downstream occupancy)
        self._inflight_batches = 0
        self._inflight_lock = threading.Lock()
        self._stop = threading.Event()
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------

    def register(self, name: str, mods, plans=None, params=None, *,
                 input_hw=(96, 96), buckets=None, warm: bool = True,
                 use_pallas: bool | None = None, calib_x=None,
                 pipelined: bool = False) -> dict:
        """Compile, prepare and bucket-warm a network under ``name``.

        ``input_hw`` is one (H, W) pair or a list of them: every listed
        resolution gets its own batching lanes and its own warmed jit
        traces, resident side-by-side (``submit`` routes by image shape).
        ``buckets`` overrides the server-wide bucket ladder (per-network
        policy: e.g. cap a cache-thrashing workload at batch 8).
        ``calib_x`` is the calibration batch for plans that freeze
        activation scales at prepare time (``Plan.calibrate``) — required
        for such plans, ignored otherwise.  Calibrated and uncalibrated
        plans carry different plan signatures, so mixed registrations
        never share an engine.  ``pipelined=True`` serves through the
        stage-pipelined engine (bit-identical results; device hand-offs
        exposed for overlap).  Returns the engine's exec stats after
        warm-up (one trace per bucket x resolution)."""
        if params is None:
            params = init_network(mods, jax.random.PRNGKey(0))
        if use_pallas is None:
            use_pallas = self.use_pallas    # server-wide default
        entry = _Entry(name, mods, plans, params,
                       input_hw, buckets or self.buckets, use_pallas,
                       calib_x=calib_x, pipelined=pipelined)
        with self._lock:
            self._entries[name] = entry
            self._caps[name] = entry.buckets
        return entry.warmup() if warm else entry.engine.exec_stats()

    def networks(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    def swap_params(self, name: str, params, *, calib_x=None) -> dict:
        """Hot-swap a registered network's weights without draining.

        The new parameters are prepared on a shadow handle first (weight
        quantization + optional re-calibration — the expensive half runs
        outside the server lock, so live traffic keeps flowing on the old
        generation), then dispatch is atomically redirected.  In-flight
        batches finish on the old generation; every batch flushed after
        this returns uses the new one.  The entry's ``swap_lock``
        serializes this against concurrent swaps and against stale-engine
        ``refresh`` recompiles, so a recompile that raced the swap can
        never revert it.  ``calib_x`` defaults to the batch stored at
        register time (calibrated plans re-freeze their scales against
        the new weights).  Returns the new generation stamp."""
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"unregistered network {name!r}; "
                           f"registered: {self.networks()}")
        with entry.swap_lock:
            cal = calib_x if calib_x is not None else entry.calib_x
            prepared = entry.engine.prepare(params, cal)  # shadow prepare
            with self._lock:
                entry.params = params
                if calib_x is not None:
                    entry.calib_x = calib_x
                old_gen = entry.prepared.generation
                entry.prepared = prepared                 # atomic redirect
        self.metrics.record_swap()
        return {"network": name, "generation": prepared.generation,
                "previous_generation": old_gen}

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "HeteroServer":
        if self._thread is not None:
            return self
        self._stop.clear()
        if self._completions is not None:
            self._cthread = threading.Thread(target=self._completion_loop,
                                             name="hetero-serve-complete",
                                             daemon=True)
            self._cthread.start()
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="hetero-serve-drain",
                                        daemon=True)
        self._thread.start()
        return self

    def shutdown(self, timeout: float = 10.0) -> None:
        """Stop the drain loop after flushing everything still queued (and,
        at in_flight > 1, after every dispatched batch completed)."""
        if self._thread is None:
            return
        self._stop.set()
        self._batcher.put(Request("__wake__", None))   # unblock wait_ready
        self._thread.join(timeout)
        if self._thread.is_alive():
            # drain thread still mid-flush (e.g. a long recompile): leave
            # the completion thread running so its batches still resolve;
            # a later shutdown() retries the join
            return
        self._thread = None
        for lane, reqs in self._batcher.drain_all():
            reqs = [r for r in reqs if r.network != "__wake__"]
            if not reqs:
                continue
            # a backlog can exceed the largest bucket — flush in chunks
            cap = self._caps.get(lane.network, self.buckets)[-1]
            for i in range(0, len(reqs), cap):
                self._flush(lane, reqs[i:i + cap], by_deadline=True)
        if self._cthread is not None:
            self._completions.put(None)                # completion sentinel
            self._cthread.join(timeout)
            self._cthread = None

    def __enter__(self) -> "HeteroServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path ------------------------------------------------------

    def submit(self, name: str, x, *, priority: int = DEFAULT_PRIORITY):
        """Admit one image; returns a ``concurrent.futures.Future`` whose
        result is that request's logits row.  The image's (H, W) picks the
        resolution lane; ``priority <= 0`` routes to the deadline-critical
        lane (shorter flush deadline), larger values are bulk traffic."""
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"unregistered network {name!r}; "
                           f"registered: {self.networks()}")
        x = np.asarray(x) if not hasattr(x, "shape") else x
        shape = tuple(x.shape)
        if len(shape) == 4 and shape[0] == 1:
            x, shape = x[0], shape[1:]
        res = entry.match_res(shape)
        if res is None:
            want = [entry.input_shape(1, r)[1:] for r in entry.resolutions]
            raise ValueError(f"{name}: expected an image of shape "
                             f"{' or '.join(map(str, want))} "
                             f"(or with a leading batch-1 axis), "
                             f"got {shape}")
        req = Request(name, x, res=res, priority=int(priority))
        self.metrics.record_submit(now=time.monotonic())
        self._batcher.put(req)
        return req.future

    def submit_many(self, name: str, images, *,
                    priority: int = DEFAULT_PRIORITY) -> list:
        return [self.submit(name, x, priority=priority) for x in images]

    # -- drain loop --------------------------------------------------------

    def _inflight(self) -> int:
        with self._inflight_lock:
            return self._inflight_batches

    def _inflight_add(self, d: int) -> None:
        with self._inflight_lock:
            self._inflight_batches += d

    def _can_dispatch(self) -> bool:
        """Downstream admission signal for the batcher: False while the
        dispatch window is fully occupied (a deadline flush would only
        queue behind in-flight batches — keep accumulating instead)."""
        return self._inflight() < self.in_flight

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            got = self._batcher.wait_ready(timeout=0.05,
                                           buckets_by=self._caps,
                                           can_dispatch=self._can_dispatch)
            if got is None:
                continue
            lane, reqs, by_deadline = got
            reqs = [r for r in reqs if r.network != "__wake__"]
            if reqs:
                self._flush(lane, reqs, by_deadline)

    def _flush(self, lane: LaneKey, reqs, by_deadline: bool) -> None:
        """Dispatch one single-lane batch.  At in_flight == 1 this also
        completes it inline (the fully-serialized pre-pipelining loop);
        otherwise the async result is handed to the completion thread and
        this thread immediately returns to batching — padding of batch i+1
        overlaps device compute of batch i."""
        with self._lock:
            entry = self._entries.get(lane.network)
        if entry is None:                     # unregistered mid-flight
            for r in reqs:
                r.future.set_exception(KeyError(lane.network))
            self.metrics.record_failure(len(reqs))
            return
        try:
            if not entry.engine.is_current():
                # executor cache was cleared under us: rebuild, stay live
                entry.refresh()
                self.metrics.record_recompile()
            # one snapshot per batch: a concurrent swap_params lands either
            # wholly before or wholly after this batch, never inside it
            prepared = entry.prepared
            bucket = pick_bucket(len(reqs), entry.buckets)
            xb = pad_batch([r.x for r in reqs], bucket)
            if self._completions is not None:
                # depth gate BEFORE dispatch: this batch is padded and
                # ready while at most (in_flight - 1) computations are
                # still unfinished — at in_flight=2 compute stays
                # serialized and only host work overlaps it
                while len(self._outstanding) >= self.in_flight - 1:
                    jax.block_until_ready(self._outstanding.pop(0))
            # xb is drain-loop-owned and never read after dispatch: donate
            # its buffer (exec_stats counts the copies saved)
            out = entry.engine(prepared, xb, donate=True)
            self._inflight_add(1)
            if self._completions is not None:
                self._outstanding.append(out)
                self._completions.put((lane, reqs, bucket, by_deadline, out))
            else:
                self._complete(lane, reqs, bucket, by_deadline, out)
        except Exception as e:                # pragma: no cover - defensive
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            self.metrics.record_failure(len(reqs))

    def _complete(self, lane: LaneKey, reqs, bucket: int, by_deadline: bool,
                  out) -> None:
        """Resolve one dispatched batch: block until the device result
        lands, de-batch, fulfil futures, release the admission slot."""
        try:
            jax.block_until_ready(out)
            # one host copy, then de-batch as numpy views — per-row device
            # slices would pay 1 dispatch per request
            rows = np.asarray(out)
            now = time.monotonic()
            lats = [now - r.t_enqueue for r in reqs]
            for i, r in enumerate(reqs):
                r.future.set_result(rows[i])
            self.metrics.record_batch(len(reqs), bucket, lats, by_deadline,
                                      now=now, lane=lane_label(lane))
        except Exception as e:                # pragma: no cover - defensive
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            self.metrics.record_failure(len(reqs))
        finally:
            self._inflight_add(-1)
            self._batcher.kick()    # a slot freed: deferred flushes re-check

    def _completion_loop(self) -> None:
        """FIFO completion path (in_flight > 1): batches resolve in
        dispatch order, so per-request ordering survives pipelining."""
        while True:
            item = self._completions.get()
            if item is None:                  # shutdown sentinel
                return
            self._complete(*item)

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """Server metrics + per-engine exec/trace stats + executor cache."""
        from repro.core.executor import cache_stats
        with self._lock:
            engines = {name: {**e.engine.exec_stats(),
                              "current": e.engine.is_current(),
                              "pipelined": e.pipelined,
                              "buckets": e.buckets,
                              "resolutions": e.resolutions,
                              "param_generation": e.prepared.generation}
                       for name, e in self._entries.items()}
        return {"server": self.metrics.snapshot(),
                "in_flight": self.in_flight,
                "inflight_batches": self._inflight(),
                "engines": engines,
                "executor_cache": cache_stats()}
