"""HeteroServer: batched multi-plan, multi-resolution QoS serving.

The deployment half of the paper's argument: per-layer FPGA-GPU gains only
matter if the serving loop preserves them.  ``HeteroServer`` keeps one
compiled engine per registered (modules, plans) pair resident — SqueezeNet,
MobileNetV2 and ShuffleNetV2 plans simultaneously, keyed by the PR-1 plan
signature — admits single-image requests into a multi-lane dynamic batcher,
and dispatches padded bucket-sized batches from a background drain thread.

    server = HeteroServer(buckets=(1, 4, 8, 32), max_wait_ms=2.0,
                          in_flight=4)
    server.register("mbv2", mods, plans, params,
                    input_hw=[(96, 96), (64, 64)])    # one lane set per res
    with server:                        # starts the drain loop
        fut = server.submit("mbv2", image)            # returns immediately
        hot = server.submit("mbv2", image, priority=0)   # deadline-critical
        logits = fut.result()                         # de-batched row

**Multi-resolution lanes.**  ``register(..., input_hw=...)`` accepts one
(H, W) or a list of them; every (network, resolution, priority) triple is
its own batching lane, so batches never mix input shapes and each
(resolution, bucket) pair is a separately warmed resident jit trace —
compiled programs for all registered resolutions stay resident
side-by-side.  ``submit`` infers the lane from the image's shape.

**Priority lanes.**  ``submit(..., priority=0)`` routes to the
deadline-critical lane: its deadline is a fraction (default 1/4) of the
bulk max-wait, so urgent requests preempt bulk traffic at flush time,
while deadline flushes stay earliest-deadline-first overall — the
starvation guard that keeps every bulk lane's wait bounded even under a
saturated high-priority lane (``repro.serving.batcher``).

**In-flight-aware admission.**  Deadline flushes are gated on downstream
occupancy: while ``in_flight`` batches are still unfinished, a partial
bucket would only queue behind them, so the batcher keeps accumulating
(up to a hard deadline) and flushes a fuller batch when a slot frees.
Full buckets are never deferred.

**Replica-striped dispatch** (PR 8).  ``register(..., replicas=R)`` (or
an explicit ``mesh=``) stripes one network's traffic across R data-axis
replicas of a device mesh: the parameters are prepared ONCE and a copy
is committed to each replica's devices under one shared generation stamp
(``repro.core.executor.ReplicaSet``); each flushed batch routes whole to
the least-outstanding replica (round-robin on ties), ``in_flight``
becomes a per-replica depth, metrics grow per-replica lanes, and the
straggler watchdog's backup dispatch fires on a DIFFERENT replica than
the straggling one.  ``swap_params``/plan migrations swap all replicas
atomically — no batch ever mixes parameter generations across replicas —
and every served row still bit-matches the single-device batch-1 oracle
(same program, same prepared tree; placement only moves it).

**Prepared-parameter hot-swap.**  ``swap_params(net, params)`` prepares
the new weights on a shadow handle (the expensive half, outside the
server lock; serialized against stale-engine recompiles)
and then atomically redirects dispatch to it — the queue is never
drained.  Batches already dispatched finish on the old parameter
generation; every batch flushed after the swap returns uses the new one
(``repro.core.executor.PreparedParams`` stamps the generation, and
``stats()``/``metrics`` record the swap).  Bit-match contract across a
swap: every served row equals a batch-1 engine call under exactly ONE
parameter generation — generations never mix inside a batch, and requests
submitted after ``swap_params`` returns are guaranteed the new one.

**Failure semantics** (the PR-6 fault-tolerance contract):

  * **Every future issued by ``submit`` resolves exactly once** — with a
    logits row, or with a typed error (``repro.serving.errors``).  There
    is no path on which an admitted request hangs: dispatch failures
    de-batch into one bounded head-of-lane retry and then reject;
    ``shutdown`` flushes the backlog and sweeps whatever is left with
    ``Shutdown``.
  * **Admission failures raise synchronously.**  ``submit`` on a server
    that is not running raises ``ServerClosed``; a lane at its
    queue-depth bound (``max_queue``) raises ``Overloaded``
    (reject-with-backpressure, never unbounded buffering).
  * **Per-request deadlines.**  ``submit(..., deadline_ms=...)``: a
    request whose deadline passes before its batch dispatches resolves
    with ``DeadlineExceeded`` instead of being served late.
  * **Degraded-mode failover.**  Each entry carries a circuit breaker
    over device-attributed dispatch failures: after
    ``breaker_threshold`` consecutive FPGA-attributed failures the
    server shadow-prepares the GPU-only plan for the same modules (the
    paper's all-GPU baseline), bucket-warms it, and atomically redirects
    live traffic to it — the ``swap_params`` mechanism generalized from
    weight swaps to plan swaps.  While failed over, half-open probe
    batches run on the hybrid plan every ``probe_interval_s``;
    ``recover_after`` consecutive passes swap traffic back.  Served rows
    always bit-match the batch-1 oracle of the plan that served them.
  * **Straggler defense.**  The completion loop polls each dispatched
    batch against a rolling budget (``straggler_factor`` x the entry's
    median completion, via ``repro.runtime.resilience.StragglerMonitor``);
    a batch past its budget counts a watchdog event and, for pipelined
    entries, races a backup monolithic dispatch of the same batch.
  * All of it is deterministic under ``repro.runtime.faults`` injection —
    no hardware fault required to exercise any path in CI.

**Online re-partitioning** (PR 7, ``repro.core.replan``).  Constructed
with ``replanner=Replanner(...)``, the server samples every
``measure_every``-th primary-mode batch through the engine's timed
dispatch (per-stage walls on pipelined entries), attributes the measured
times to the cost model's device/transfer coefficients, and re-fits them
over a sliding window.  When re-partitioning under the fitted model
predicts a latency win that clears the replanner's hysteresis (>= 15%
for >= K consecutive windows by default), the entry hot-migrates:
``_Entry.migrate`` is the breaker-failover shadow-prepare/atomic-redirect
generalized to ANY candidate plan set.  ``stats()['replan']`` carries the
fitted coefficients and migration log; rows served before and after a
migration each bit-match their own plan generation's batch-1 oracle.

Guarantees:
  * results are bit-identical to ``compile_network`` called one request at
    a time — the engine is batch-invariant, padding rows are inert, and
    neither donation, in-flight depth, lane, nor priority changes any
    computed value;
  * every (bucket, resolution) shape is compile-warmed at register time,
    so no live request pays a jit trace;
  * a ``clear_cache()`` in ``repro.core.executor`` does not break a live
    server: the drain loop notices the stale engine and transparently
    recompiles (counted in ``stats()['recompiles']``).

``register(..., pipelined=True)`` serves a network through the
stage-pipelined engine (``compile_pipelined``) instead of the monolithic
one — same bits, device hand-offs exposed for overlap.
"""
from __future__ import annotations

import queue
import threading
import time

import jax
import numpy as np

from repro.core.executor import (ReplicaSet, compile_network,
                                 compile_pipelined)
from repro.core.hetero import init_network
from repro.launch.mesh import make_production_mesh
from repro.core.replan import Replanner, carry_calibration
from repro.core.schedule import network_stage_components
from repro.runtime import faults
from repro.runtime.resilience import StragglerMonitor
from repro.serving.batcher import (DEFAULT_BUCKETS, DEFAULT_PRIORITY,
                                   DynamicBatcher, LaneKey, Request,
                                   pad_batch, pick_bucket)
from repro.serving.errors import (DeadlineExceeded, Overloaded, ServerClosed,
                                  Shutdown)
from repro.serving.metrics import ServerMetrics


def _normalize_resolutions(input_hw) -> tuple:
    """Accept a single (H, W) pair or an iterable of pairs."""
    hw = tuple(input_hw)
    if hw and all(isinstance(v, int) for v in hw):
        hw = (hw,)
    res = tuple(tuple(int(v) for v in r) for r in hw)
    if not res or any(len(r) != 2 for r in res):
        raise ValueError(f"input_hw must be (H, W) or a list of (H, W) "
                         f"pairs, got {input_hw!r}")
    if len(set(res)) != len(res):
        raise ValueError(f"duplicate resolutions in input_hw: {input_hw!r}")
    return res


def lane_label(lane: LaneKey) -> str:
    """Human-readable lane name for the metrics snapshot."""
    res = "x".join(str(v) for v in lane.res) if lane.res else "?"
    return f"{lane.network}@{res}/p{lane.priority}"


class _Breaker:
    """Per-network circuit breaker over FPGA-attributed dispatch failures.

    closed -> open after ``threshold`` consecutive failures on the
    primary (hybrid) plan; while open, half-open probe batches run on
    the primary every ``probe_interval_s`` and ``recover_after``
    consecutive passes close it again.  Not thread-safe on its own —
    all transitions happen on the drain thread."""

    def __init__(self, threshold: int = 3, probe_interval_s: float = 0.25,
                 recover_after: int = 2):
        self.threshold = max(1, int(threshold))
        self.probe_interval_s = probe_interval_s
        self.recover_after = max(1, int(recover_after))
        self.state = "closed"
        self.fails = 0              # consecutive primary failures
        self.oks = 0                # consecutive half-open probe passes
        self.last_probe = 0.0

    @property
    def label(self) -> str:
        if self.state == "open" and self.oks > 0:
            return "half_open"      # probing, partway to recovery
        return self.state

    def record_failure(self) -> bool:
        """True when this failure trips (or finds) the breaker open."""
        self.fails += 1
        if self.fails >= self.threshold:
            self.state = "open"
            self.oks = 0
        return self.state == "open"

    def record_success(self) -> None:
        self.fails = 0

    def probe_due(self, now: float) -> bool:
        return (self.state == "open"
                and now - self.last_probe >= self.probe_interval_s)

    def record_probe(self, ok: bool, now: float) -> bool:
        """True when this probe completes recovery (breaker closes)."""
        self.last_probe = now
        if not ok:
            self.oks = 0
            return False
        self.oks += 1
        if self.oks >= self.recover_after:
            self.state = "closed"
            self.fails = self.oks = 0
            return True
        return False


class _Entry:
    """One registered network: engine + prepared params + bucket policy +
    the set of admitted input resolutions + the fault-tolerance state
    (circuit breaker, GPU-only fallback variant, straggler monitor)."""

    def __init__(self, name, mods, plans, params, input_hw, buckets,
                 use_pallas, calib_x=None, pipelined=False,
                 breaker: _Breaker | None = None,
                 straggler_factor: float = 4.0,
                 replicas: int = 1, mesh=None,
                 ema_batches: int = 16, ema_alpha: float = 0.25):
        self.name = name
        self.mods = mods
        self.plans = plans
        self.params = params
        self.resolutions = _normalize_resolutions(input_hw)
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self.calib_x = calib_x
        self.pipelined = pipelined
        # replica striping: an explicit mesh wins; replicas > 1 builds a
        # data-only mesh over the first ``replicas`` devices.  mesh=None,
        # replicas=1 keeps the raw engine — the pre-replication path,
        # byte for byte.
        self.mesh = mesh
        if self.mesh is None and int(replicas) > 1:
            self.mesh = make_production_mesh(shape=(int(replicas),))
        self._compile = compile_pipelined if pipelined else compile_network
        self.engine = self._wrap(
            self._compile(mods, plans, use_pallas=use_pallas))
        self.replicas = (self.engine.n_replicas
                         if isinstance(self.engine, ReplicaSet) else 1)
        if self.engine.needs_calibration and calib_x is None:
            raise ValueError(
                f"{name}: plans request calibration (Plan.calibrate=True) "
                f"— register(..., calib_x=batch) is required")
        self.prepared = self.engine.prepare(params, calib_x)
        # online EMA scale refinement budget (Plan.calibrate("ema")):
        # the first ``ema_batches`` primary batches each blend their
        # captured amplitudes into the frozen scales
        self.ema_left = (int(ema_batches)
                         if getattr(self.engine, "ema_modules", None) else 0)
        self.ema_alpha = float(ema_alpha)
        self.c_in = mods[0].nodes[0].spec.c_in
        # model-side stage decomposition of the LIVE plan set — aligned
        # 1:1 with the pipelined engine's executable stages, this is what
        # measured stage times are attributed against (repro.core.replan)
        self.stage_comps = network_stage_components(mods, plans)
        self.plan_generation = 0            # bumped by each replan migration
        self.measure_seq = 0                # batches since registration
        # serializes swap_params against refresh: a stale-engine recompile
        # must never finish AFTER a swap it started BEFORE and silently
        # revert the served parameters to the pre-swap generation
        self.swap_lock = threading.Lock()
        # failover state: "primary" serves the registered (hybrid) plans,
        # "fallback" the GPU-only plan for the same modules
        self.mode = "primary"
        self.fb_engine = None               # lazily compiled GPU-only plan
        self.fb_prepared = None
        self.bk_engine = None               # lazy monolithic straggler backup
        self.bk_prepared = None
        self.breaker = breaker or _Breaker()
        self.monitor = StragglerMonitor(threshold=straggler_factor)
        self._seq = 0

    def _wrap(self, eng):
        """Stripe an engine across this entry's mesh when replicated;
        single-replica entries keep the raw engine (the pre-replication
        serving path, byte for byte)."""
        return ReplicaSet(eng, self.mesh) if self.mesh is not None else eng

    def input_shape(self, batch: int, res: tuple | None = None) -> tuple:
        return (batch, *(res or self.resolutions[0]), self.c_in)

    def match_res(self, shape: tuple) -> tuple | None:
        """The registered resolution an (H, W, C) image shape belongs to."""
        for r in self.resolutions:
            if tuple(shape) == (*r, self.c_in):
                return r
        return None

    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def active(self):
        """(engine, prepared) snapshot of the live variant."""
        if self.mode == "fallback":
            return self.fb_engine, self.fb_prepared
        return self.engine, self.prepared

    def _warm_shapes(self) -> list:
        return [self.input_shape(b, r)
                for r in self.resolutions for b in self.buckets]

    def warmup(self) -> dict:
        # warm the donating variant: it is what the dispatch path calls
        return self.engine.warmup(self.prepared, self._warm_shapes(),
                                  donate=True)

    def ensure_fallback(self) -> None:
        """Shadow-prepare the GPU-only plan (the paper's all-GPU baseline):
        compiled, prepared and bucket-warmed BEFORE any live traffic is
        redirected to it — failover is an atomic pointer swap, not a
        compile on the request path."""
        if self.fb_engine is None or not self.fb_engine.is_current():
            # the fallback inherits the entry's replica striping, so a
            # failover keeps serving across the same mesh
            self.fb_engine = self._wrap(compile_network(
                self.mods, None, use_pallas=self.use_pallas))
            self.fb_prepared = self.fb_engine.prepare(self.params)
            self.fb_engine.warmup(self.fb_prepared, self._warm_shapes(),
                                  donate=True)

    def failover(self) -> None:
        with self.swap_lock:
            self.ensure_fallback()
            self.mode = "fallback"          # atomic redirect

    def recover(self) -> None:
        with self.swap_lock:
            self.mode = "primary"

    def probe(self, xb) -> bool:
        """Half-open probe: one batch on the primary (hybrid) engine,
        output discarded — live traffic keeps flowing on the fallback.
        Dispatches a COPY through the donating path (the only variant
        ``warmup`` traces — a non-donating call here would pay a fresh
        jit trace mid-failover), so the caller's buffer survives for the
        real dispatch."""
        try:
            out = self.engine(self.prepared, np.array(xb), donate=True)
            jax.block_until_ready(out)
            return True
        except Exception:
            return False

    def ensure_backup(self):
        """Monolithic engine over the SAME plans — the straggler backup
        for pipelined entries (bit-identical results, no stage hand-offs
        to stall on).  None for entries already monolithic."""
        if not self.pipelined:
            return None
        if self.bk_engine is None or not self.bk_engine.is_current():
            self.bk_engine = compile_network(self.mods, self.plans,
                                             use_pallas=self.use_pallas)
            self.bk_prepared = self.bk_engine.prepare(self.params,
                                                      self.calib_x)
        return self.bk_engine

    def refresh(self) -> None:
        """Re-acquire the engine after an executor cache clear (re-running
        calibration from the stored batch when the plans need it).  Keeps
        the CURRENT params, and holds ``swap_lock`` end to end so a
        concurrent ``swap_params`` either completes before the recompile
        reads ``self.params`` or lands after it — a hot-swap that raced
        the clear always survives.  The fallback variant (if built) is
        rebuilt too; the straggler backup rebuilds lazily."""
        faults.trip("refresh")
        with self.swap_lock:
            self.engine = self._wrap(self._compile(
                self.mods, self.plans, use_pallas=self.use_pallas))
            self.prepared = self.engine.prepare(self.params, self.calib_x)
            self.warmup()
            if self.fb_engine is not None:
                self.fb_engine = None
                self.ensure_fallback()
            self.bk_engine = None

    def migrate(self, plans) -> None:
        """Hot-migrate this entry to a replanner candidate plan set — the
        breaker-failover machinery generalized from "the GPU-only plan"
        to ANY plan: shadow-compile, prepare and bucket-warm the new
        plans' engine first (live traffic keeps flowing on the old one),
        then atomically redirect under ``swap_lock``.  Batches already
        dispatched finish on the old plan generation; every batch flushed
        after this returns serves the new one, and each still bit-matches
        its own plan's batch-1 oracle.  Candidate plans inherit the live
        plans' per-module calibration choice (a migration never changes
        quantization semantics)."""
        plans = carry_calibration(self.plans, plans)
        eng = self._wrap(self._compile(self.mods, plans,
                                       use_pallas=self.use_pallas))
        cal = self.calib_x if eng.needs_calibration else None
        prep = eng.prepare(self.params, cal)
        eng.warmup(prep, self._warm_shapes(), donate=True)
        with self.swap_lock:
            self.plans = plans
            self.engine = eng
            self.prepared = prep                # atomic redirect
            self.stage_comps = network_stage_components(self.mods, plans)
            self.bk_engine = None   # straggler backup follows the new plans
            self.plan_generation += 1


class HeteroServer:
    """Async dynamic-batching server over ``repro.core.executor``."""

    def __init__(self, *, buckets=DEFAULT_BUCKETS, max_wait_ms: float = 2.0,
                 use_pallas: bool | None = None, in_flight: int = 1,
                 max_queue: int = 1024, breaker_threshold: int = 3,
                 probe_interval_s: float = 0.25, recover_after: int = 2,
                 straggler_factor: float = 4.0,
                 straggler_min_ms: float = 50.0,
                 replanner: Replanner | None = None,
                 measure_every: int = 8,
                 ema_batches: int = 16, ema_alpha: float = 0.25):
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self.in_flight = max(1, int(in_flight))
        self.max_queue = max(1, int(max_queue))
        # online re-partitioning: every ``measure_every``-th primary-mode
        # batch dispatches through the engine's timed path (serialized,
        # per-stage walls), feeds the replanner's fitter, and may trigger
        # a hot plan migration (repro.core.replan)
        self._replanner = replanner
        self.measure_every = max(1, int(measure_every))
        # online EMA scale refinement (Plan.calibrate("ema")): budget of
        # refined batches per entry, and the blend factor per batch
        self.ema_batches = max(0, int(ema_batches))
        self.ema_alpha = float(ema_alpha)
        # widest replica fan-out across entries: scales the dispatch
        # window the batcher's deadline deferral reads (1 = today's gate)
        self._max_replicas = 1
        self._breaker_cfg = (breaker_threshold, probe_interval_s,
                             recover_after)
        self.straggler_factor = straggler_factor
        self._straggler_min_s = straggler_min_ms * 1e-3
        self._batcher = DynamicBatcher(max_wait_s=max_wait_ms * 1e-3,
                                       max_batch=self.buckets[-1])
        self._entries: dict[str, _Entry] = {}
        self._caps: dict[str, tuple] = {}      # per-network bucket ladder
        self.metrics = ServerMetrics()
        self._thread: threading.Thread | None = None
        self._cthread: threading.Thread | None = None
        # dispatched-but-unresolved batches, FIFO to the completion thread
        self._completions: queue.Queue | None = (
            queue.Queue() if self.in_flight > 1 else None)
        # async results the dispatcher has not yet gated on (depth window)
        self._outstanding: list = []
        # dispatched-but-uncompleted batch count: the admission signal the
        # batcher's deadline deferral reads (downstream occupancy)
        self._inflight_batches = 0
        self._inflight_lock = threading.Lock()
        self._stop = threading.Event()
        self._lock = threading.Lock()
        # every admitted future, until resolved: the shutdown sweep's
        # ground truth that nothing ever hangs
        self._pending: set = set()
        self._pending_lock = threading.Lock()
        self._state = "new"                 # -> "running" -> "closed"
        # live-state gauges for /healthz and /metrics: served through
        # ``metrics.snapshot()`` so transports read counters AND gauges
        # from one call (the provider reads under the batcher's and the
        # pending registry's own locks — no new locking)
        self.metrics.set_gauge_provider(self._gauge_snapshot)

    def _gauge_snapshot(self) -> dict:
        with self._pending_lock:
            pending = len(self._pending)
        depths = self._batcher.depths()
        return {"state": self._state,
                "pending_requests": pending,
                "inflight_batches": self._inflight(),
                "queue_total": sum(depths.values()),
                "queue_depth": {lane_label(lane): d
                                for lane, d in depths.items()}}

    @property
    def state(self) -> str:
        """Lifecycle state: ``new`` -> ``running`` -> ``closed``."""
        return self._state

    # -- registration ------------------------------------------------------

    def register(self, name: str, mods, plans=None, params=None, *,
                 input_hw=(96, 96), buckets=None, warm: bool = True,
                 use_pallas: bool | None = None, calib_x=None,
                 pipelined: bool = False,
                 prewarm_fallback: bool = False,
                 replicas: int = 1, mesh=None) -> dict:
        """Compile, prepare and bucket-warm a network under ``name``.

        ``input_hw`` is one (H, W) pair or a list of them: every listed
        resolution gets its own batching lanes and its own warmed jit
        traces, resident side-by-side (``submit`` routes by image shape).
        ``buckets`` overrides the server-wide bucket ladder (per-network
        policy: e.g. cap a cache-thrashing workload at batch 8).
        ``calib_x`` is the calibration batch for plans that freeze
        activation scales at prepare time (``Plan.calibrate``) — required
        for such plans, ignored otherwise.  Calibrated and uncalibrated
        plans carry different plan signatures, so mixed registrations
        never share an engine.  ``pipelined=True`` serves through the
        stage-pipelined engine (bit-identical results; device hand-offs
        exposed for overlap).  ``prewarm_fallback=True`` compiles and
        bucket-warms the GPU-only failover plan NOW, bounding a later
        failover pause to the atomic redirect instead of a first-failure
        compile (by default the fallback builds lazily when the breaker
        trips).  ``replicas=R`` (or an explicit ``mesh=``) stripes this
        network's traffic across R data-axis replicas: the parameters are
        prepared once and committed per replica (one shared generation
        stamp), flushed batches route to the least-outstanding replica,
        and each replica gets its own in-flight slots and metrics lane —
        requires at least R devices (``make_production_mesh(shape=(R,))``).
        Returns the engine's exec stats after warm-up (one trace per
        bucket x resolution, per replica)."""
        if params is None:
            params = init_network(mods, jax.random.PRNGKey(0))
        if use_pallas is None:
            use_pallas = self.use_pallas    # server-wide default
        entry = _Entry(name, mods, plans, params,
                       input_hw, buckets or self.buckets, use_pallas,
                       calib_x=calib_x, pipelined=pipelined,
                       breaker=_Breaker(*self._breaker_cfg),
                       straggler_factor=self.straggler_factor,
                       replicas=replicas, mesh=mesh,
                       ema_batches=self.ema_batches,
                       ema_alpha=self.ema_alpha)
        if prewarm_fallback and plans is not None:
            entry.ensure_fallback()
        with self._lock:
            self._entries[name] = entry
            self._caps[name] = entry.buckets
            self._max_replicas = max(self._max_replicas, entry.replicas)
        self.metrics.set_breaker(name, entry.breaker.label)
        return entry.warmup() if warm else entry.engine.exec_stats()

    def networks(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    def swap_params(self, name: str, params, *, calib_x=None) -> dict:
        """Hot-swap a registered network's weights without draining.

        The new parameters are prepared on a shadow handle first (weight
        quantization + optional re-calibration — the expensive half runs
        outside the server lock, so live traffic keeps flowing on the old
        generation), then dispatch is atomically redirected.  In-flight
        batches finish on the old generation; every batch flushed after
        this returns uses the new one.  The entry's ``swap_lock``
        serializes this against concurrent swaps and against stale-engine
        ``refresh`` recompiles, so a recompile that raced the swap can
        never revert it.  ``calib_x`` defaults to the batch stored at
        register time (calibrated plans re-freeze their scales against
        the new weights).  A built GPU-only fallback variant re-prepares
        under the same swap, so a later failover serves the new weights.
        Returns the new generation stamp."""
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"unregistered network {name!r}; "
                           f"registered: {self.networks()}")
        with entry.swap_lock:
            cal = calib_x if calib_x is not None else entry.calib_x
            prepared = entry.engine.prepare(params, cal)  # shadow prepare
            fb_prepared = (entry.fb_engine.prepare(params)
                           if entry.fb_engine is not None else None)
            with self._lock:
                entry.params = params
                if calib_x is not None:
                    entry.calib_x = calib_x
                old_gen = entry.prepared.generation
                entry.prepared = prepared                 # atomic redirect
                if fb_prepared is not None:
                    entry.fb_prepared = fb_prepared
                entry.bk_engine = None    # backup re-prepares on next use
        self.metrics.record_swap()
        return {"network": name, "generation": prepared.generation,
                "previous_generation": old_gen}

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "HeteroServer":
        if self._state == "closed":
            raise ServerClosed("start() after shutdown(): a HeteroServer "
                               "is single-use")
        if self._thread is not None:
            return self
        self._state = "running"
        self._stop.clear()
        if self._completions is not None:
            self._cthread = threading.Thread(target=self._completion_loop,
                                             name="hetero-serve-complete",
                                             daemon=True)
            self._cthread.start()
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="hetero-serve-drain",
                                        daemon=True)
        self._thread.start()
        return self

    def shutdown(self, timeout: float = 10.0) -> None:
        """Graceful drain: stop admission first, flush everything still
        queued (partial buckets included, in chunks when a backlog
        exceeds the largest bucket), let every dispatched batch complete
        (at in_flight > 1 via the completion thread), then resolve
        anything still pending with ``Shutdown`` — a shutdown never
        leaves a future hanging."""
        self._state = "closed"                         # stop admission
        if self._thread is not None:
            self._stop.set()
            self._batcher.put(Request("__wake__", None))  # unblock wait_ready
            self._thread.join(timeout)
            if self._thread.is_alive():
                # drain thread still mid-flush (e.g. a long recompile):
                # leave the completion thread running so its batches still
                # resolve; a later shutdown() retries the join
                return
            self._thread = None
            # bounded passes: a dispatch-failure retry during the drain
            # re-enqueues head-of-lane and must still be flushed
            for _ in range(3):
                drained = self._batcher.drain_all()
                if not drained:
                    break
                for lane, reqs in drained:
                    reqs = [r for r in reqs if r.network != "__wake__"]
                    if not reqs:
                        continue
                    # a backlog can exceed the largest bucket — chunk it
                    cap = self._caps.get(lane.network, self.buckets)[-1]
                    for i in range(0, len(reqs), cap):
                        self.metrics.count("drain_flushed")
                        self._flush(lane, reqs[i:i + cap], by_deadline=True)
            if self._cthread is not None:
                self._completions.put(None)            # completion sentinel
                self._cthread.join(timeout)
                self._cthread = None
        # registry sweep: whatever survived the flush resolves typed
        with self._pending_lock:
            leftovers = list(self._pending)
            self._pending.clear()
        for fut in leftovers:
            if fut.done():
                continue
            try:
                fut.set_exception(Shutdown("server shut down before this "
                                           "request could be served"))
                self.metrics.count("drain_aborted")
            except Exception:           # resolved in the race window: fine
                pass

    def __enter__(self) -> "HeteroServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path ------------------------------------------------------

    def _fulfil(self, fut, value) -> None:
        """Resolve a future with a result, exactly once (late duplicates —
        e.g. a shutdown sweep racing a completion — are dropped)."""
        with self._pending_lock:
            self._pending.discard(fut)
        try:
            fut.set_result(value)
        except Exception:
            pass

    def _reject(self, fut, exc) -> None:
        with self._pending_lock:
            self._pending.discard(fut)
        try:
            fut.set_exception(exc)
        except Exception:
            pass

    def submit(self, name: str, x, *, priority: int = DEFAULT_PRIORITY,
               deadline_ms: float | None = None):
        """Admit one image; returns a ``concurrent.futures.Future`` whose
        result is that request's logits row.  The image's (H, W) picks the
        resolution lane; ``priority <= 0`` routes to the deadline-critical
        lane (shorter flush deadline), larger values are bulk traffic.

        ``deadline_ms`` is a per-request deadline from now: if the batch
        holding the request has not dispatched by then, the future
        resolves with ``DeadlineExceeded``.  Raises ``ServerClosed`` when
        the server is not running, ``Overloaded`` when the request's lane
        is at the ``max_queue`` depth bound (load shed)."""
        # validation precedes the state check: a malformed request is
        # malformed whether or not the server is running
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"unregistered network {name!r}; "
                           f"registered: {self.networks()}")
        x = np.asarray(x) if not hasattr(x, "shape") else x
        shape = tuple(x.shape)
        if len(shape) == 4 and shape[0] == 1:
            x, shape = x[0], shape[1:]
        res = entry.match_res(shape)
        if res is None:
            want = [entry.input_shape(1, r)[1:] for r in entry.resolutions]
            raise ValueError(f"{name}: expected an image of shape "
                             f"{' or '.join(map(str, want))} "
                             f"(or with a leading batch-1 axis), "
                             f"got {shape}")
        if self._state != "running":
            raise ServerClosed("submit() before start()"
                               if self._state == "new" else
                               "submit() after shutdown()")
        now = time.monotonic()
        deadline = None if deadline_ms is None else now + deadline_ms * 1e-3
        req = Request(name, x, res=res, priority=int(priority),
                      deadline_s=deadline)
        with self._pending_lock:
            self._pending.add(req.future)
        if not self._batcher.put(req, bound=self.max_queue):
            with self._pending_lock:
                self._pending.discard(req.future)
            self.metrics.count("shed")
            raise Overloaded(f"lane {lane_label(req.lane)} at queue-depth "
                             f"bound {self.max_queue}",
                             lane=req.lane, bound=self.max_queue,
                             label=lane_label(req.lane))
        self.metrics.record_submit(now=now)
        return req.future

    def submit_many(self, name: str, images, *,
                    priority: int = DEFAULT_PRIORITY) -> list:
        return [self.submit(name, x, priority=priority) for x in images]

    # -- drain loop --------------------------------------------------------

    def _inflight(self) -> int:
        with self._inflight_lock:
            return self._inflight_batches

    def _inflight_add(self, d: int) -> None:
        with self._inflight_lock:
            self._inflight_batches += d

    def _can_dispatch(self) -> bool:
        """Downstream admission signal for the batcher: False while the
        dispatch window is fully occupied (a deadline flush would only
        queue behind in-flight batches — keep accumulating instead).
        Replica striping widens the window: ``in_flight`` is a per-replica
        depth, so R replicas absorb R x in_flight batches."""
        return self._inflight() < self.in_flight * self._max_replicas

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            reqs: list = []
            try:
                got = self._batcher.wait_ready(
                    timeout=0.05, buckets_by=self._caps,
                    can_dispatch=self._can_dispatch)
                if got is None:
                    continue
                lane, popped, by_deadline = got
                reqs = [r for r in popped if r.network != "__wake__"]
                if reqs:
                    self._flush(lane, reqs, by_deadline)
            except Exception as e:      # defensive: the loop must survive
                self.metrics.count("errors")
                self.metrics.record_failure(len(reqs))
                for r in reqs:
                    self._reject(r.future, e)

    def _flush(self, lane: LaneKey, reqs, by_deadline: bool) -> None:
        """Dispatch one single-lane batch.  At in_flight == 1 this also
        completes it inline (the fully-serialized pre-pipelining loop);
        otherwise the async result is handed to the completion thread and
        this thread immediately returns to batching — padding of batch i+1
        overlaps device compute of batch i."""
        with self._lock:
            entry = self._entries.get(lane.network)
        if entry is None:                     # unregistered mid-flight
            for r in reqs:
                self._reject(r.future, KeyError(lane.network))
            self.metrics.record_failure(len(reqs))
            return
        # per-request deadlines: late rows reject BEFORE dispatch — a
        # deadline that passed while queued is never served late
        now = time.monotonic()
        live = []
        for r in reqs:
            if r.deadline_s is not None and now > r.deadline_s:
                self.metrics.count("deadline_exceeded")
                self.metrics.record_failure(1)
                self._reject(r.future, DeadlineExceeded(
                    f"queued {now - r.t_enqueue:.4f}s, deadline "
                    f"{r.deadline_s - r.t_enqueue:.4f}s",
                    waited_s=now - r.t_enqueue,
                    deadline_s=r.deadline_s - r.t_enqueue))
                continue
            live.append(r)
        if not live:
            return
        reqs = live
        engine = replica = None
        try:
            engine, prepared = entry.active()
            if not engine.is_current():
                # executor cache was cleared under us: rebuild, stay live
                entry.refresh()
                self.metrics.record_recompile()
                engine, prepared = entry.active()
            bucket = pick_bucket(len(reqs), entry.buckets)
            xb = pad_batch([r.x for r in reqs], bucket)
            if entry.mode == "fallback" and entry.breaker.probe_due(now):
                self._probe(entry, xb)
                # a completed recovery redirects THIS batch already
                engine, prepared = entry.active()
            striped = isinstance(engine, ReplicaSet)
            if self._completions is not None:
                # depth gate BEFORE dispatch: this batch is padded and
                # ready while at most (in_flight - 1) computations are
                # still unfinished — at in_flight=2 compute stays
                # serialized and only host work overlaps it.  Replica
                # striping scales the window: the gate is per replica.
                window = ((self.in_flight - 1)
                          * (engine.n_replicas if striped else 1))
                while len(self._outstanding) >= window:
                    jax.block_until_ready(self._outstanding.pop(0))
            # replica striping: claim the least-outstanding replica AFTER
            # the gate (freshest occupancy); released on completion
            replica = engine.pick() if striped else None
            rkw = {} if replica is None else {"replica": replica}
            # xb is drain-loop-owned and never read after dispatch: donate
            # its buffer (exec_stats counts the copies saved).  The host
            # array itself survives donation, so the completion path can
            # still re-dispatch it on the straggler backup engine.
            measured = None
            if self._replanner is not None and entry.mode == "primary":
                entry.measure_seq += 1
                if entry.measure_seq % self.measure_every == 0:
                    # sampled measurement batch: serialized timed dispatch
                    # with per-stage walls (pipelined) or one total
                    out, measured = engine.timed_call(prepared, xb,
                                                      donate=True, **rkw)
            if measured is None:
                out = engine(prepared, xb, donate=True, **rkw)
        except Exception as e:
            if replica is not None:
                engine.release(replica)
            self._dispatch_failure(entry, lane, reqs, e, by_deadline)
            return
        if entry.mode == "primary":
            entry.breaker.record_success()
            if entry.ema_left > 0:
                # online EMA scale refinement: this batch served under
                # ``prepared``'s generation; the refined tree redirects
                # the NEXT flush (atomic, one stamp across all replicas)
                self._ema_refine(entry, engine, prepared, xb)
        if measured is not None:
            self._maybe_replan(entry, lane, measured, bucket)
        self._inflight_add(1)
        item = (entry, lane, reqs, bucket, by_deadline, xb, out,
                engine, prepared, replica)
        if self._completions is not None:
            self._outstanding.append(out)
            self._completions.put(item)
        else:
            try:
                self._complete(*item)
            finally:
                self._inflight_add(-1)
                self._batcher.kick()

    def _dispatch_failure(self, entry: _Entry, lane: LaneKey, reqs,
                          exc: Exception, by_deadline: bool) -> None:
        """A dispatch raised before any result existed.  Policy:
        FPGA-attributed failures on the primary plan feed the circuit
        breaker — tripping it fails over to the GPU-only plan and
        re-dispatches the same rows WITHOUT spending their retry budget
        (the rows did nothing wrong).  Every other failure de-batches
        into one bounded retry per request, re-enqueued head-of-lane so
        FIFO-within-lane survives; rows out of budget reject with the
        original error."""
        dev = faults.fault_device(exc)
        if entry.mode == "primary" and dev == "fpga":
            if entry.breaker.record_failure():
                self.metrics.set_breaker(entry.name, entry.breaker.label)
                try:
                    entry.failover()
                except Exception:
                    pass     # fallback build failed: fall to the retry path
                else:
                    self.metrics.count("failovers")
                    self._flush(lane, reqs, by_deadline)  # budget-free retry
                    return
        retry, dead = [], []
        for r in reqs:
            if r.retries < 1:
                r.retries += 1
                retry.append(r)
            else:
                dead.append(r)
        if retry:
            self.metrics.count("retries", len(retry))
            self._batcher.put_front(retry)
        for r in dead:
            self._reject(r.future, exc)
        if dead:
            self.metrics.record_failure(len(dead))

    def _probe(self, entry: _Entry, xb) -> None:
        """Half-open probe batch on the primary engine (output discarded);
        ``recover_after`` consecutive passes swap live traffic back."""
        now = time.monotonic()
        ok = entry.probe(xb)
        self.metrics.count("probes_ok" if ok else "probes_failed")
        if entry.breaker.record_probe(ok, now):
            entry.recover()
            self.metrics.count("recoveries")
        self.metrics.set_breaker(entry.name, entry.breaker.label)

    # -- online EMA scale refinement ---------------------------------------

    def _ema_refine(self, entry: _Entry, engine, prepared, xb) -> None:
        """One step of the ``Plan.calibrate("ema")`` online calibrator:
        capture each EMA site's amplitude on the live batch (under the
        CURRENT frozen scales) and blend it into the frozen scale,
        ``s' = (1 - alpha) * s + alpha * s_batch``.  The refined tree is
        a fresh generation, redirected atomically under ``swap_lock`` —
        the batch that fed the capture keeps its own generation, and a
        refinement never overwrites a racing ``swap_params`` (it only
        lands while the handle it refined is still the live one).  On a
        replicated entry all replicas refine under ONE stamp."""
        try:
            # xb was donated to the dispatch above; the host array
            # survives, a copy keeps the capture's buffer independent
            scales = engine.capture_scales(prepared, np.array(xb))
            scales = {m: s for m, s in scales.items()
                      if m in engine.ema_modules}
            if not scales:
                entry.ema_left = 0
                return
            refined = engine.refine_scales(prepared, scales,
                                           alpha=entry.ema_alpha)
        except Exception:
            self.metrics.count("errors")
            return
        with entry.swap_lock:
            if entry.prepared is prepared:
                entry.prepared = refined
                entry.ema_left -= 1
                self.metrics.count("ema_updates")

    # -- online re-partitioning --------------------------------------------

    def _maybe_replan(self, entry: _Entry, lane: LaneKey, times,
                      batch: int) -> None:
        """Feed one measured batch to the replanner and execute its
        decision.  Runs on the drain thread, exactly like breaker
        failover: a migration's shadow compile+warm blocks batching
        briefly, but the redirect itself is atomic and the queue is never
        drained.  A failed migration leaves the live plan untouched."""
        rep = self._replanner
        rep.observe(entry.name, lane.res, entry.plans, entry.stage_comps,
                    times, batch)
        self.metrics.count("measured_batches")
        decision = rep.consider(entry.name, entry.mods, entry.plans)
        self.metrics.count("replan_checks")
        if decision.scales is not None:
            self.metrics.set_fitted(entry.name, decision.scales.as_dict())
        if not decision.migrate:
            return
        try:
            entry.migrate(decision.plans)
        except Exception:
            self.metrics.count("errors")
            return
        self.metrics.count("replans")

    # -- completion path ---------------------------------------------------

    def _watch(self, entry: _Entry, xb, out, engine=None, prepared=None,
               replica=None):
        """Straggler watchdog: poll the async result against the rolling
        budget (``straggler_factor`` x the entry's median completion,
        floored at ``straggler_min_ms``).  Past the budget: count the
        event and race a backup dispatch of the same batch — on a
        DIFFERENT replica for replicated entries, on the monolithic
        engine for pipelined ones.  Whichever result this returns, the
        bits match (same plans, same prepared generation contract)."""
        budget = entry.monitor.budget()
        if budget is None or not hasattr(out, "is_ready"):
            return out
        budget = max(budget, self._straggler_min_s)
        t0 = time.monotonic()
        while not out.is_ready():
            if time.monotonic() - t0 > budget:
                self.metrics.count("straggler_events")
                backup = self._backup_dispatch(entry, xb, engine, prepared,
                                               replica)
                return out if backup is None else backup
            time.sleep(0.0005)
        return out

    def _backup_dispatch(self, entry: _Entry, xb, engine=None,
                         prepared=None, replica=None):
        """Best-effort re-dispatch of a straggling batch; None (= keep
        waiting on the original) when no backup path exists or the backup
        itself fails.  A replicated entry re-dispatches on the
        least-outstanding OTHER replica — same prepared generation, same
        bits, but none of the straggler's device state; non-replicated
        pipelined entries keep the monolithic backup engine."""
        try:
            if (replica is not None and isinstance(engine, ReplicaSet)
                    and engine.n_replicas > 1):
                other = engine.peek(exclude=(replica,))
                self.metrics.count("backup_dispatches")
                self.metrics.count("cross_replica_backups")
                # a copy through the donating path: the only variant
                # warmup traced, and the original xb stays re-usable
                return engine(prepared, np.array(xb), donate=True,
                              replica=other)
            bk = entry.ensure_backup()
            if bk is None:
                return None
            self.metrics.count("backup_dispatches")
            return bk(entry.bk_prepared, xb)
        except Exception:
            return None

    def _complete(self, entry: _Entry, lane: LaneKey, reqs, bucket: int,
                  by_deadline: bool, xb, out, engine=None, prepared=None,
                  replica=None) -> None:
        """Resolve one dispatched batch: block until the device result
        lands (under the straggler watchdog), de-batch, fulfil futures.
        Callers release the admission slot (their ``finally``), so a
        crash in here can never double-release it; the replica slot the
        flush claimed is released HERE, in all paths."""
        t0 = time.monotonic()
        try:
            out = self._watch(entry, xb, out, engine, prepared, replica)
            jax.block_until_ready(out)
            entry.monitor.record(entry.next_seq(), time.monotonic() - t0)
            # one host copy, then de-batch as numpy views — per-row device
            # slices would pay 1 dispatch per request
            rows = np.asarray(out)
            now = time.monotonic()
            lats = [now - r.t_enqueue for r in reqs]
            for i, r in enumerate(reqs):
                self._fulfil(r.future, rows[i])
            self.metrics.record_batch(len(reqs), bucket, lats, by_deadline,
                                      now=now, lane=lane_label(lane),
                                      replica=(f"{entry.name}/r{replica}"
                                               if replica is not None
                                               else None))
        except Exception as e:
            # completion-time failure: the batch's rows get the error — no
            # retry from here (a requeue behind younger completed traffic
            # would break FIFO-within-lane at in_flight > 1)
            for r in reqs:
                self._reject(r.future, e)
            self.metrics.record_failure(len(reqs))
        finally:
            if replica is not None and isinstance(engine, ReplicaSet):
                engine.release(replica)

    def _completion_loop(self) -> None:
        """FIFO completion path (in_flight > 1): batches resolve in
        dispatch order, so per-request ordering survives pipelining.
        Wrapped so an unexpected error resolves the batch's futures and
        the loop keeps serving — one bad batch never wedges the server."""
        while True:
            item = self._completions.get()
            if item is None:                  # shutdown sentinel
                return
            reqs = item[2]
            try:
                self._complete(*item)
            except Exception as e:            # pragma: no cover - defensive
                self.metrics.count("errors")
                self.metrics.record_failure(len(reqs))
                for r in reqs:
                    self._reject(r.future, e)
            finally:
                self._inflight_add(-1)
                self._batcher.kick()  # a slot freed: deferred flushes re-run

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """Server metrics + per-engine exec/trace stats + executor cache."""
        from repro.core.executor import cache_stats
        with self._lock:
            engines = {name: {**e.engine.exec_stats(),
                              "current": e.engine.is_current(),
                              "pipelined": e.pipelined,
                              "buckets": e.buckets,
                              "resolutions": e.resolutions,
                              "param_generation": e.prepared.generation,
                              "plan_generation": e.plan_generation,
                              "replica_count": e.replicas,
                              "ema_left": e.ema_left,
                              "devices": e.engine.devices,
                              "mode": e.mode,
                              "breaker": e.breaker.label,
                              "fallback_ready": e.fb_engine is not None}
                       for name, e in self._entries.items()}
        out = {"server": self.metrics.snapshot(),
               "state": self._state,
               "in_flight": self.in_flight,
               "inflight_batches": self._inflight(),
               "engines": engines,
               "executor_cache": cache_stats()}
        if self._replanner is not None:
            out["replan"] = self._replanner.snapshot()
        return out
