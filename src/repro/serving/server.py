"""HeteroServer: batched multi-plan serving on the compiled engine.

The deployment half of the paper's argument: per-layer FPGA-GPU gains only
matter if the serving loop preserves them.  ``HeteroServer`` keeps one
compiled engine per registered (modules, plans) pair resident — SqueezeNet,
MobileNetV2 and ShuffleNetV2 plans simultaneously, keyed by the PR-1 plan
signature — admits single-image requests into a dynamic batcher, and
dispatches padded bucket-sized batches from a background drain thread.

    server = HeteroServer(buckets=(1, 4, 8, 32), max_wait_ms=2.0)
    server.register("mbv2", mods, plans, params, input_hw=(96, 96))
    with server:                        # starts the drain loop
        fut = server.submit("mbv2", image)        # returns immediately
        logits = fut.result()                     # de-batched row

Guarantees:
  * results are bit-identical to ``compile_network`` called one request at
    a time — the engine is batch-invariant and padding rows are inert;
  * every bucket shape is compile-warmed at register time, so no live
    request pays a jit trace;
  * a ``clear_cache()`` in ``repro.core.executor`` does not break a live
    server: the drain loop notices the stale engine and transparently
    recompiles (counted in ``stats()['recompiles']``).
"""
from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.core.executor import compile_network
from repro.core.hetero import init_network
from repro.serving.batcher import (DEFAULT_BUCKETS, DynamicBatcher, Request,
                                   pad_batch, pick_bucket)
from repro.serving.metrics import ServerMetrics


class _Entry:
    """One registered network: engine + prepared params + bucket policy."""

    def __init__(self, name, mods, plans, params, input_hw, buckets,
                 use_pallas, calib_x=None):
        self.name = name
        self.mods = mods
        self.plans = plans
        self.params = params
        self.input_hw = tuple(input_hw)
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self.calib_x = calib_x
        self.engine = compile_network(mods, plans, use_pallas=use_pallas)
        if self.engine.needs_calibration and calib_x is None:
            raise ValueError(
                f"{name}: plans request calibration (Plan.calibrate=True) "
                f"— register(..., calib_x=batch) is required")
        self.prepared = self.engine.prepare(params, calib_x)
        self.c_in = mods[0].nodes[0].spec.c_in

    def input_shape(self, batch: int) -> tuple:
        return (batch, *self.input_hw, self.c_in)

    def warmup(self) -> dict:
        return self.engine.warmup(
            self.prepared, [self.input_shape(b) for b in self.buckets])

    def refresh(self):
        """Re-acquire the engine after an executor cache clear (re-running
        calibration from the stored batch when the plans need it)."""
        self.engine = compile_network(self.mods, self.plans,
                                      use_pallas=self.use_pallas)
        self.prepared = self.engine.prepare(self.params, self.calib_x)
        self.warmup()


class HeteroServer:
    """Async dynamic-batching server over ``repro.core.executor``."""

    def __init__(self, *, buckets=DEFAULT_BUCKETS, max_wait_ms: float = 2.0,
                 use_pallas: bool | None = None):
        self.buckets = tuple(sorted(buckets))
        self.use_pallas = use_pallas
        self._batcher = DynamicBatcher(max_wait_s=max_wait_ms * 1e-3,
                                       max_batch=self.buckets[-1])
        self._entries: dict[str, _Entry] = {}
        self._caps: dict[str, tuple] = {}      # per-network bucket ladder
        self.metrics = ServerMetrics()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------

    def register(self, name: str, mods, plans=None, params=None, *,
                 input_hw=(96, 96), buckets=None, warm: bool = True,
                 use_pallas: bool | None = None, calib_x=None) -> dict:
        """Compile, prepare and bucket-warm a network under ``name``.

        ``buckets`` overrides the server-wide bucket ladder (per-network
        policy: e.g. cap a cache-thrashing workload at batch 8).
        ``calib_x`` is the calibration batch for plans that freeze
        activation scales at prepare time (``Plan.calibrate``) — required
        for such plans, ignored otherwise.  Calibrated and uncalibrated
        plans carry different plan signatures, so mixed registrations
        never share an engine.  Returns the engine's exec stats after
        warm-up (one trace per bucket)."""
        if params is None:
            params = init_network(mods, jax.random.PRNGKey(0))
        if use_pallas is None:
            use_pallas = self.use_pallas    # server-wide default
        entry = _Entry(name, mods, plans, params,
                       input_hw, buckets or self.buckets, use_pallas,
                       calib_x=calib_x)
        with self._lock:
            self._entries[name] = entry
            self._caps[name] = entry.buckets
        return entry.warmup() if warm else entry.engine.exec_stats()

    def networks(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "HeteroServer":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="hetero-serve-drain",
                                        daemon=True)
        self._thread.start()
        return self

    def shutdown(self, timeout: float = 10.0) -> None:
        """Stop the drain loop after flushing everything still queued."""
        if self._thread is None:
            return
        self._stop.set()
        self._batcher.put(Request("__wake__", None))   # unblock wait_ready
        self._thread.join(timeout)
        self._thread = None
        for name, reqs in self._batcher.drain_all():
            reqs = [r for r in reqs if r.network != "__wake__"]
            if not reqs:
                continue
            # a backlog can exceed the largest bucket — flush in chunks
            cap = self._caps.get(name, self.buckets)[-1]
            for i in range(0, len(reqs), cap):
                self._flush(name, reqs[i:i + cap], by_deadline=True)

    def __enter__(self) -> "HeteroServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path ------------------------------------------------------

    def submit(self, name: str, x):
        """Admit one image; returns a ``concurrent.futures.Future`` whose
        result is that request's logits row."""
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"unregistered network {name!r}; "
                           f"registered: {self.networks()}")
        x = np.asarray(x) if not hasattr(x, "shape") else x
        if tuple(x.shape) == entry.input_shape(1):
            x = x[0]
        want = entry.input_shape(1)[1:]
        if tuple(x.shape) != want:
            raise ValueError(f"{name}: expected image of shape {want} "
                             f"(or (1, *shape)), got {tuple(x.shape)}")
        req = Request(name, x)
        self.metrics.record_submit(now=time.monotonic())
        self._batcher.put(req)
        return req.future

    def submit_many(self, name: str, images) -> list:
        return [self.submit(name, x) for x in images]

    # -- drain loop --------------------------------------------------------

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            got = self._batcher.wait_ready(timeout=0.05,
                                           buckets_by=self._caps)
            if got is None:
                continue
            name, reqs, by_deadline = got
            reqs = [r for r in reqs if r.network != "__wake__"]
            if reqs:
                self._flush(name, reqs, by_deadline)

    def _flush(self, name: str, reqs, by_deadline: bool) -> None:
        with self._lock:
            entry = self._entries.get(name)
        if entry is None:                     # unregistered mid-flight
            for r in reqs:
                r.future.set_exception(KeyError(name))
            self.metrics.record_failure(len(reqs))
            return
        try:
            if not entry.engine.is_current():
                # executor cache was cleared under us: rebuild, stay live
                entry.refresh()
                self.metrics.record_recompile()
            bucket = pick_bucket(len(reqs), entry.buckets)
            xb = pad_batch([r.x for r in reqs], bucket)
            out = entry.engine(entry.prepared, xb)
            out.block_until_ready()
            # one host copy, then de-batch as numpy views — per-row device
            # slices would pay 1 dispatch per request
            rows = np.asarray(out)
            now = time.monotonic()
            lats = [now - r.t_enqueue for r in reqs]
            for i, r in enumerate(reqs):
                r.future.set_result(rows[i])
            self.metrics.record_batch(len(reqs), bucket, lats, by_deadline,
                                      now=now)
        except Exception as e:                # pragma: no cover - defensive
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            self.metrics.record_failure(len(reqs))

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """Server metrics + per-engine exec/trace stats + executor cache."""
        from repro.core.executor import cache_stats
        with self._lock:
            engines = {name: {**e.engine.exec_stats(),
                              "current": e.engine.is_current(),
                              "buckets": e.buckets}
                       for name, e in self._entries.items()}
        return {"server": self.metrics.snapshot(), "engines": engines,
                "executor_cache": cache_stats()}
