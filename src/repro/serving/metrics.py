"""Serving metrics: counters + latency percentiles + throughput.

One ``ServerMetrics`` per ``HeteroServer``; the drain loop records a sample
per completed request (end-to-end: enqueue -> result ready) and a sample
per flushed batch, tagged with the batch's lane (network @ resolution /
priority) so the snapshot reports per-lane p50/p99 next to the server-wide
numbers.  ``snapshot`` is safe to call from any thread.
"""
from __future__ import annotations

import threading
import time
from collections import deque


def percentile(values, q: float) -> float:
    """Linear-interpolation percentile (q in [0, 100]) of an iterable."""
    vs = sorted(values)
    if not vs:
        return float("nan")
    if len(vs) == 1:
        return float(vs[0])
    pos = (len(vs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(vs) - 1)
    frac = pos - lo
    return float(vs[lo] * (1.0 - frac) + vs[hi] * frac)


class ServerMetrics:
    """Thread-safe counters and bounded latency reservoirs (one server-wide,
    one per lane)."""

    def __init__(self, reservoir: int = 8192, lane_reservoir: int = 2048):
        self._lock = threading.Lock()
        self._t_start = time.monotonic()         # uptime_s in snapshot
        # live-state gauge provider: a callable returning a dict of point-
        # in-time gauges (queue depths, in-flight, pending futures, server
        # state).  The owning server registers it; ``snapshot`` calls it
        # OUTSIDE this metrics lock — the provider reads structures that
        # carry their own locks, so /healthz and /metrics serve counters
        # AND gauges from one snapshot without any new locking here.
        self._gauges = None
        self._lat = deque(maxlen=reservoir)      # seconds, per request
        self._lane_reservoir = lane_reservoir
        self._lanes: dict[str, dict] = {}        # label -> {lat, completed}
        # replica lanes: "net/r<idx>" -> same stats, one per data-axis
        # replica of a striped entry (repro.core.executor.ReplicaSet)
        self._replica_lanes: dict[str, dict] = {}
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.batches = 0
        self.deadline_flushes = 0                # flushed by max-wait timer
        self.size_flushes = 0                    # flushed by a full bucket
        self.padded_slots = 0                    # bucket slots wasted on pad
        self.recompiles = 0                      # stale-engine recoveries
        self.swaps = 0                           # prepared-param hot-swaps
        self.shed = 0                            # Overloaded rejections
        self.bad_requests = 0                    # malformed wire bodies (400)
        self.retries = 0                         # dispatch-failure requeues
        self.deadline_exceeded = 0               # per-request deadline misses
        self.errors = 0                          # unexpected loop errors
        self.failovers = 0                       # hybrid -> GPU-only swaps
        self.recoveries = 0                      # GPU-only -> hybrid swaps
        self.probes_ok = 0                       # half-open probes that passed
        self.probes_failed = 0                   # half-open probes that failed
        self.straggler_events = 0                # watchdog budget overruns
        self.backup_dispatches = 0               # straggler backup launches
        self.cross_replica_backups = 0           # backups on another replica
        self.ema_updates = 0                     # online EMA scale refinements
        self.drain_flushed = 0                   # batches served during drain
        self.drain_aborted = 0                   # requests Shutdown-rejected
        self.measured_batches = 0                # timed replan sample batches
        self.replan_checks = 0                   # replanner decisions taken
        self.replans = 0                         # plan hot-migrations served
        self.breaker_states: dict[str, str] = {}  # network -> breaker state
        self.fitted_scales: dict[str, dict] = {}  # network -> fitted coeffs
        self._t_first = None
        self._t_last = None

    def record_submit(self, n: int = 1, now: float | None = None):
        with self._lock:
            self.submitted += n
            if self._t_first is None:
                self._t_first = now

    def record_batch(self, n_real: int, bucket: int, latencies,
                     by_deadline: bool, now: float | None = None,
                     lane: str | None = None, replica: str | None = None):
        with self._lock:
            self.batches += 1
            self.completed += n_real
            self.padded_slots += bucket - n_real
            if by_deadline:
                self.deadline_flushes += 1
            else:
                self.size_flushes += 1
            self._lat.extend(latencies)
            for label, lanes in ((lane, self._lanes),
                                 (replica, self._replica_lanes)):
                if label is None:
                    continue
                st = lanes.setdefault(
                    label, {"lat": deque(maxlen=self._lane_reservoir),
                            "completed": 0, "batches": 0})
                st["lat"].extend(latencies)
                st["completed"] += n_real
                st["batches"] += 1
            self._t_last = now

    def record_failure(self, n: int = 1):
        with self._lock:
            self.failed += n

    def record_recompile(self):
        with self._lock:
            self.recompiles += 1

    def record_swap(self):
        with self._lock:
            self.swaps += 1

    def count(self, name: str, n: int = 1):
        """Increment one of the failure-state counters by attribute name
        (``shed``, ``retries``, ``failovers``, ...)."""
        with self._lock:
            setattr(self, name, getattr(self, name) + n)

    def set_breaker(self, network: str, state: str):
        with self._lock:
            self.breaker_states[network] = state

    def set_fitted(self, network: str, scales: dict):
        """Record the replanner's latest fitted cost coefficients."""
        with self._lock:
            self.fitted_scales[network] = dict(scales)

    def set_gauge_provider(self, fn) -> None:
        """Register the live-state gauge callable (see ``__init__``)."""
        self._gauges = fn

    def snapshot(self) -> dict:
        with self._lock:
            lat = list(self._lat)
            lanes = {label: (list(st["lat"]), st["completed"], st["batches"])
                     for label, st in self._lanes.items()}
            replicas = {label: (list(st["lat"]), st["completed"],
                                st["batches"])
                        for label, st in self._replica_lanes.items()}
            span = ((self._t_last - self._t_first)
                    if self._t_first is not None and self._t_last is not None
                    else 0.0)
            out = {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "batches": self.batches,
                "deadline_flushes": self.deadline_flushes,
                "size_flushes": self.size_flushes,
                "padded_slots": self.padded_slots,
                "recompiles": self.recompiles,
                "swaps": self.swaps,
                "shed": self.shed,
                "bad_requests": self.bad_requests,
                "retries": self.retries,
                "deadline_exceeded": self.deadline_exceeded,
                "errors": self.errors,
                "failovers": self.failovers,
                "recoveries": self.recoveries,
                "probes_ok": self.probes_ok,
                "probes_failed": self.probes_failed,
                "straggler_events": self.straggler_events,
                "backup_dispatches": self.backup_dispatches,
                "cross_replica_backups": self.cross_replica_backups,
                "ema_updates": self.ema_updates,
                "drain_flushed": self.drain_flushed,
                "drain_aborted": self.drain_aborted,
                "measured_batches": self.measured_batches,
                "replan_checks": self.replan_checks,
                "replans": self.replans,
                "breakers": dict(self.breaker_states),
                "fitted": {k: dict(v)
                           for k, v in self.fitted_scales.items()},
                "throughput_rps": (self.completed / span if span > 0
                                   else float("nan")),
                "uptime_s": time.monotonic() - self._t_start,
            }
        # gauges are read outside the lock: the provider's structures
        # (batcher, pending registry) carry their own synchronization
        gauges = {}
        if self._gauges is not None:
            try:
                gauges = dict(self._gauges() or {})
            except Exception:       # a mid-shutdown provider never breaks
                gauges = {}         # a health probe
        out["gauges"] = gauges
        out["p50_ms"] = percentile(lat, 50) * 1e3 if lat else float("nan")
        out["p99_ms"] = percentile(lat, 99) * 1e3 if lat else float("nan")
        out["lanes"] = {
            label: {"completed": completed, "batches": batches,
                    "p50_ms": percentile(ls, 50) * 1e3,
                    "p99_ms": percentile(ls, 99) * 1e3}
            for label, (ls, completed, batches) in lanes.items()}
        out["replicas"] = {
            label: {"completed": completed, "batches": batches,
                    "p50_ms": percentile(ls, 50) * 1e3,
                    "p99_ms": percentile(ls, 99) * 1e3}
            for label, (ls, completed, batches) in replicas.items()}
        return out
