"""Batched multi-plan, multi-resolution QoS serving on the compiled engine.

``HeteroServer`` turns the jit-once engine (``repro.core.executor``) into a
serving system: dynamic batching into padded, pre-warmed bucket shapes,
per-(network, resolution, priority) lanes with an earliest-deadline-first
flush policy, several networks' plans resident at once, prepared-parameter
hot-swap without draining, async submit/future dispatch, and per-lane
p50/p99/throughput metrics.  PR 6 adds the fault-tolerance layer: typed
request-level errors (``errors``), per-entry circuit-breaker failover to
the GPU-only plan, bounded dispatch retries, per-request deadlines,
load shedding, straggler watchdog, and graceful drain.  PR 7 closes the
measurement loop: ``HeteroServer(replanner=Replanner(...))`` samples timed
batches, re-fits the cost model's device coefficients online, and
hot-migrates live traffic to a re-partitioned plan when the fitted model
shows a clear, sustained win (``repro.core.replan``).  PR 8 adds
replica-striped dispatch: ``register(..., replicas=R)`` prepares one
parameter copy per data-axis replica of a device mesh and stripes flushed
batches to the least-outstanding replica, with per-replica in-flight
slots, per-replica metrics lanes, cross-replica straggler backup, and
atomic all-replica hot-swap (``repro.core.executor.ReplicaSet``).  See
``server.py`` and ``docs/architecture.md`` for the guarantees.
"""
from repro.core.executor import ReplicaPrepared, ReplicaSet
from repro.core.replan import Replanner
from repro.serving.batcher import (DEFAULT_BUCKETS, DEFAULT_PRIORITY,
                                   DynamicBatcher, LaneKey, Request,
                                   pad_batch, pick_bucket)
from repro.serving.errors import (DeadlineExceeded, Overloaded, ServerClosed,
                                  ServingError, Shutdown)
from repro.serving.metrics import ServerMetrics, percentile
from repro.serving.server import HeteroServer, lane_label

__all__ = ["DEFAULT_BUCKETS", "DEFAULT_PRIORITY", "DeadlineExceeded",
           "DynamicBatcher", "HeteroServer", "LaneKey", "Overloaded",
           "Replanner", "ReplicaPrepared", "ReplicaSet", "Request",
           "ServerClosed", "ServerMetrics", "ServingError", "Shutdown",
           "lane_label", "pad_batch", "percentile", "pick_bucket"]
