"""Batched multi-plan serving on the compiled heterogeneous engine.

``HeteroServer`` turns the jit-once engine (``repro.core.executor``) into a
serving system: dynamic batching into padded, pre-warmed bucket shapes,
several networks' plans resident at once, async submit/future dispatch, and
p50/p99/throughput metrics.  See ``server.py`` for the guarantees.
"""
from repro.serving.batcher import (DEFAULT_BUCKETS, DynamicBatcher, Request,
                                   pad_batch, pick_bucket)
from repro.serving.metrics import ServerMetrics, percentile
from repro.serving.server import HeteroServer

__all__ = ["DEFAULT_BUCKETS", "DynamicBatcher", "HeteroServer", "Request",
           "ServerMetrics", "pad_batch", "percentile", "pick_bucket"]
