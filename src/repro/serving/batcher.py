"""Dynamic request batching: padded buckets + deadline flush.

Requests are single images; the batcher groups them per network and
releases a batch when either (a) enough requests are queued to fill the
largest bucket, or (b) the oldest request has waited ``max_wait_s``.  The
released group is padded up to the smallest bucket that holds it, so every
flush hits one of a handful of pre-warmed jit traces instead of compiling a
fresh batch shape per group size.

Bit-exactness contract: the compiled engine is batch-invariant (see
``repro.core.lowering``), so neither the bucket choice, the zero padding,
nor a request's batch-mates can change its logits.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

DEFAULT_BUCKETS = (1, 4, 8, 32)


@dataclass
class Request:
    network: str
    x: object                              # (H, W, C) array
    future: Future = field(default_factory=Future)
    t_enqueue: float = field(default_factory=time.monotonic)


def pick_bucket(n: int, buckets) -> int:
    """Smallest bucket >= n (buckets must be sorted ascending; n is capped
    at the largest bucket by the flush logic)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def pad_batch(xs, bucket: int):
    """Stack (H,W,C) images into a (bucket,H,W,C) batch, zero-padding the
    tail slots.  Host-side numpy on purpose: a ``jnp.stack`` here would
    jit-compile one concatenate per (bucket, image-count) pair and bill the
    first live request for it.  Zero rows never affect real rows (batch
    invariance)."""
    xb = np.zeros((bucket, *np.shape(xs[0])), np.float32)
    for i, x in enumerate(xs):
        xb[i] = np.asarray(x)
    return xb


class DynamicBatcher:
    """Per-network FIFO queues with a shared condition variable.

    ``put`` enqueues and wakes the drain loop; ``wait_ready`` blocks until
    some network has a flushable group (full bucket or deadline hit) and
    pops it.  Multi-plan isolation is structural: groups never mix
    networks, so each flush goes to exactly one compiled engine.
    """

    def __init__(self, max_wait_s: float = 0.002,
                 max_batch: int = DEFAULT_BUCKETS[-1]):
        self.max_wait_s = max_wait_s
        self.max_batch = max_batch
        self._queues: dict[str, deque] = {}
        self._cond = threading.Condition()

    def put(self, req: Request) -> None:
        with self._cond:
            self._queues.setdefault(req.network, deque()).append(req)
            self._cond.notify()

    def pending(self) -> int:
        with self._cond:
            return sum(len(q) for q in self._queues.values())

    def _next_deadline_in(self, now: float) -> float | None:
        ages = [now - q[0].t_enqueue for q in self._queues.values() if q]
        if not ages:
            return None
        return max(0.0, self.max_wait_s - max(ages))

    @staticmethod
    def _deadline_take(n: int, ladder) -> int:
        """How many of n overdue requests to flush given a bucket ladder.
        Padding n up to its covering bucket is cheap when the waste is
        small; when more than half the covering bucket would be pad (e.g.
        10 requests into a 32-bucket), flush the largest full bucket
        instead and leave the remainder queued for the next group."""
        cover = pick_bucket(n, ladder)
        if cover - n <= cover // 2:
            return n
        full = [b for b in ladder if b <= n]
        return full[-1] if full else n

    def wait_ready(self, timeout: float | None = None,
                   buckets_by: dict | None = None):
        """Block until a group is flushable; returns (network, requests,
        by_deadline) or None on timeout.  ``buckets_by`` maps network ->
        bucket ladder override (per-network bucket policy)."""
        t_end = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                now = time.monotonic()
                for name, q in list(self._queues.items()):
                    ladder = ((buckets_by or {}).get(name)
                              or (self.max_batch,))
                    limit = min(self.max_batch, ladder[-1])
                    if len(q) >= limit:
                        return (name,
                                [q.popleft() for _ in range(limit)], False)
                    if q and now - q[0].t_enqueue >= self.max_wait_s:
                        take = self._deadline_take(min(len(q), limit),
                                                   ladder)
                        return name, [q.popleft() for _ in range(take)], True
                wait = self._next_deadline_in(now)
                if t_end is not None:
                    rem = t_end - now
                    if rem <= 0:
                        return None
                    wait = rem if wait is None else min(wait, rem)
                self._cond.wait(wait)

    def drain_all(self):
        """Pop every queued request (shutdown path), grouped per network."""
        with self._cond:
            out = [(name, list(q)) for name, q in self._queues.items() if q]
            for _name, _q in out:
                self._queues[_name].clear()
            return out
