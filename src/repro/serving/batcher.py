"""Dynamic request batching: multi-lane padded buckets + deadline flush.

Requests are single images; the batcher groups them into *lanes* — one
FIFO per ``(network, resolution, priority)`` — and releases a group when
either (a) enough requests are queued to fill the largest bucket, or
(b) the lane's oldest request has crossed its deadline.  The released
group is padded up to the smallest bucket that holds it, so every flush
hits one of a handful of pre-warmed jit traces instead of compiling a
fresh batch shape per group size.  Groups never mix lanes: a batch is
always one network, one input resolution, one priority class.

Flush policy (the QoS scheduler):

  * **Deadline flushes run earliest-deadline-first.**  Each lane's
    deadline is ``max_wait_s`` after its head request enqueued —
    scaled down by ``high_wait_frac`` for priority <= 0 lanes, so
    deadline-critical requests preempt bulk traffic at flush time.
    Ordering by deadline (not by priority) is the starvation guard:
    every lane's wait is bounded by its own deadline plus the flushes
    already due, no matter how saturated a higher lane is.
  * **Full buckets flush highest-priority-first**, oldest head breaking
    ties — but never ahead of an already-overdue lane.
  * **Deadline flushes are admission-gated on downstream depth.**  When
    ``can_dispatch`` reports the dispatch window full, a partial bucket
    would only queue behind in-flight batches, so the flush is deferred
    — requests keep accumulating into a fuller bucket — until the hard
    deadline (``hard_wait_mult`` x the lane deadline), which flushes
    regardless.  Full buckets are never deferred: they cannot get any
    fuller.  ``kick()`` wakes the scheduler when a downstream slot
    frees.

Bit-exactness contract: the compiled engine is batch-invariant (see
``repro.core.lowering``), so neither the bucket choice, the zero padding,
nor a request's batch-mates can change its logits.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

DEFAULT_BUCKETS = (1, 4, 8, 32)
DEFAULT_PRIORITY = 1       # bulk; priority <= 0 is the deadline-critical lane
HIGH_WAIT_FRAC = 0.25      # priority <= 0 deadline, as a fraction of max_wait
HARD_WAIT_MULT = 4.0       # deferred deadline flushes fire at this multiple


class LaneKey(NamedTuple):
    """Identity of one batching queue.  ``res`` is the input (H, W) —
    ``None`` only for control requests that never reach an engine."""
    network: str
    res: tuple | None
    priority: int


@dataclass
class Request:
    network: str
    x: object                              # (H, W, C) array
    res: tuple | None = None               # input (H, W); lane component
    priority: int = DEFAULT_PRIORITY
    deadline_s: float | None = None        # per-request deadline (from
    #                                      # enqueue); late work is
    #                                      # rejected with DeadlineExceeded
    retries: int = 0                       # dispatch-failure retries spent
    future: Future = field(default_factory=Future)
    t_enqueue: float = field(default_factory=time.monotonic)

    @property
    def lane(self) -> LaneKey:
        return LaneKey(self.network, self.res, self.priority)


def pick_bucket(n: int, buckets) -> int:
    """Smallest bucket >= n (buckets must be sorted ascending; n is capped
    at the largest bucket by the flush logic)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def pad_batch(xs, bucket: int):
    """Stack (H,W,C) images into a (bucket,H,W,C) batch, zero-padding the
    tail slots.  Host-side numpy on purpose: a ``jnp.stack`` here would
    jit-compile one concatenate per (bucket, image-count) pair and bill the
    first live request for it.  Zero rows never affect real rows (batch
    invariance)."""
    xb = np.zeros((bucket, *np.shape(xs[0])), np.float32)
    for i, x in enumerate(xs):
        xb[i] = np.asarray(x)
    return xb


class DynamicBatcher:
    """Per-lane FIFO queues with a shared condition variable.

    ``put`` enqueues and wakes the drain loop; ``wait_ready`` blocks until
    some lane has a flushable group (full bucket, or deadline hit and the
    dispatch window open) and pops it.  Multi-plan and multi-resolution
    isolation is structural: groups never mix lanes, so each flush goes to
    exactly one compiled engine at exactly one input shape.
    """

    def __init__(self, max_wait_s: float = 0.002,
                 max_batch: int = DEFAULT_BUCKETS[-1],
                 high_wait_frac: float = HIGH_WAIT_FRAC,
                 hard_wait_mult: float = HARD_WAIT_MULT):
        self.max_wait_s = max_wait_s
        self.max_batch = max_batch
        self.high_wait_frac = high_wait_frac
        self.hard_wait_mult = hard_wait_mult
        self._queues: dict[LaneKey, deque] = {}
        self._cond = threading.Condition()

    def put(self, req: Request, bound: int | None = None) -> bool:
        """Enqueue one request.  ``bound`` is the lane's queue-depth limit:
        when the lane already holds ``bound`` requests the request is NOT
        enqueued and False is returned — the caller sheds it
        (reject-with-backpressure) instead of buffering without bound."""
        with self._cond:
            q = self._queues.setdefault(req.lane, deque())
            if bound is not None and len(q) >= bound:
                if not q:                   # never leave an empty stub lane
                    del self._queues[req.lane]
                return False
            q.append(req)
            self._cond.notify()
            return True

    def put_front(self, reqs) -> None:
        """Re-enqueue already-admitted requests at the HEAD of their lane,
        preserving their order (the dispatch-failure retry path: retried
        rows must not fall behind younger traffic in the same lane, or
        FIFO-within-lane breaks).  Bounds do not apply — these rows were
        admitted once already."""
        by_lane: dict[LaneKey, list] = {}
        for r in reqs:
            by_lane.setdefault(r.lane, []).append(r)
        with self._cond:
            for lane, rs in by_lane.items():
                self._queues.setdefault(lane, deque()).extendleft(
                    reversed(rs))
            self._cond.notify()

    def depth(self, lane: LaneKey) -> int:
        with self._cond:
            return len(self._queues.get(lane, ()))

    def kick(self) -> None:
        """Wake the scheduler without enqueueing — called when a downstream
        dispatch slot frees, so deferred deadline flushes re-evaluate."""
        with self._cond:
            self._cond.notify()

    def pending(self) -> int:
        with self._cond:
            return sum(len(q) for q in self._queues.values())

    def depths(self) -> dict[LaneKey, int]:
        """Point-in-time queue depth per non-empty lane (the /healthz
        gauge source — one pass under the batcher's own lock)."""
        with self._cond:
            return {lane: len(q) for lane, q in self._queues.items() if q}

    def _lane_wait(self, lane: LaneKey) -> float:
        """The lane's soft deadline: priority <= 0 lanes flush after a
        fraction of the bulk max-wait — preemption at flush time."""
        if lane.priority <= 0:
            return self.max_wait_s * self.high_wait_frac
        return self.max_wait_s

    def _next_deadline_in(self, now: float, free: bool) -> float | None:
        """Seconds until the soonest actionable lane deadline (the hard
        deadline when the dispatch window is full — nothing happens at the
        soft one until ``kick``)."""
        waits = []
        for lane, q in self._queues.items():
            if not q:
                continue
            due = self._lane_wait(lane)
            if not free:
                due *= self.hard_wait_mult
            waits.append(max(0.0, due - (now - q[0].t_enqueue)))
        return min(waits, default=None)

    @staticmethod
    def _deadline_take(n: int, ladder) -> int:
        """How many of n overdue requests to flush given a bucket ladder.
        Padding n up to its covering bucket is cheap when the waste is
        small; when more than half the covering bucket would be pad (e.g.
        10 requests into a 32-bucket), flush the largest full bucket
        instead and leave the remainder queued for the next group."""
        cover = pick_bucket(n, ladder)
        if cover - n <= cover // 2:
            return n
        full = [b for b in ladder if b <= n]
        return full[-1] if full else n

    def wait_ready(self, timeout: float | None = None,
                   buckets_by: dict | None = None,
                   can_dispatch=None):
        """Block until a group is flushable; returns (lane, requests,
        by_deadline) or None on timeout.  ``buckets_by`` maps network ->
        bucket ladder override (per-network bucket policy).
        ``can_dispatch`` is the downstream admission signal: a callable
        returning False while the dispatch window is full, which defers
        deadline flushes (see module docstring) — full buckets and
        hard-overdue lanes flush regardless."""
        t_end = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                now = time.monotonic()
                free = can_dispatch() if can_dispatch is not None else True
                full_lanes, overdue = [], []
                for lane, q in list(self._queues.items()):
                    if not q:
                        # prune dead lanes: callers may mint arbitrarily
                        # many (network, res, priority) keys over a long
                        # run, and scanning them forever would make every
                        # wakeup O(all lanes ever seen)
                        del self._queues[lane]
                        continue
                    ladder = ((buckets_by or {}).get(lane.network)
                              or (self.max_batch,))
                    limit = min(self.max_batch, ladder[-1])
                    if len(q) >= limit:
                        full_lanes.append((lane.priority, q[0].t_enqueue,
                                           lane, limit))
                        continue
                    age = now - q[0].t_enqueue
                    soft = self._lane_wait(lane)
                    if age >= soft and (free
                                        or age >= soft * self.hard_wait_mult):
                        deadline = q[0].t_enqueue + soft
                        overdue.append((deadline, lane, ladder, limit))
                if overdue:                    # earliest deadline first
                    _, lane, ladder, limit = min(overdue)
                    q = self._queues[lane]
                    take = self._deadline_take(min(len(q), limit), ladder)
                    reqs = [q.popleft() for _ in range(take)]
                    if not q:
                        del self._queues[lane]
                    return lane, reqs, True
                if full_lanes:                 # highest priority first
                    _, _, lane, limit = min(full_lanes)
                    q = self._queues[lane]
                    reqs = [q.popleft() for _ in range(limit)]
                    if not q:
                        del self._queues[lane]
                    return lane, reqs, False
                wait = self._next_deadline_in(now, free)
                if t_end is not None:
                    rem = t_end - now
                    if rem <= 0:
                        return None
                    wait = rem if wait is None else min(wait, rem)
                self._cond.wait(wait)

    def drain_all(self):
        """Pop every queued request (shutdown path), grouped per lane."""
        with self._cond:
            out = [(lane, list(q)) for lane, q in self._queues.items() if q]
            self._queues.clear()
            return out
