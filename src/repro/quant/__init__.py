"""8-bit fixed-point quantization — the paper's FPGA number format [2].

Symmetric int8: per-channel scales for weights (``axis=-1``), per-sample
scales for activations (``axis=0`` — one scale per batch row, so batched
serving never couples requests), per-tensor when ``axis=None``.  Used by
(a) the hetero executor's FPGA substrate (DHM computes in int8), (b) the
int8 Pallas GEMM kernel, and (c) the batched serving path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, axis=None, bits: int = 8):
    """Returns (q int8, scale f32).  axis: per-channel axis (None = tensor)."""
    qmax = 2.0 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=red, keepdims=True)
    scale = jnp.maximum(amax.astype(jnp.float32), 1e-8) / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def scale_from_amax(amax, bits: int = 8):
    """Frozen-scale calibration: amax (max |activation| over a calibration
    batch) -> per-tensor scale on the same int grid ``quantize`` uses."""
    qmax = 2.0 ** (bits - 1) - 1
    return jnp.maximum(jnp.asarray(amax, jnp.float32), 1e-8) / qmax


def quantize_with_scale(x, scale, bits: int = 8):
    """int8-quantize with a FROZEN scale (no runtime amax reduction)."""
    qmax = 2.0 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8)


def fake_quant_with_scale(x, scale, bits: int = 8):
    q = quantize_with_scale(x, scale, bits)
    return (q.astype(jnp.float32) * scale).astype(x.dtype)


def fake_quant(x, axis=None, bits: int = 8):
    q, s = quantize(x, axis, bits)
    return dequantize(q, s).astype(x.dtype)


def int8_matmul(x_q, x_scale, w_q, w_scale):
    """int8 x int8 -> int32 accumulate -> f32 requantize.

    x_q (m, k) int8; w_q (k, n) int8; w_scale per-channel (1, n) or scalar.
    """
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * x_scale * w_scale.reshape(1, -1)


def quantize_params(params, axis=-1):
    """int8-quantize every >=2D leaf of a param tree (serving path)."""
    def q(p):
        if p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            qq, s = quantize(p, axis=axis)
            return {"q": qq, "scale": s}
        return p
    return jax.tree.map(q, params)
