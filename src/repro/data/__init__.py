from repro.data.pipeline import TokenPipeline, synthetic_batches  # noqa: F401
