"""Data pipeline: deterministic, shardable, restartable.

Two sources:
 * ``synthetic_batches`` — seeded LM token stream with Zipfian marginals and
   a Markov structure (so models can actually reduce loss on it);
 * ``TokenPipeline`` — memory-mapped token file, sharded by host, with an
   explicit cursor so a restore resumes the stream exactly (the checkpoint
   stores the cursor alongside model state).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def synthetic_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                      extras=None):
    """Infinite iterator of {'tokens': (B, S) int32} with learnable bigram
    structure.  extras: callables name -> (B,) shaped generator."""
    rng = np.random.default_rng(seed)
    # fixed random bigram transition table with low entropy
    heads = rng.integers(0, vocab, size=(vocab, 4))

    def gen(step):
        r = np.random.default_rng(seed + 1000 + step)
        toks = np.empty((batch, seq), np.int32)
        toks[:, 0] = r.integers(0, vocab, size=batch)
        for t in range(1, seq):
            nxt = heads[toks[:, t - 1], r.integers(0, 4, size=batch)]
            mutate = r.random(batch) < 0.1
            toks[:, t] = np.where(mutate, r.integers(0, vocab, batch), nxt)
        out = {"tokens": toks}
        if extras:
            for name, fn in extras.items():
                out[name] = fn(r)
        return out

    return gen


@dataclass
class TokenPipeline:
    """Sharded stateful reader over a flat token array (np.memmap-able)."""
    tokens: np.ndarray
    batch: int
    seq: int
    host_id: int = 0
    n_hosts: int = 1
    cursor: int = 0

    def next_batch(self) -> dict:
        per_host = self.batch // self.n_hosts
        need = per_host * self.seq
        span = len(self.tokens) - self.seq * self.batch - 1
        out = np.empty((per_host, self.seq), np.int32)
        for i in range(per_host):
            off = (self.cursor + (self.host_id * per_host + i) * self.seq) \
                % max(span, 1)
            out[i] = self.tokens[off:off + self.seq]
        self.cursor += self.batch * self.seq
        return {"tokens": out}

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def restore(self, state: dict):
        self.cursor = int(state["cursor"])
