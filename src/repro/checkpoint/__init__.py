"""Fault-tolerant checkpointing: async, atomic, topology-elastic.

- Atomic: writes go to ``<dir>/tmp.<step>`` and are renamed to
  ``<dir>/step_<n>`` only when complete — a crash mid-save never corrupts
  the latest checkpoint.
- Async: device->host transfer happens on the caller thread (cheap), file IO
  on a background thread so the train loop keeps stepping.
- Elastic: restore takes target shardings — a checkpoint written on one mesh
  restores onto any other (device_put reshards), which is how elastic
  scaling re-admits work after node loss.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    """Flatten in jax.tree order: dict keys SORTED, NamedTuple fields in
    declaration order, sequences positional."""
    out = {}
    if hasattr(tree, "_asdict"):                  # NamedTuple
        for k, v in tree._asdict().items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, blocking: bool = False):
        """Snapshot to host, then write asynchronously + atomically."""
        self.wait()
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        treedef = jax.tree.structure(state)

        def write():
            tmp = self.dir / f"tmp.{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            for k, v in host.items():
                np.save(tmp / (k.replace("/", "__") + ".npy"), v)
            meta = {"step": step, "keys": sorted(host),
                    "treedef": str(treedef)}
            (tmp / "meta.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step:010d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*"))
        for c in ckpts[:-self.keep]:
            shutil.rmtree(c, ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> int | None:
        ckpts = sorted(self.dir.glob("step_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, step: int | None, like, shardings=None):
        """Restore into the structure of ``like``; reshard onto ``shardings``
        (a matching pytree of NamedSharding) if given — elastic restore."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        flat_like = _flatten(like)
        arrays = {}
        for k in flat_like:
            arrays[k] = np.load(d / (k.replace("/", "__") + ".npy"))
        leaves_like, treedef = jax.tree.flatten(like)
        flat_keys = list(flat_like.keys())
        restored_flat = [arrays[k] for k in flat_keys]
        state = jax.tree.unflatten(treedef, restored_flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, step

    @staticmethod
    def _to_pytree(state):
        """NamedTuples -> plain dicts for stable pathing."""
        if hasattr(state, "_asdict"):
            return {k: CheckpointManager._to_pytree(v)
                    for k, v in state._asdict().items()}
        if isinstance(state, dict):
            return {k: CheckpointManager._to_pytree(v)
                    for k, v in state.items()}
        if isinstance(state, (list, tuple)) and not hasattr(state, "shape"):
            return [CheckpointManager._to_pytree(v) for v in state]
        return state
