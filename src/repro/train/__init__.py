from repro.train.steps import (  # noqa: F401
    TrainState, cross_entropy, make_decode_fn, make_prefill_fn,
    make_train_step, make_train_state,
)
