"""Step factories: training (remat + microbatched grad accumulation) and
serving (prefill / decode).  All are pure functions of (state|params, batch)
suitable for ``jax.jit`` with explicit in/out shardings.

Microbatching: the global batch is split into ``microbatches`` slices and
scanned; gradients accumulate in fp32.  XLA's latency-hiding scheduler
overlaps the reduce-scatter of microbatch i with the compute of i+1 (enabled
by launcher flags) — the paper's `max(compute, comm)` overlap at DC scale.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import model as lm
from repro.optim import Optimizer, apply_updates


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def make_train_state(cfg: ModelConfig, optimizer: Optimizer, key) -> TrainState:
    params = lm.init_params(cfg, key)
    return TrainState(jnp.zeros((), jnp.int32), params,
                      optimizer.init(params))


def cross_entropy(logits, labels, mask):
    """logits (B,T,V) fp32, labels (B,T) int32, mask (B,T)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / denom


def _loss_fn(cfg: ModelConfig, params, batch, hierarchy_levels: int = 0):
    logits, _, aux = lm.forward(cfg, params, batch,
                                hierarchy_levels=hierarchy_levels)
    tokens = batch["tokens"]
    extra = cfg.vlm_patches
    txt_logits = logits[:, extra:-1] if extra else logits[:, :-1]
    labels = tokens[:, 1:]
    mask = jnp.ones(labels.shape, jnp.float32)
    loss = cross_entropy(txt_logits.astype(jnp.float32), labels, mask)
    aux_coef = cfg.moe.router_aux_coef if cfg.moe is not None else 0.0
    return loss + aux_coef * aux, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    microbatches: int = 1, hierarchy_levels: int = 0,
                    accum_dtype=jnp.float32):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grad_fn(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: _loss_fn(cfg, p, mb, hierarchy_levels),
            has_aux=True)(params)
        return grads, metrics

    def train_step(state: TrainState, batch: dict):
        if microbatches == 1:
            grads, metrics = grad_fn(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(acc, mb):
                from repro.models.lm.sharding import lc
                mb = jax.tree.map(
                    lambda t: lc(t, "batch", *([None] * (t.ndim - 1))), mb)
                g, m = grad_fn(state.params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(accum_dtype), acc, g)
                return acc, m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
            grads, ms = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m.mean(), ms)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        return TrainState(state.step + 1, params, opt_state), metrics

    return train_step


def make_prefill_fn(cfg: ModelConfig, hierarchy_levels: int = 0):
    def prefill_fn(params, batch):
        logits, caches, _ = lm.forward(cfg, params, batch, return_cache=True,
                                       hierarchy_levels=hierarchy_levels)
        return logits[:, -1:], caches
    return prefill_fn


def make_decode_fn(cfg: ModelConfig):
    def decode_fn(params, cache, token, cache_len):
        return lm.decode_step(cfg, params, cache, token, cache_len)
    return decode_fn
