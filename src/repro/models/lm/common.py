"""Shared LM primitives: schemas, init, RMSNorm, SwiGLU FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# A "schema" maps param path -> (shape, logical_axes, init_kind).
# init_kind: "normal" (fan-in scaled), "zeros", "ones".
Schema = dict


def init_from_schema(schema: Schema, key, dtype) -> dict:
    flat = {}
    paths = sorted(schema)
    keys = jax.random.split(key, len(paths))
    for k, path in zip(keys, paths):
        shape, _axes, kind = schema[path]
        if kind == "zeros":
            arr = jnp.zeros(shape, dtype)
        elif kind == "ones":
            arr = jnp.ones(shape, dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / np.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
        flat[path] = arr
    return unflatten(flat)


def axes_from_schema(schema: Schema) -> dict:
    return unflatten({p: axes for p, (_s, axes, _k) in schema.items()})


def unflatten(flat: dict) -> dict:
    out: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return out


def prefix_schema(prefix: str, schema: Schema) -> Schema:
    return {f"{prefix}/{p}": v for p, v in schema.items()}


def merge_schemas(*schemas: Schema) -> Schema:
    out: Schema = {}
    for s in schemas:
        for k, v in s.items():
            assert k not in out, f"duplicate param path {k}"
            out[k] = v
    return out


def stack_axes(axes_tree):
    """Prepend the 'layers' (scan) axis to every logical-axes tuple."""
    return jax.tree.map(
        lambda axes: ("layers",) + tuple(axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def norm_schema(d: int) -> Schema:
    return {"scale": ((d,), (None,), "zeros")}


def ffn_schema(d: int, f: int) -> Schema:
    return {
        "w_gate": ((d, f), ("embed", "ffn"), "normal"),
        "w_up": ((d, f), ("embed", "ffn"), "normal"),
        "w_down": ((f, d), ("ffn", "embed"), "normal"),
    }


def ffn_apply(p, x, hidden_axes=None):
    from repro.models.lm.sharding import lc
    h = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    if hidden_axes is None:
        hidden_axes = ("batch",) + (None,) * (h.ndim - 2) + ("ffn",)
    h = lc(h, *hidden_axes)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])
