"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, sequential scan).  [arXiv:2405.04517]

mLSTM is the linear-complexity workhorse (chunked linear attention with
exponential input gates and forget-gate decay); sLSTM keeps a recurrent
hidden-to-gate connection and therefore scans sequentially.  Both expose a
single-step recurrent form for decode (state is O(B*H*dk*dv) resp. O(B*d)),
which is what makes the 500k-token decode cell runnable.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.lm.common import Schema


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_schema(d: int, n_heads: int) -> Schema:
    dm = 2 * d     # up-projection factor 2
    return {
        "w_up": ((d, dm), ("embed", "ffn"), "normal"),
        "w_gate_up": ((d, dm), ("embed", "ffn"), "normal"),
        "wq": ((dm, dm), ("ffn", None), "normal"),
        "wk": ((dm, dm), ("ffn", None), "normal"),
        "wv": ((dm, dm), ("ffn", None), "normal"),
        "w_if": ((dm, 2 * n_heads), ("ffn", None), "normal"),
        "b_if": ((2 * n_heads,), (None,), "zeros"),
        "w_down": ((dm, d), ("ffn", "embed"), "normal"),
    }


def _mlstm_chunk(q, k, v, ig, lf, carry):
    """One chunk, one head-batch.  q,k,v (B,H,L,dk/dv) any float dtype —
    upcast HERE so the full-sequence tensors stay bf16 (§Perf: full-seq fp32
    q/k/v dominated prefill memory traffic); ig,lf (B,H,L) fp32.

    carry = (C (B,H,dk,dv), n (B,H,dk), m (B,H)).  Returns (h, new_carry).
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    B, H, L, dk = q.shape
    b = jnp.cumsum(lf, axis=-1)                        # inclusive log-decay
    btot = b[..., -1]
    # intra-chunk log weights a_ij = b_i - b_j + ig_j  (j <= i)
    aij = b[..., :, None] - b[..., None, :] + ig[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    aij = jnp.where(tri, aij, -jnp.inf)
    m_intra = aij.max(axis=-1)                         # (B,H,L)
    C, n, m = carry
    m_inter = m[..., None] + b                         # (B,H,L)
    m_i = jnp.maximum(m_inter, m_intra)
    m_i = jnp.maximum(m_i, -60.0)                      # numeric floor
    w_inter = jnp.exp(m_inter - m_i)                   # (B,H,L)
    p_intra = jnp.exp(aij - m_i[..., None])            # (B,H,L,L)
    qs = q / math.sqrt(dk)
    num = (w_inter[..., None] * jnp.einsum("bhld,bhdv->bhlv", qs, C)
           + jnp.einsum("bhlj,bhjv->bhlv", p_intra * jnp.einsum(
               "bhld,bhjd->bhlj", qs, k), v))
    den = (w_inter * jnp.einsum("bhld,bhd->bhl", qs, n)
           + jnp.einsum("bhlj,bhlj->bhl", p_intra,
                        jnp.einsum("bhld,bhjd->bhlj", qs, k)))
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
    # state update
    m_new = jnp.maximum(m + btot, (btot[..., None] - b + ig).max(axis=-1))
    m_new = jnp.maximum(m_new, -60.0)
    wk = jnp.exp(btot[..., None] - b + ig - m_new[..., None])   # (B,H,L)
    C_new = (jnp.exp(m + btot - m_new)[..., None, None] * C
             + jnp.einsum("bhj,bhjd,bhjv->bhdv", wk, k, v))
    n_new = (jnp.exp(m + btot - m_new)[..., None] * n
             + jnp.einsum("bhj,bhjd->bhd", wk, k))
    return h, (C_new, n_new, m_new)


def mlstm_seq(q, k, v, ig, lf, carry, chunk: int = 64):
    """Chunkwise scan over the sequence.  q,k,v (B,S,H,dh); ig,lf (B,S,H)."""
    B, S, H, dh = q.shape
    chunk = min(chunk, S)
    nc = S // chunk
    assert S % chunk == 0

    def to_chunks(x):
        return (x.transpose(0, 2, 1, 3).reshape(B, H, nc, chunk, -1)
                .transpose(2, 0, 1, 3, 4))

    qc, kc, vc = map(to_chunks, (q, k, v))
    igc = ig.transpose(0, 2, 1).reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)
    lfc = lf.transpose(0, 2, 1).reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)

    @jax.checkpoint
    def body(c, xs):
        qi, ki, vi, igi, lfi = xs
        h, c = _mlstm_chunk(qi, ki, vi, igi, lfi, c)
        return c, h

    carry, hs = jax.lax.scan(body, carry, (qc, kc, vc, igc, lfc))
    # hs: (nc, B, H, L, dv) -> (B, S, H, dv)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, -1).transpose(0, 2, 1, 3)
    return h, carry


def mlstm_step(q, k, v, ig, lf, carry):
    """Single decode step.  q,k,v (B,H,dh); ig,lf (B,H)."""
    C, n, m = carry
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    dk = q.shape[-1]
    m_new = jnp.maximum(m + lf, ig)
    m_new = jnp.maximum(m_new, -60.0)
    wf = jnp.exp(m + lf - m_new)
    wi = jnp.exp(ig - m_new)
    C = wf[..., None, None] * C + wi[..., None, None] * jnp.einsum(
        "bhd,bhv->bhdv", k, v)
    n = wf[..., None] * n + wi[..., None] * k
    qs = q / math.sqrt(dk)
    num = jnp.einsum("bhd,bhdv->bhv", qs, C)
    den = jnp.einsum("bhd,bhd->bh", qs, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, (C, n, m_new)


def mlstm_init_state(batch: int, n_heads: int, dh: int):
    return (jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            jnp.zeros((batch, n_heads, dh), jnp.float32),
            jnp.full((batch, n_heads), -60.0, jnp.float32))


def mlstm_apply(p, x, n_heads: int, state=None):
    """Full mLSTM block.  x (B,S,d) -> (out, new_state).

    Full-sequence intermediates are sharded over the model axis on their
    inner (head_dim) dim — xLSTM has too few heads for head sharding, but
    dh = 2*d/n_heads divides a 16-way axis (§Perf cell 3).
    """
    from repro.models.lm.sharding import lc
    B, S, d = x.shape
    up = lc(jnp.einsum("bsd,dm->bsm", x, p["w_up"]), "batch", None, "rnn")
    gate = jax.nn.silu(jnp.einsum(
        "bsd,dm->bsm", x, p["w_gate_up"]).astype(jnp.float32)).astype(x.dtype)
    gate = lc(gate, "batch", None, "rnn")
    dm = up.shape[-1]
    dh = dm // n_heads

    def heads(w):
        # stays in model dtype at full sequence length; chunks upcast
        h = jnp.einsum("bsm,mn->bsn", up, w).reshape(B, S, n_heads, dh)
        return lc(h, "batch", None, None, "rnn")

    q, k, v = heads(p["wq"]), heads(p["wk"]), heads(p["wv"])
    if_ = (jnp.einsum("bsm,mh->bsh", up, p["w_if"])
           .astype(jnp.float32) + p["b_if"].astype(jnp.float32))
    ig, fg = jnp.split(if_, 2, axis=-1)                 # (B,S,H)
    lf = jax.nn.log_sigmoid(fg)

    if state is None:
        state = mlstm_init_state(B, n_heads, dh)
    if S == 1:
        h, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], lf[:, 0],
                              state)
        h = h[:, None]
    else:
        h, state = mlstm_seq(q, k, v, ig, lf, state)
    h = h.reshape(B, S, dm).astype(x.dtype) * gate
    return jnp.einsum("bsm,md->bsd", h, p["w_down"]), state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_schema(d: int, n_heads: int) -> Schema:
    dh = d // n_heads
    return {
        "w": ((d, 4 * d), ("embed", "ffn"), "normal"),
        "b": ((4 * d,), (None,), "zeros"),
        "r": ((n_heads, dh, 4 * dh), (None, None, None), "normal"),
        "w_out": ((d, d), ("ffn", "embed"), "normal"),
    }


def slstm_init_state(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z - 60.0}


def _slstm_cell(p, wx, st, n_heads: int):
    """wx (B,4d) precomputed W x + b (fp32).  st: dict of (B,d)."""
    B, d4 = wx.shape
    d = d4 // 4
    dh = d // n_heads
    hr = st["h"].reshape(B, n_heads, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr, p["r"].astype(jnp.float32))
    pre = wx + rec.reshape(B, 4 * d)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + st["m"], it)
    m_new = jnp.maximum(m_new, -60.0)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(jax.nn.log_sigmoid(ft) + st["m"] - m_new)
    c = f_ * st["c"] + i_ * zt
    n = f_ * st["n"] + i_
    h = ot * c / jnp.maximum(n, 1e-6)
    return h, {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(p, x, n_heads: int, state=None):
    """x (B,S,d) -> (out, state).  Sequential lax.scan over time."""
    B, S, d = x.shape
    if state is None:
        state = slstm_init_state(B, d)
    wx = (jnp.einsum("bsd,de->bse", x, p["w"]).astype(jnp.float32)
          + p["b"].astype(jnp.float32))

    if S == 1:
        h, state = _slstm_cell(p, wx[:, 0], state, n_heads)
        hs = h[:, None]
    else:
        def body(st, wxt):
            h, st = _slstm_cell(p, wxt, st, n_heads)
            return st, h
        state, hs = jax.lax.scan(body, state, wx.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
    out = jnp.einsum("bsd,de->bse", hs.astype(x.dtype), p["w_out"])
    return out, state
