"""Logical-axis sharding: t5x-style rules mapping logical axes -> mesh axes.

Model code annotates params/activations with *logical* axis names; an
``AxisRules`` object (built per (config, mesh)) resolves them to
``PartitionSpec``s.  With no active rules every annotation is a no-op, so the
same model code runs unsharded on one CPU device for smoke tests.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_CTX = threading.local()


class AxisRules:
    """Resolve logical axis names to mesh axes for a given policy/mesh."""

    def __init__(self, mesh, policy, moe=None):
        names = tuple(mesh.axis_names) if mesh is not None else ()
        self.mesh = mesh
        self.policy = policy
        has = lambda a: a in names
        batch = tuple(a for a in policy.batch_axes if has(a))
        tp = "model" not in policy.batch_axes
        ep = tuple(a for a in (moe.ep_axes if moe else ()) if has(a))
        self.table: dict[str, tuple[str, ...] | None] = {
            # --- weights ---
            "embed": ("data",) if (policy.fsdp and has("data")) else None,
            "heads": ("model",) if (has("model") and tp) else None,
            "kv_heads": (None if policy.kv_replicated else
                         (("model",) if (has("model") and tp) else None)),
            "ffn": ("model",) if (has("model") and tp) else None,
            "vocab": (("model",) if (policy.shard_vocab and has("model")
                                     and tp) else None),
            "experts": ep or None,
            "rnn": ("model",) if (has("model") and tp) else None,
            # expert-weight d_model dim: FSDP over data unless EP already
            # occupies the data axis (deepseek: experts span data x model)
            "embed_ep": (("data",) if (policy.fsdp and has("data")
                                       and "data" not in ep) else None),
            "layers": None,
            "head_dim": None,
            "none": None,
            # --- activations ---
            "batch": batch or None,
            # flattened (batch*seq) token dim: batch axes + model (SP layout)
            "tokens": tuple(dict.fromkeys(
                batch + (("model",) if has("model") else ()))) or None,
            "seq": None,
            "seq_sp": (("model",) if (policy.seq_parallel and has("model")
                                      and tp) else None),
            # KV-cache sequence dim for caches with no head dim to shard
            # (MLA latent cache): sequence-parallel decode attention
            "seq_kv": ("model",) if (has("model") and tp) else None,
            "act_embed": None,
        }

    def spec(self, *axes) -> P:
        parts = []
        for a in axes:
            if a is None:
                parts.append(None)
                continue
            m = self.table.get(a)
            if m is None:
                parts.append(None)
            elif len(m) == 1:
                parts.append(m[0])
            else:
                parts.append(m)
        return P(*parts)


@contextmanager
def use_rules(rules: AxisRules | None):
    prev = getattr(_CTX, "rules", None)
    _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.rules = prev


def current_rules() -> AxisRules | None:
    return getattr(_CTX, "rules", None)


def lc(x, *axes):
    """Logical sharding constraint on an activation (no-op without rules)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.spec(*axes))


def specs_from_axes(axes_tree, rules: AxisRules):
    """Map a pytree of logical-axes tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(*axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )
