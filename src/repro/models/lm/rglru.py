"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: norm -> { gate branch: linear+GELU } * { rec branch: linear -> causal
conv1d(4) -> RG-LRU } -> out proj.  The RG-LRU:

    r_t = sigmoid(alpha_r * x_t + b_r)          (per-channel gates — see
    i_t = sigmoid(alpha_i * x_t + b_i)           DESIGN.md: diagonal gate
    a_t = exp(-c * softplus(lam) * r_t)          simplification)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth); decode is a
single recurrent step.  State stays O(B*W) — the "DHM-like" streaming module
of this architecture (weights + state resident on-chip).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.common import Schema
from repro.models.lm.sharding import lc

C_FACTOR = 8.0
CONV_W = 4


def rglru_schema(d: int, w: int) -> Schema:
    return {
        "w_gate": ((d, w), ("embed", "rnn"), "normal"),
        "w_rec": ((d, w), ("embed", "rnn"), "normal"),
        "conv/k": ((CONV_W, w), (None, "rnn"), "normal"),
        "conv/b": ((w,), ("rnn",), "zeros"),
        "lru/alpha_r": ((w,), ("rnn",), "normal"),
        "lru/b_r": ((w,), ("rnn",), "zeros"),
        "lru/alpha_i": ((w,), ("rnn",), "normal"),
        "lru/b_i": ((w,), ("rnn",), "zeros"),
        "lru/lam": ((w,), ("rnn",), "ones"),
        "w_out": ((w, d), ("rnn", "embed"), "normal"),
    }


def _gates(p, x):
    """x (..., w) -> (a, b) of the affine recurrence h = a*h_prev + b (fp32)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["lru"]["alpha_r"].astype(jnp.float32)
                       + p["lru"]["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf * p["lru"]["alpha_i"].astype(jnp.float32)
                       + p["lru"]["b_i"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lru"]["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xf)
    return a, b


def _causal_conv(p, x, state=None):
    """Depthwise causal conv width 4.  x (B,S,w).  state (B,3,w) for decode."""
    k = p["conv"]["k"].astype(jnp.float32)
    if state is None:
        pad = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    xf = pad.astype(jnp.float32)
    s = x.shape[1]
    out = sum(xf[:, j:j + s] * k[j] for j in range(CONV_W))
    out = out + p["conv"]["b"].astype(jnp.float32)
    new_state = pad[:, -(CONV_W - 1):]
    return out.astype(x.dtype), new_state


def rglru_apply(p, x, state=None):
    """x (B,S,d).  state None (train) or dict (decode/carry-over).

    Returns (out (B,S,d), new_state).
    """
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_gate"]).astype(jnp.float32))
    rec = jnp.einsum("bsd,dw->bsw", x, p["w_rec"])
    rec = lc(rec, "batch", None, "rnn")
    conv_state = None if state is None else state["conv"]
    rec, new_conv = _causal_conv(p, rec, conv_state)
    a, b = _gates(p, rec)

    if x.shape[1] == 1 and state is not None:
        h = a[:, 0] * state["h"] + b[:, 0]               # (B, w) fp32
        hs = h[:, None]
        new_h = h
    else:
        if state is not None:
            # fold carried state into the first step
            b = b.at[:, 0].add(a[:, 0] * state["h"])

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_sc, hs = jax.lax.associative_scan(comb, (a, b), axis=1)
        del a_sc
        new_h = hs[:, -1]

    out = (gate * hs).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", out, p["w_out"])
    return out, {"h": new_h, "conv": new_conv}


def rglru_init_state(batch: int, w: int):
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, CONV_W - 1, w), jnp.bfloat16)}
