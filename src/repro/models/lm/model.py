"""The composable LM: init / forward / prefill / decode for every arch family.

Layers are grouped into (prefix, scanned stack, suffix):
 - prefix  — unrolled leading layers (e.g. DeepSeek's 3 dense-FFN layers)
 - stack   — `lax.scan` over repeating *pattern units* (one HLO body for 58
             MoE layers / 12x(R,R,A) units / ...), remat per unit
 - suffix  — unrolled remainder (e.g. recurrentgemma's trailing R,R)

Params and decode caches are pytrees mirroring this grouping; scanned leaves
carry a leading n_units axis.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm.blocks import (BlockCtx, apply_block, block_schema,
                                    init_block_cache)
from repro.models.lm.common import (axes_from_schema, init_from_schema,
                                    rms_norm, stack_axes)
from repro.models.lm.sharding import lc


@dataclass(frozen=True)
class LayerGroups:
    prefix: tuple[str, ...]
    unit: tuple[str, ...]
    n_units: int
    suffix: tuple[str, ...]


def layer_groups(cfg: ModelConfig, kinds=None) -> LayerGroups:
    kinds = list(kinds if kinds is not None else cfg.layer_kinds())
    prefix_n = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    rest = kinds[prefix_n:]
    unit = len(cfg.block_pattern) if cfg.block_pattern else 1
    n_units = len(rest) // unit
    return LayerGroups(
        prefix=tuple(kinds[:prefix_n]),
        unit=tuple(rest[:unit]) if n_units else (),
        n_units=n_units,
        suffix=tuple(rest[n_units * unit:]),
    )


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _unit_schemas(cfg, groups: LayerGroups, ref_idx: int):
    return {f"b{j}": block_schema(cfg, kind, ref_idx + j)
            for j, kind in enumerate(groups.unit)}


def _init_unit(cfg, groups, ref_idx, key):
    schemas = _unit_schemas(cfg, groups, ref_idx)
    keys = jax.random.split(key, len(schemas))
    return {name: init_from_schema(schemas[name], k, _dtype(cfg))
            for (name, k) in zip(sorted(schemas), keys)}


def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg)
    groups = layer_groups(cfg)
    k_embed, k_head, k_pre, k_stack, k_suf, k_enc = jax.random.split(key, 6)
    pv = cfg.padded_vocab
    params: dict = {
        "embed": (jax.random.normal(k_embed, (pv, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dt)},
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            k_head, (cfg.d_model, pv), jnp.float32)
            / np.sqrt(cfg.d_model)).astype(dt)
    if groups.prefix:
        keys = jax.random.split(k_pre, len(groups.prefix))
        params["prefix"] = {
            str(i): init_from_schema(block_schema(cfg, kind, i), keys[i], dt)
            for i, kind in enumerate(groups.prefix)}
    if groups.n_units:
        keys = jax.random.split(k_stack, groups.n_units)
        params["stack"] = jax.vmap(
            lambda k: _init_unit(cfg, groups, len(groups.prefix), k))(keys)
    if groups.suffix:
        keys = jax.random.split(k_suf, len(groups.suffix))
        base = len(groups.prefix) + groups.n_units * len(groups.unit)
        params["suffix"] = {
            str(i): init_from_schema(
                block_schema(cfg, kind, base + i), keys[i], dt)
            for i, kind in enumerate(groups.suffix)}
    if cfg.enc_dec:
        keys = jax.random.split(k_enc, cfg.n_enc_layers + 1)
        params["encoder"] = {
            str(i): init_from_schema(block_schema(cfg, "E", i), keys[i], dt)
            for i in range(cfg.n_enc_layers)}
        params["enc_norm"] = {"scale": jnp.zeros((cfg.d_model,), dt)}
    return params


def param_axes(cfg: ModelConfig) -> dict:
    """Logical-axes pytree mirroring ``init_params``."""
    groups = layer_groups(cfg)
    axes: dict = {
        "embed": ("vocab", "embed"),
        "final_norm": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        axes["head"] = ("embed", "vocab")
    if groups.prefix:
        axes["prefix"] = {
            str(i): axes_from_schema(block_schema(cfg, kind, i))
            for i, kind in enumerate(groups.prefix)}
    if groups.n_units:
        unit_axes = {f"b{j}": axes_from_schema(
            block_schema(cfg, kind, len(groups.prefix) + j))
            for j, kind in enumerate(groups.unit)}
        axes["stack"] = stack_axes(unit_axes)
    if groups.suffix:
        base = len(groups.prefix) + groups.n_units * len(groups.unit)
        axes["suffix"] = {
            str(i): axes_from_schema(block_schema(cfg, kind, base + i))
            for i, kind in enumerate(groups.suffix)}
    if cfg.enc_dec:
        axes["encoder"] = {
            str(i): axes_from_schema(block_schema(cfg, "E", i))
            for i in range(cfg.n_enc_layers)}
        axes["enc_norm"] = {"scale": (None,)}
    return axes


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_layers(cfg, params, x, ctx: BlockCtx, caches=None,
                collect_cache=False):
    """Run prefix + stack + suffix.  Returns (x, new_caches, aux)."""
    groups = layer_groups(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_caches: dict = {}

    def get(c, *ks):
        for k_ in ks:
            if c is None:
                return None
            c = c.get(k_) if isinstance(c, dict) else c
        return c

    remat_unrolled = cfg.policy.remat == "block" and ctx.mode == "train"

    def run_one(kind, idx, p_, x_, sl):
        def f(p__, x__):
            return apply_block(cfg, kind, idx, p__, x__,
                               _with_cache(ctx, sl))
        if remat_unrolled:
            f = jax.checkpoint(f)
        return f(p_, x_)

    for i, kind in enumerate(groups.prefix):
        sl = get(caches, "prefix", str(i))
        x, nc, a = run_one(kind, i, params["prefix"][str(i)], x, sl)
        aux = aux + a
        if collect_cache:
            new_caches.setdefault("prefix", {})[str(i)] = nc

    if groups.n_units:
        ref = len(groups.prefix)
        remat = cfg.policy.remat == "block" and ctx.mode == "train"

        def one_block(j, kind, p_, xc, sl):
            def f(p__, xc__):
                return apply_block(cfg, kind, ref + j, p__, xc__,
                                   _with_cache(ctx, sl))
            if remat:
                f = jax.checkpoint(f)
            return f(p_, xc)

        def unit_body(carry, xs):
            xc, auxc = carry
            up, uc = xs
            ncs = {}
            for j, kind in enumerate(groups.unit):
                sl = None if uc is None else uc[f"b{j}"]
                xc, nc, a = one_block(j, kind, up[f"b{j}"], xc, sl)
                auxc = auxc + a
                ncs[f"b{j}"] = nc
            xc = lc(xc, "batch", "seq_sp", None)
            if not collect_cache:
                ncs = None
            return (xc, auxc), ncs

        stack_caches = get(caches, "stack")
        if stack_caches is None:
            (x, aux), ncs = jax.lax.scan(
                lambda c, p_: unit_body(c, (p_, None)), (x, aux),
                params["stack"])
        else:
            (x, aux), ncs = jax.lax.scan(unit_body, (x, aux),
                                         (params["stack"], stack_caches))
        if collect_cache:
            new_caches["stack"] = ncs

    base = len(groups.prefix) + groups.n_units * len(groups.unit)
    for i, kind in enumerate(groups.suffix):
        sl = get(caches, "suffix", str(i))
        x, nc, a = run_one(kind, base + i, params["suffix"][str(i)], x, sl)
        aux = aux + a
        if collect_cache:
            new_caches.setdefault("suffix", {})[str(i)] = nc
    return x, new_caches, aux


def _with_cache(ctx: BlockCtx, cache) -> BlockCtx:
    return BlockCtx(mode=ctx.mode, positions=ctx.positions, cache=cache,
                    enc_out=ctx.enc_out, cache_len=ctx.cache_len,
                    hierarchy_levels=ctx.hierarchy_levels)


def encode(cfg: ModelConfig, params, frames):
    """Encoder over precomputed frame embeddings (B, Se, d)."""
    x = lc(frames, "batch", "seq_sp", None)
    pos = jnp.arange(frames.shape[1])
    ctx = BlockCtx(mode="train", positions=pos)
    for i in range(cfg.n_enc_layers):
        x, _, _ = apply_block(cfg, "E", i, params["encoder"][str(i)], x, ctx)
    return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def forward(cfg: ModelConfig, params, batch: dict, *, return_cache=False,
            hierarchy_levels: int = 0):
    """batch: tokens (B,S) [+ image_embeds (B,P,d) | frames (B,Se,d)].

    Returns (logits (B, S_total, V), caches|None, aux_loss).
    """
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.vlm_patches:
        img = batch["image_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
    x = lc(x, "batch", "seq_sp", None)
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, batch["frames"].astype(x.dtype))
    S = x.shape[1]
    ctx = BlockCtx(mode="train", positions=jnp.arange(S), enc_out=enc_out,
                   hierarchy_levels=hierarchy_levels)
    x, caches, aux = _run_layers(cfg, params, x, ctx,
                                 collect_cache=return_cache)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = _lm_head(cfg, params, x)
    return logits, (caches if return_cache else None), aux


def _lm_head(cfg: ModelConfig, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab:      # mask pad rows out of the softmax
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return lc(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, smax: int, enc_len: int = 0):
    groups = layer_groups(cfg)
    cache: dict = {}
    if groups.prefix:
        cache["prefix"] = {
            str(i): init_block_cache(cfg, kind, batch, smax, enc_len)
            for i, kind in enumerate(groups.prefix)}
    if groups.n_units:
        def one(_):
            return {f"b{j}": init_block_cache(cfg, kind, batch, smax, enc_len)
                    for j, kind in enumerate(groups.unit)}
        cache["stack"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (groups.n_units,) + x.shape),
            one(None))
    if groups.suffix:
        cache["suffix"] = {
            str(i): init_block_cache(cfg, kind, batch, smax, enc_len)
            for i, kind in enumerate(groups.suffix)}
    return cache


def cache_axes(cfg: ModelConfig):
    """Logical-axes tree mirroring ``init_cache``."""
    from repro.models.lm.blocks import block_cache_axes
    groups = layer_groups(cfg)
    axes: dict = {}
    if groups.prefix:
        axes["prefix"] = {str(i): block_cache_axes(cfg, kind)
                          for i, kind in enumerate(groups.prefix)}
    if groups.n_units:
        unit = {f"b{j}": block_cache_axes(cfg, kind)
                for j, kind in enumerate(groups.unit)}
        axes["stack"] = stack_axes(unit)
    if groups.suffix:
        axes["suffix"] = {str(i): block_cache_axes(cfg, kind)
                          for i, kind in enumerate(groups.suffix)}
    return axes


def decode_step(cfg: ModelConfig, params, cache, token, cache_len):
    """token (B,1) int32; cache_len scalar int32.  Returns (logits, cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    x = lc(x, "batch", None, None)
    pos = cache_len[None] if cache_len.ndim == 0 else cache_len
    ctx = BlockCtx(mode="decode", positions=pos, cache_len=cache_len)
    x, new_caches, _ = _run_layers(cfg, params, x, ctx, caches=cache,
                                   collect_cache=True)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return _lm_head(cfg, params, x), new_caches


def prefill(cfg: ModelConfig, params, batch: dict):
    """Forward over the prompt, returning (last_logits, caches).

    Cache seq dims equal the prompt length; the serve driver re-pads into
    its decode cache (``decode_cache_from_prefill``).
    """
    logits, caches, _ = forward(cfg, params, batch, return_cache=True)
    return logits[:, -1:], caches


def decode_cache_from_prefill(cfg: ModelConfig, caches, prompt_len: int,
                              smax: int):
    """Pad prefill caches (seq dim = prompt_len) into decode caches (smax).

    Attention k/v grow to smax; sliding-window caches become ring buffers;
    recurrent states pass through unchanged.
    """
    W = cfg.window

    def fix(c, lead):
        """c: one layer's cache dict; lead=1 if leaves carry n_units dim."""
        if not isinstance(c, dict) or not any(
                n in c for n in ("k", "v", "ckv", "kr")):
            return c                                  # recurrent state
        out = dict(c)
        sdim = 1 + lead                               # (units?, B, S, ...)
        for name in ("k", "v", "ckv", "kr"):
            if name not in c:
                continue
            arr = c[name]
            if W is not None and name in ("k", "v"):
                if prompt_len >= W:
                    idx = [slice(None)] * arr.ndim
                    idx[sdim] = slice(prompt_len - W, prompt_len)
                    tail = arr[tuple(idx)]
                    slots = np.arange(prompt_len - W, prompt_len) % W
                    out[name] = jnp.take(tail, np.argsort(slots), axis=sdim)
                else:
                    pad = [(0, 0)] * arr.ndim
                    pad[sdim] = (0, W - prompt_len)
                    out[name] = jnp.pad(arr, pad)
            else:
                pad = [(0, 0)] * arr.ndim
                pad[sdim] = (0, smax - prompt_len)
                out[name] = jnp.pad(arr, pad)
        if W is not None and "k" in c:
            pos = np.full((W,), -1, np.int32)
            n = min(prompt_len, W)
            pp = np.arange(prompt_len - n, prompt_len)
            pos[pp % W] = pp
            pos = jnp.asarray(pos)
            if lead:
                nu = c["k"].shape[0]
                pos = jnp.broadcast_to(pos[None], (nu, W))
            out["pos"] = pos
        return out

    out: dict = {}
    for grp, sub in caches.items():
        if grp == "stack":
            out[grp] = {bj: fix(sl, 1) for bj, sl in sub.items()}
        else:
            out[grp] = {i: fix(sl, 0) for i, sl in sub.items()}
    return out
