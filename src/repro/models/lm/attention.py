"""Attention: GQA (full / chunked-flash / sliding-window) + DeepSeek MLA.

All long-sequence paths are *static-shape* and XLA-native so the dry-run's
``cost_analysis()`` is meaningful (Pallas kernels are opaque to HLO cost
analysis; the Pallas flash kernel in ``repro.kernels.flash_attention`` is the
TPU execution path and is validated against these references).

``hierarchy_levels``: hierarchical causal decomposition.  A masked full
rectangle costs S^2 score-FLOPs; recursively splitting (q-halves attend
prefix unmasked + diagonal recursively) converges to the 0.5*S^2 causal
optimum with *static* shapes: levels L -> (0.5 + 0.5^(L+1)) * S^2.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions):
    """(..., head_dim//2) cos/sin tables for given integer positions."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) rotated pairwise over D; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    cos, sin = rope_freqs(d, theta, positions)            # (S, d/2) or (B,S,d/2)
    if cos.ndim == 2:                                     # (S, half) -> broadcast
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                                                 # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Online-softmax chunked attention (flash-in-XLA)
# ---------------------------------------------------------------------------

def _chunk_scores(q, k, scale):
    """q (B,Cq,Kh,G,D), k (B,Ck,Kh,D) -> (B,Kh,G,Cq,Ck) fp32."""
    return jnp.einsum("bqkgd,bckd->bkgqc", q, k,
                      preferred_element_type=jnp.float32) * scale


def _online_chunk(carry, kv, q, qpos, kpos, scale, causal, window):
    """One online-softmax step over a kv chunk.  carry=(acc,m,l)."""
    acc, m, l = carry
    k, v = kv
    s = _chunk_scores(q, k, scale)                        # (B,Kh,G,Cq,Ck)
    mask = (kpos >= 0)[None, :]                           # exclude padding
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window is not None:
        mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))                # (B,Kh,G,Cq)
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    acc = acc * corr[..., None] + pv
    return (acc, m_new, l), None


def _attend_partial(q, k, v, q_offset, k_offset, *, scale, causal,
                    window=None, kv_chunk=1024):
    """Online-softmax attention returning unnormalised partials.

    q: (B,Cq,Kh,G,D); k,v: (B,Sk,Kh,D).  Returns (acc fp32 (B,Kh,G,Cq,D),
    m (B,Kh,G,Cq), l (B,Kh,G,Cq)).  Offsets give absolute positions.
    """
    B, Cq, Kh, G, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[1]
    kv_chunk = math.gcd(Sk, min(kv_chunk, Sk))
    n_kv = Sk // kv_chunk
    qpos = q_offset + jnp.arange(Cq)
    acc = jnp.zeros((B, Kh, G, Cq, Dv), jnp.float32)
    m = jnp.full((B, Kh, G, Cq), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Kh, G, Cq), jnp.float32)
    if n_kv == 1:
        kpos = k_offset + jnp.arange(Sk)
        (acc, m, l), _ = _online_chunk((acc, m, l), (k, v), q, qpos, kpos,
                                       scale, causal, window)
        return acc, m, l

    kr = k.reshape(B, n_kv, kv_chunk, Kh, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, n_kv, kv_chunk, Kh, Dv).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint       # flash-style: recompute p in backward, never save
    def body(carry, xs):
        kc, vc, j = xs
        kpos = k_offset + j * kv_chunk + jnp.arange(kv_chunk)
        return _online_chunk(carry, (kc, vc), q, qpos, kpos, scale, causal,
                             window)

    (acc, m, l), _ = jax.lax.scan(
        body, (acc, m, l), (kr, vr, jnp.arange(n_kv)))
    return acc, m, l


def _merge_partials(parts):
    """Merge online-softmax partials [(acc, m, l), ...] -> normalised out."""
    acc0, m0, l0 = parts[0]
    for acc1, m1, l1 in parts[1:]:
        m_new = jnp.maximum(m0, m1)
        c0 = jnp.exp(m0 - m_new)
        c1 = jnp.exp(m1 - m_new)
        acc0 = acc0 * c0[..., None] + acc1 * c1[..., None]
        l0 = l0 * c0 + l1 * c1
        m0 = m_new
    return acc0 / jnp.maximum(l0[..., None], 1e-30)


def _causal_hier(q, k, v, q_off, k_off, *, scale, levels, q_chunk, kv_chunk):
    """Hierarchical causal decomposition (static shapes)."""
    S = q.shape[1]
    if levels <= 0 or S <= max(q_chunk, kv_chunk) or S % 2:
        return _causal_scan(q, k, v, q_off, k_off, scale=scale,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
    h = S // 2
    out1 = _causal_hier(q[:, :h], k[:, :h], v[:, :h], q_off, k_off,
                        scale=scale, levels=levels - 1, q_chunk=q_chunk,
                        kv_chunk=kv_chunk)
    # second q half: unmasked prefix + recursive diagonal, merged online
    q2 = _to5(q[:, h:])
    pre = _attend_partial(q2, k[:, :h], v[:, :h], q_off + h, k_off,
                          scale=scale, causal=False, kv_chunk=kv_chunk)
    dia = _causal_partial(q[:, h:], k[:, h:], v[:, h:], q_off + h, k_off + h,
                          scale=scale, q_chunk=q_chunk, kv_chunk=kv_chunk)
    out2 = _from5(_merge_partials([pre, dia]), q.dtype)
    return jnp.concatenate([out1, out2], axis=1)


def _to5(q):
    # (B,S,H,D) -> (B,S,Kh,G,D) is done by caller; here q is already 5D or 4D
    return q


def _from5(acc, dtype):
    # acc (B,Kh,G,Cq,D) -> (B,Cq,Kh*G,D)
    B, Kh, G, Cq, D = acc.shape
    return acc.transpose(0, 3, 1, 2, 4).reshape(B, Cq, Kh * G, D).astype(dtype)


def _causal_partial(q, k, v, q_off, k_off, *, scale, q_chunk, kv_chunk):
    """Masked-rectangle causal attention partials for the whole q block."""
    return _attend_partial(q, k, v, q_off, k_off, scale=scale, causal=True,
                           kv_chunk=kv_chunk)


def _causal_scan(q, k, v, q_off, k_off, *, scale, q_chunk, kv_chunk):
    """Scan over q chunks; each does online softmax over all kv (masked)."""
    B, S, Kh, G, D = q.shape
    q_chunk = math.gcd(S, min(q_chunk, S))
    nq = S // q_chunk
    if nq == 1:
        acc, m, l = _attend_partial(q, k, v, q_off, k_off, scale=scale,
                                    causal=True, kv_chunk=kv_chunk)
        return _from5(_merge_partials([(acc, m, l)]), q.dtype)

    qr = q.reshape(B, nq, q_chunk, Kh, G, D).transpose(1, 0, 2, 3, 4, 5)

    @jax.checkpoint
    def body(_, xs):
        qc, i = xs
        acc, m, l = _attend_partial(qc, k, v, q_off + i * q_chunk, k_off,
                                    scale=scale, causal=True,
                                    kv_chunk=kv_chunk)
        return None, _from5(_merge_partials([(acc, m, l)]), q.dtype)

    _, outs = jax.lax.scan(body, None, (qr, jnp.arange(nq)))
    # outs: (nq, B, q_chunk, H, Dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Kh * G, -1)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def gqa_attention(q, k, v, *, causal=True, window=None, impl="chunked",
                  q_chunk=512, kv_chunk=1024, hierarchy_levels=0):
    """q (B,S,H,D); k,v (B,S,Kh,D); H % Kh == 0.  Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh
    scale = 1.0 / math.sqrt(D)
    q5 = q.reshape(B, S, Kh, G, D)
    if impl == "local" and window is not None and S > window:
        return _local_attention(q5, k, v, window=window, scale=scale)
    if impl == "full" or S <= q_chunk:
        acc, m, l = _attend_partial(q5, k, v, 0, 0, scale=scale,
                                    causal=causal, window=window,
                                    kv_chunk=max(S, 1))
        return _from5(_merge_partials([(acc, m, l)]), q.dtype)
    if not causal:
        acc, m, l = _attend_partial(q5, k, v, 0, 0, scale=scale, causal=False,
                                    kv_chunk=kv_chunk)
        return _from5(_merge_partials([(acc, m, l)]), q.dtype)
    if hierarchy_levels > 0:
        return _causal_hier(q5, k, v, 0, 0, scale=scale,
                            levels=hierarchy_levels, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    return _causal_scan(q5, k, v, 0, 0, scale=scale, q_chunk=q_chunk,
                        kv_chunk=kv_chunk)


def _local_attention(q5, k, v, *, window, scale):
    """Banded sliding-window attention: q chunk i sees kv [iW-W, iW+W)."""
    B, S, Kh, G, D = q5.shape
    W = window
    nq = S // W
    assert S % W == 0, (S, W)
    kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))
    qr = q5.reshape(B, nq, W, Kh, G, D).transpose(1, 0, 2, 3, 4, 5)

    @jax.checkpoint
    def body(_, xs):
        qc, i = xs
        k_sl = jax.lax.dynamic_slice_in_dim(kp, i * W, 2 * W, axis=1)
        v_sl = jax.lax.dynamic_slice_in_dim(vp, i * W, 2 * W, axis=1)
        # absolute positions: q chunk starts at i*W; the slice starts at
        # real position i*W - W (front pad has kpos < 0 -> masked)
        acc, m, l = _attend_partial(qc, k_sl, v_sl, i * W, i * W - W,
                                    scale=scale, causal=True, window=W,
                                    kv_chunk=2 * W)
        return None, _from5(_merge_partials([(acc, m, l)]), qc.dtype)

    _, outs = jax.lax.scan(body, None, (qr, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Kh * G, -1)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     chunk=4096):
    """Single-step decode, flash-decoding style: online softmax over cache
    chunks so only one chunk is ever live/upcast at a time.

    q (B,1,H,D); caches (B,Smax,Kh,D); cache_len (B,).
    """
    B, _, H, D = q.shape
    Kh = k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // Kh
    Smax = k_cache.shape[1]
    scale = 1.0 / math.sqrt(D)
    q5 = q.reshape(B, 1, Kh, G, D)
    chunk = math.gcd(Smax, min(chunk, Smax))
    nc = Smax // chunk

    def score_chunk(kj, vj, kpos):
        s = jnp.einsum("bqkgd,bckd->bkgqc", q5, kj,
                       preferred_element_type=jnp.float32) * scale
        valid = kpos[None] < cache_len[:, None]              # (B, chunk)
        if window is not None:
            valid &= kpos[None] >= (cache_len[:, None] - window)
        return jnp.where(valid[:, None, None, None, :], s, NEG_INF), vj

    def online(carry, sv):
        acc, m, l = carry
        s, vj = sv
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(q.dtype), vj,
                        preferred_element_type=jnp.float32)
        return (acc * corr[..., None] + pv, m_new, l), None

    acc = jnp.zeros((B, Kh, G, 1, Dv), jnp.float32)
    m = jnp.full((B, Kh, G, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Kh, G, 1), jnp.float32)
    if nc == 1:
        s, vj = score_chunk(k_cache, v_cache, jnp.arange(Smax))
        (acc, m, l), _ = online((acc, m, l), (s, vj))
    else:
        kr = k_cache.reshape(B, nc, chunk, Kh, D).transpose(1, 0, 2, 3, 4)
        vr = v_cache.reshape(B, nc, chunk, Kh, Dv).transpose(1, 0, 2, 3, 4)

        def body(carry, xs):
            kj, vj, j = xs
            s, vj = score_chunk(kj, vj, j * chunk + jnp.arange(chunk))
            return online(carry, (s, vj))[0], None

        (acc, m, l), _ = jax.lax.scan(body, (acc, m, l),
                                      (kr, vr, jnp.arange(nc)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, Dv).astype(q.dtype)
