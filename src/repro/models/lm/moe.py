"""Mixture-of-Experts: shared + routed top-k with two dispatch strategies.

``dense``   — every expert computes every token, combined with routing
              weights.  O(T*E) FLOPs: only for smoke tests and as the oracle
              the EP path is validated against.
``ep``      — expert parallelism: capacity-based all_to_all dispatch inside
              ``shard_map`` over the config's ``ep_axes``.  This is the
              paper's GConv partition expressed at datacentre scale: expert
              groups execute in parallel on disjoint devices and results are
              concatenated/combined afterwards, latency = max(group) + comm.

Local expert compute is either ``scan`` (masked loop over local experts,
E_loc x FLOPs waste, differentiable everywhere — default for training) or
``ragged`` (sort + jax.lax.ragged_dot, no waste — serving/perf path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.lm.common import Schema, ffn_apply, ffn_schema, prefix_schema
from repro.models.lm.sharding import current_rules


def _shard_map(body, mesh, in_specs, out_specs):
    """jax.shard_map (jax >= 0.5, check_vma) vs experimental shard_map
    (jax 0.4.x, check_rep) — same semantics, replication check off."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _pad_experts(m: MoEConfig, n_ep: int) -> int:
    """Experts padded up to a multiple of the EP group count."""
    e = m.n_routed
    return ((e + n_ep - 1) // n_ep) * n_ep if n_ep > 1 else e


def moe_schema(d: int, m: MoEConfig, n_ep: int = 1) -> Schema:
    e_pad = _pad_experts(m, n_ep)
    s: Schema = {
        "router/w": ((d, m.n_routed), ("embed", None), "normal"),
        "experts/w_gate": ((e_pad, d, m.d_ff_expert), ("experts", "embed_ep", None), "normal"),
        "experts/w_up": ((e_pad, d, m.d_ff_expert), ("experts", "embed_ep", None), "normal"),
        "experts/w_down": ((e_pad, m.d_ff_expert, d), ("experts", None, "embed_ep"), "normal"),
    }
    if m.n_shared:
        s.update(prefix_schema("shared", ffn_schema(d, m.n_shared * m.d_ff_shared)))
        s["shared_gate/w"] = ((d, 1), ("embed", None), "normal")
    return s


def _route(x, wr, top_k: int):
    """Router: returns (weights (T,k), idx (T,k), (f, p) balance stats)."""
    logits = jnp.einsum("td,de->te", x, wr,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, top_k)
    weights = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance statistics
    e = wr.shape[-1]
    f = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p = probs.mean(axis=0)
    return weights, idx, (f, p)


def _aux_from_stats(f, p):
    return f.shape[-1] * jnp.sum(f * p)


def _shared_out(p, x):
    """Shared-expert FFN on flattened (T, d) tokens — token-sharded layout."""
    from repro.models.lm.sharding import lc
    if "shared" not in p:
        return 0.0
    y = ffn_apply(p["shared"], x, hidden_axes=("tokens", None))
    y = lc(y, "tokens", None)
    g = jax.nn.sigmoid(
        jnp.einsum("td,dk->tk", x, p["shared_gate"]["w"],
                   preferred_element_type=jnp.float32))
    return y * g.astype(y.dtype)


# ---------------------------------------------------------------------------
# Dense dispatch (oracle / smoke)
# ---------------------------------------------------------------------------

def moe_dense(p, x, m: MoEConfig):
    """x (T, d) -> (y (T, d), aux)."""
    weights, idx, (f_, p_) = _route(x, p["router"]["w"], m.top_k)
    aux = _aux_from_stats(f_, p_)
    e = m.n_routed

    def per_expert(carry, ew):
        wg, wu, wd, ei = ew
        h = jax.nn.silu((x @ wg).astype(jnp.float32)).astype(x.dtype) * (x @ wu)
        y_e = h @ wd                                    # (T, d)
        gate = jnp.sum(jnp.where(idx == ei, weights, 0.0), axis=-1)  # (T,)
        return carry + y_e * gate[:, None].astype(y_e.dtype), None

    init = jnp.zeros_like(x)
    ew = (p["experts"]["w_gate"][:e], p["experts"]["w_up"][:e],
          p["experts"]["w_down"][:e], jnp.arange(e))
    y, _ = jax.lax.scan(per_expert, init, ew)
    return y + _shared_out(p, x), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map + all_to_all)
# ---------------------------------------------------------------------------

def _ep_local(x, wr, wg, wu, wd, m: MoEConfig, ep_axes, n_ep: int,
              local_compute: str, tok_axes):
    """Per-device body under shard_map.  x (T_loc, d); w* (E_loc, ...)."""
    t, d = x.shape
    e_loc = wg.shape[0]
    e_pad = e_loc * n_ep
    weights, idx, (f_, p_) = _route(x, wr, m.top_k)     # idx in [0, n_routed)
    # global load-balance loss: average the STATS across every token shard,
    # then take the product — identical to the dense oracle's global aux
    aux = _aux_from_stats(jax.lax.pmean(f_, tok_axes),
                          jax.lax.pmean(p_, tok_axes))

    flat_idx = idx.reshape(-1)                          # (T*k,)
    flat_w = weights.reshape(-1)
    dst = flat_idx // e_loc                             # destination EP shard
    lid = flat_idx % e_loc                              # local expert on dst
    cap = int(max(8, round(t * m.top_k * m.capacity_factor / n_ep)))
    # slot = rank of this assignment among those to the same dst
    onehot = (dst[:, None] == jnp.arange(n_ep)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, dst[:, None], axis=1)[:, 0]
    keep = slot < cap
    send_idx = jnp.where(keep, dst * cap + slot, n_ep * cap)   # OOB -> drop

    tok = jnp.arange(t * m.top_k) // m.top_k
    buf_x = jnp.zeros((n_ep * cap, d), x.dtype).at[send_idx].set(
        x[tok], mode="drop")
    buf_l = jnp.zeros((n_ep * cap,), jnp.int32).at[send_idx].set(
        lid + 1, mode="drop")                            # 0 = empty

    a2a = partial(jax.lax.all_to_all, axis_name=ep_axes, split_axis=0,
                  concat_axis=0, tiled=True)
    recv_x = a2a(buf_x)                                  # (n_ep*cap, d)
    recv_l = a2a(buf_l) - 1                              # -1 = empty

    if local_compute == "ragged" and e_loc > 1:
        grp = jnp.where(recv_l < 0, e_loc - 1, recv_l)
        order = jnp.argsort(grp, stable=True)
        xs = recv_x[order]
        gs = jnp.zeros((e_loc,), jnp.int32).at[grp].add(1)
        h = jax.nn.silu(jax.lax.ragged_dot(xs, wg, gs).astype(jnp.float32))
        h = h.astype(x.dtype) * jax.lax.ragged_dot(xs, wu, gs)
        ys = jax.lax.ragged_dot(h, wd, gs)
        y_rows = jnp.zeros_like(ys).at[order].set(ys)
    elif e_loc == 1:
        h = jax.nn.silu((recv_x @ wg[0]).astype(jnp.float32)).astype(x.dtype)
        y_rows = (h * (recv_x @ wu[0])) @ wd[0]
    else:
        def per_local(carry, ew):
            g_, u_, d_, ei = ew
            h = jax.nn.silu((recv_x @ g_).astype(jnp.float32)).astype(x.dtype)
            y_e = (h * (recv_x @ u_)) @ d_
            sel = (recv_l == ei)[:, None]
            return carry + jnp.where(sel, y_e, 0.0), None
        y_rows, _ = jax.lax.scan(
            per_local, jnp.zeros_like(recv_x),
            (wg, wu, wd, jnp.arange(e_loc)))

    back = a2a(y_rows)                                   # (n_ep*cap, d)
    safe = jnp.where(keep, dst * cap + slot, 0)
    y_tk = back[safe] * keep[:, None].astype(back.dtype)  # (T*k, d)
    y = jnp.zeros_like(x).at[tok].add(y_tk * flat_w[:, None].astype(back.dtype))
    return y, aux


def _scatter_to(dst, payloads, n_dst: int, cap: int):
    """Capacity-scatter rows to per-destination buffers.

    dst (R,) int32; payloads: list of (R, ...) arrays.  Returns
    ([(n_dst*cap, ...)], keep (R,), slot (R,)).
    """
    r = dst.shape[0]
    onehot = (dst[:, None] == jnp.arange(n_dst)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, dst[:, None], axis=1)[:, 0]
    keep = slot < cap
    send_idx = jnp.where(keep, dst * cap + slot, n_dst * cap)
    bufs = []
    for pay in payloads:
        shape = (n_dst * cap,) + pay.shape[1:]
        bufs.append(jnp.zeros(shape, pay.dtype).at[send_idx].set(
            pay, mode="drop"))
    return bufs, keep, slot


def _ep2_local(x, wr, wg, wu, wd, m: MoEConfig, ax_d, ax_m, n_d, n_m,
               tok_axes, local_compute: str):
    """Hierarchical 2-hop expert dispatch (beyond-paper §Perf):

    expert e lives on device (d, m_) = (e // (n_m*E_loc*?) ...) arranged
    row-major; tokens hop all_to_all over the `data` axis first, then over
    `model`.  Each collective spans 16 devices instead of 256, which (a)
    keeps the XLA while loop rolled (full-mesh a2a triggers loop unrolling)
    and (b) matches torus link locality.
    """
    t, d = x.shape
    e_loc = wg.shape[0]
    weights, idx, (f_, p_) = _route(x, wr, m.top_k)
    aux = _aux_from_stats(jax.lax.pmean(f_, tok_axes),
                          jax.lax.pmean(p_, tok_axes))

    flat_idx = idx.reshape(-1)
    flat_w = weights.reshape(-1)
    tok = jnp.arange(t * m.top_k) // m.top_k
    # expert e -> (d_dst, m_dst, lid)
    per_d = n_m * e_loc
    d_dst = flat_idx // per_d
    m_dst = (flat_idx % per_d) // e_loc
    lid = flat_idx % e_loc

    cap1 = int(max(8, round(t * m.top_k * m.capacity_factor / n_d)))
    (bx1, bm1, bl1), keep1, slot1 = _scatter_to(
        d_dst, [x[tok], m_dst + 1, lid.astype(jnp.int32)], n_d, cap1)
    a2a_d = partial(jax.lax.all_to_all, axis_name=ax_d, split_axis=0,
                    concat_axis=0, tiled=True)
    rx1, rm1, rl1 = a2a_d(bx1), a2a_d(bm1), a2a_d(bl1)

    # hop 2: within the data row, to the model column owning the expert
    valid1 = rm1 > 0
    cap2 = int(max(8, round(t * m.top_k * m.capacity_factor / (n_d * n_m)
                            * n_d)))
    dst2 = jnp.where(valid1, rm1 - 1, n_m)           # invalid -> dropped
    (bx2, bl2), keep2, slot2 = _scatter_to(
        dst2, [rx1, rl1 + 1], n_m, cap2)
    a2a_m = partial(jax.lax.all_to_all, axis_name=ax_m, split_axis=0,
                    concat_axis=0, tiled=True)
    rx2, rl2 = a2a_m(bx2), a2a_m(bl2)

    lid2 = rl2 - 1
    if e_loc == 1:
        h = jax.nn.silu((rx2 @ wg[0]).astype(jnp.float32)).astype(x.dtype)
        y2 = (h * (rx2 @ wu[0])) @ wd[0]
    else:
        def per_local(carry, ew):
            g_, u_, dn_, ei = ew
            h = jax.nn.silu((rx2 @ g_).astype(jnp.float32)).astype(x.dtype)
            y_e = (h * (rx2 @ u_)) @ dn_
            return carry + jnp.where((lid2 == ei)[:, None], y_e, 0.0), None
        y2, _ = jax.lax.scan(per_local, jnp.zeros_like(rx2),
                             (wg, wu, wd, jnp.arange(e_loc)))

    # reverse hop 2
    back2 = a2a_m(y2)
    safe2 = jnp.where(keep2, dst2 * cap2 + slot2, 0)
    y1 = back2[safe2] * (keep2 & valid1)[:, None].astype(back2.dtype)
    # reverse hop 1
    back1 = a2a_d(y1)
    safe1 = jnp.where(keep1, d_dst * cap1 + slot1, 0)
    y_tk = back1[safe1] * keep1[:, None].astype(back1.dtype)
    y = jnp.zeros_like(x).at[tok].add(
        y_tk * flat_w[:, None].astype(back1.dtype))
    return y, aux


def moe_ep(p, x, m: MoEConfig, local_compute: str = "scan"):
    """x (T, d) sharded over (batch x seq); EP over m.ep_axes."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return moe_dense(p, x, m)
    mesh = rules.mesh
    ep_axes = tuple(a for a in m.ep_axes if a in mesh.axis_names)
    n_ep = 1
    for a in ep_axes:
        n_ep *= mesh.shape[a]
    if n_ep == 1:
        return moe_dense(p, x, m)

    from jax.sharding import PartitionSpec as P
    # tokens sharded over every batch-bearing axis + model (SP layout)
    tok_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    n_tok = 1
    for a in tok_axes:
        n_tok *= mesh.shape[a]
    t_global = x.shape[0]
    t_pad = -(-t_global // n_tok) * n_tok          # decode: pad tiny batches
    xp = jnp.pad(x, ((0, t_pad - t_global), (0, 0))) if t_pad != t_global else x
    from repro.models.lm.sharding import lc
    xp = lc(xp, "tokens", None)
    x_spec = P(tok_axes, None)
    # expert weights enter the shard_map gathered over the FSDP dim
    e_spec = P(ep_axes, None, None)
    out_specs = (x_spec, P())

    if m.dispatch == "ep2" and len(ep_axes) == 2:
        ax_d, ax_m = ep_axes
        n_d, n_m = mesh.shape[ax_d], mesh.shape[ax_m]

        def body(x_, wr_, wg_, wu_, wd_):
            return _ep2_local(x_, wr_, wg_, wu_, wd_, m, ax_d, ax_m,
                              n_d, n_m, tok_axes, local_compute)
    else:
        def body(x_, wr_, wg_, wu_, wd_):
            return _ep_local(x_, wr_, wg_, wu_, wd_, m, ep_axes, n_ep,
                             local_compute, tok_axes)

    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), e_spec, e_spec, e_spec),
        out_specs=out_specs,
    )(xp, p["router"]["w"], p["experts"]["w_gate"], p["experts"]["w_up"],
      p["experts"]["w_down"])
    y = lc(y, "tokens", None)
    if t_pad != t_global:
        y = y[:t_global]
    return y + _shared_out(p, x), aux


def moe_apply(p, x, m: MoEConfig, deterministic_dispatch: str | None = None):
    """x (..., d) -> (y, aux_loss).  Flattens leading dims."""
    from repro.models.lm.sharding import lc
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if x2.shape[0] % 256 == 0:       # keep SP token layout through the moe
        x2 = lc(x2, "tokens", None)
    dispatch = deterministic_dispatch or m.dispatch
    if dispatch == "dense":
        y, aux = moe_dense(p, x2, m)
    else:
        y, aux = moe_ep(p, x2, m)
    return y.reshape(shape), aux
