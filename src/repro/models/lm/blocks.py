"""Transformer / hybrid blocks: schemas + apply for every layer kind.

Layer kinds:
  "A" — (self-)attention + FFN/MoE     (GQA or MLA)
  "D" — decoder block: self-attn + cross-attn + FFN   (enc-dec)
  "E" — encoder block: bidirectional attn + FFN
  "R" — RG-LRU recurrent block + FFN   (recurrentgemma)
  "m" — mLSTM block (self-contained)
  "s" — sLSTM block (self-contained)

``apply_block(cfg, kind, params, x, ctx)`` where ctx carries positions,
mode ("train"|"decode"), per-layer cache slice, encoder output, and returns
(x, new_cache_slice, aux_loss).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import attention as attn
from repro.models.lm.common import (Schema, ffn_apply, ffn_schema,
                                    merge_schemas, norm_schema, prefix_schema,
                                    rms_norm)
from repro.models.lm.moe import moe_apply, moe_schema
from repro.models.lm.rglru import rglru_apply, rglru_init_state, rglru_schema
from repro.models.lm.sharding import lc
from repro.models.lm.xlstm import (mlstm_apply, mlstm_init_state,
                                   mlstm_schema, slstm_apply,
                                   slstm_init_state, slstm_schema)


@dataclass
class BlockCtx:
    mode: str                      # "train" | "decode"
    positions: Any                 # (S,) int32 absolute positions
    cache: Any = None              # per-layer cache slice (decode) / None
    enc_out: Any = None            # (B, Se, d) for cross-attention
    cache_len: Any = None          # scalar int32 current length (decode)
    hierarchy_levels: int = 0      # causal-attention decomposition level


# ---------------------------------------------------------------------------
# Attention sublayer (GQA)
# ---------------------------------------------------------------------------

def gqa_schema(cfg: ModelConfig, cross: bool = False) -> Schema:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    s: Schema = {
        "wq": ((d, nq), ("embed", "heads"), "normal"),
        "wk": ((d, nkv), ("embed", "kv_heads"), "normal"),
        "wv": ((d, nkv), ("embed", "kv_heads"), "normal"),
        "wo": ((nq, d), ("heads", "embed"), "normal"),
    }
    if cfg.qkv_bias and not cross:
        s.update({
            "bq": ((nq,), ("heads",), "zeros"),
            "bk": ((nkv,), ("kv_heads",), "zeros"),
            "bv": ((nkv,), ("kv_heads",), "zeros"),
        })
    return s


def _qkv(cfg, p, x, kv_src=None):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    kv_src = x if kv_src is None else kv_src
    Skv = kv_src.shape[1]
    q = jnp.einsum("bsd,dn->bsn", x, p["wq"])
    k = jnp.einsum("bsd,dn->bsn", kv_src, p["wk"])
    v = jnp.einsum("bsd,dn->bsn", kv_src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = lc(q.reshape(B, S, cfg.n_heads, hd), "batch", None, "heads", None)
    k = lc(k.reshape(B, Skv, cfg.n_kv_heads, hd), "batch", None, "kv_heads", None)
    v = lc(v.reshape(B, Skv, cfg.n_kv_heads, hd), "batch", None, "kv_heads", None)
    return q, k, v


def gqa_self_attention(cfg: ModelConfig, p, x, ctx: BlockCtx):
    """Returns (out, new_cache).

    Cache layout: {k,v: (B, Smax, Kh*hd)} — the head dim is FLATTENED so the
    cache shards evenly over a 16-way model axis even when Kh < 16 (jit
    argument shardings must divide exactly; intermediates may pad).
    """
    B, S, _ = x.shape
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(cfg, p, x)
    if ctx.mode == "decode":
        q = attn.apply_rope(q, ctx.positions, cfg.rope_theta)
        k = attn.apply_rope(k, ctx.positions, cfg.rope_theta)
        cache = ctx.cache
        kf, vf = k.reshape(B, 1, Kh * hd), v.reshape(B, 1, Kh * hd)
        if cfg.window is not None:                     # ring buffer
            slot = ctx.cache_len % cfg.window
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], kf, slot, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], vf, slot, 1)
            pos = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], ctx.cache_len[None].astype(jnp.int32), slot, 0)
            W = cfg.window
            k4 = kc.reshape(B, W, Kh, hd)
            v4 = vc.reshape(B, W, Kh, hd)
            s = jnp.einsum("bqkgd,bckd->bkgqc",
                           q.reshape(B, 1, Kh, -1, hd), k4,
                           preferred_element_type=jnp.float32)
            s = s / jnp.sqrt(jnp.float32(hd))
            valid = ((pos >= 0) & (pos <= ctx.cache_len)
                     & (pos > ctx.cache_len - W))
            s = jnp.where(valid[None, None, None, None, :], s, attn.NEG_INF)
            pr = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bkgqc,bckd->bkgqd", pr.astype(x.dtype), v4,
                           preferred_element_type=jnp.float32)
            o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, cfg.n_heads, -1)
            out, new_cache = o.astype(x.dtype), {"k": kc, "v": vc, "pos": pos}
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kf, ctx.cache_len, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vf, ctx.cache_len, 1)
            Smax = kc.shape[1]
            out = attn.decode_attention(q, kc.reshape(B, Smax, Kh, hd),
                                        vc.reshape(B, Smax, Kh, hd),
                                        jnp.full((B,), ctx.cache_len + 1))
            new_cache = {"k": kc, "v": vc}
    else:
        q = attn.apply_rope(q, ctx.positions, cfg.rope_theta)
        k = attn.apply_rope(k, ctx.positions, cfg.rope_theta)
        new_cache = {"k": k.reshape(B, S, Kh * hd),
                     "v": v.reshape(B, S, Kh * hd)}    # prefill: raw kv
        ka, va = k, v
        if cfg.policy.gqa_expand_kv and Kh < cfg.n_heads:
            g = cfg.n_heads // Kh
            ka = lc(jnp.repeat(k, g, axis=2), "batch", None, "heads", None)
            va = lc(jnp.repeat(v, g, axis=2), "batch", None, "heads", None)
        impl = ("local" if (cfg.window is not None and cfg.attn_impl == "local")
                else cfg.attn_impl)
        out = attn.gqa_attention(q, ka, va, causal=True, window=cfg.window,
                                 impl=impl,
                                 hierarchy_levels=ctx.hierarchy_levels)
    out = jnp.einsum(
        "bsn,nd->bsd",
        out.reshape(B, out.shape[1], cfg.n_heads * cfg.resolved_head_dim),
        p["wo"])
    return out, new_cache


def cross_attention(cfg: ModelConfig, p, x, ctx: BlockCtx):
    """Cross-attn: q from x, kv from enc_out (precomputed in decode cache).

    Cache layout: xk/xv flattened (B, Se, Kh*hd) like the self-attn cache.
    """
    B, S, _ = x.shape
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dn->bsn", x, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    if ctx.mode == "decode" and ctx.cache is not None and "xk" in ctx.cache:
        Se = ctx.cache["xk"].shape[1]
        k = ctx.cache["xk"].reshape(B, Se, Kh, hd)
        v = ctx.cache["xv"].reshape(B, Se, Kh, hd)
    else:
        Se = ctx.enc_out.shape[1]
        k = jnp.einsum("bsd,dn->bsn", ctx.enc_out, p["wk"]).reshape(
            B, Se, Kh, hd)
        v = jnp.einsum("bsd,dn->bsn", ctx.enc_out, p["wv"]).reshape(
            B, Se, Kh, hd)
    out = attn.gqa_attention(q, k, v, causal=False, impl="chunked")
    out = jnp.einsum("bsn,nd->bsd",
                     out.reshape(B, S, cfg.n_heads * hd), p["wo"])
    return out, {"xk": k.reshape(B, Se, Kh * hd),
                 "xv": v.reshape(B, Se, Kh * hd)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_schema(cfg: ModelConfig) -> Schema:
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    return {
        "wdq": ((d, m.q_lora_rank), ("embed", None), "normal"),
        "q_norm/scale": ((m.q_lora_rank,), (None,), "zeros"),
        "wuq": ((m.q_lora_rank, H * (m.qk_nope_dim + m.qk_rope_dim)),
                (None, "heads"), "normal"),
        "wdkv": ((d, m.kv_lora_rank), ("embed", None), "normal"),
        "kv_norm/scale": ((m.kv_lora_rank,), (None,), "zeros"),
        "wkr": ((d, m.qk_rope_dim), ("embed", None), "normal"),
        "wuk": ((m.kv_lora_rank, H, m.qk_nope_dim), (None, "heads", None), "normal"),
        "wuv": ((m.kv_lora_rank, H, m.v_head_dim), (None, "heads", None), "normal"),
        "wo": ((H * m.v_head_dim, d), ("heads", "embed"), "normal"),
    }


def mla_attention(cfg: ModelConfig, p, x, ctx: BlockCtx):
    """Returns (out, cache {ckv:(B,Smax,r), kr:(B,Smax,rope)})."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]),
                  p["q_norm"]["scale"], cfg.norm_eps)
    q = jnp.einsum("bsr,rn->bsn", cq, p["wuq"]).reshape(
        B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q = lc(q, "batch", None, "heads", None)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = attn.apply_rope(q_rope, ctx.positions, cfg.rope_theta)
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"]),
                   p["kv_norm"]["scale"], cfg.norm_eps)
    kr = attn.apply_rope(
        jnp.einsum("bsd,dr->bsr", x, p["wkr"])[:, :, None, :],
        ctx.positions, cfg.rope_theta)[:, :, 0, :]

    if ctx.mode == "decode":
        cache = ctx.cache
        ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv,
                                                    ctx.cache_len, 1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr,
                                                   ctx.cache_len, 1)
        # absorbed decode: scores in the 512-d latent space, W_uk folded
        # into q.  The latent cache has no head dim, so its SEQUENCE dim is
        # sharded over the model axis (sequence-parallel decode): each shard
        # scores its cache slice; GSPMD reduces the softmax + context sums.
        ckv_c = lc(ckv_c, "batch", "seq_kv", None)
        kr_c = lc(kr_c, "batch", "seq_kv", None)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["wuk"],
                           preferred_element_type=jnp.float32)
        s = (jnp.einsum("bshr,bcr->bshc", q_lat.astype(x.dtype), ckv_c,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshr,bcr->bshc", q_rope, kr_c,
                          preferred_element_type=jnp.float32))
        s = s / jnp.sqrt(jnp.float32(m.qk_nope_dim + m.qk_rope_dim))
        pos = jnp.arange(ckv_c.shape[1])
        valid = pos[None] < (ctx.cache_len + 1)
        s = jnp.where(valid[:, None, None, :], s, attn.NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        ctx_lat = jnp.einsum("bshc,bcr->bshr", pr.astype(x.dtype), ckv_c,
                             preferred_element_type=jnp.float32)
        o = jnp.einsum("bshr,rhv->bshv", ctx_lat.astype(x.dtype), p["wuv"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        out = jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1), p["wo"])
        return out, {"ckv": ckv_c, "kr": kr_c}

    # train / prefill: materialise per-head k, v
    k_nope = jnp.einsum("bcr,rhn->bchn", ckv, p["wuk"])
    v = jnp.einsum("bcr,rhv->bchv", ckv, p["wuv"])
    v = lc(v, "batch", None, "heads", None)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                  (B, S, H, m.qk_rope_dim))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attn.gqa_attention(qf, k, v, causal=True, impl=cfg.attn_impl,
                             hierarchy_levels=ctx.hierarchy_levels)
    out = jnp.einsum("bsn,nd->bsd",
                     out.reshape(B, S, H * m.v_head_dim), p["wo"])
    return out, {"ckv": ckv, "kr": kr}


def _mla_decode_chunked(q_lat, q_rope, ckv_c, kr_c, cache_len, scale,
                        chunk: int = 4096):
    """Online-softmax over latent-cache chunks.  q_lat (B,H,r); q_rope
    (B,H,rope); ckv_c (B,Smax,r); kr_c (B,Smax,rope).  Returns (B,H,r) f32."""
    import math as _math
    B, H, r = q_lat.shape
    Smax = ckv_c.shape[1]
    chunk = _math.gcd(Smax, min(chunk, Smax))
    nc = Smax // chunk

    def score(cj, kj, kpos):
        s = (jnp.einsum("bhr,bcr->bhc", q_lat, cj,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhr,bcr->bhc", q_rope, kj,
                          preferred_element_type=jnp.float32)) * scale
        valid = kpos[None] < (cache_len + 1)
        return jnp.where(valid[:, None, :], s, attn.NEG_INF)

    def online(carry, scj):
        acc, mx, l = carry
        s, cj = scj
        m_new = jnp.maximum(mx, s.max(-1))
        pr = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mx - m_new)
        l = l * corr + pr.sum(-1)
        pv = jnp.einsum("bhc,bcr->bhr", pr.astype(cj.dtype), cj,
                        preferred_element_type=jnp.float32)
        return (acc * corr[..., None] + pv, m_new, l), None

    acc = jnp.zeros((B, H, r), jnp.float32)
    mx = jnp.full((B, H), attn.NEG_INF, jnp.float32)
    l = jnp.zeros((B, H), jnp.float32)
    if nc == 1:
        s = score(ckv_c, kr_c, jnp.arange(Smax))
        (acc, mx, l), _ = online((acc, mx, l), (s, ckv_c))
    else:
        cr = ckv_c.reshape(B, nc, chunk, r).transpose(1, 0, 2, 3)
        kr = kr_c.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)

        def body(carry, xs):
            cj, kj, j = xs
            s = score(cj, kj, j * chunk + jnp.arange(chunk))
            return online(carry, (s, cj))[0], None

        (acc, mx, l), _ = jax.lax.scan(body, (acc, mx, l),
                                       (cr, kr, jnp.arange(nc)))
    return acc / jnp.maximum(l[..., None], 1e-30)


# ---------------------------------------------------------------------------
# Block-level schema/apply
# ---------------------------------------------------------------------------

def _ffn_part_schema(cfg: ModelConfig, layer_idx: int) -> Schema:
    d = cfg.d_model
    if cfg.moe is not None:
        if layer_idx < cfg.moe.first_dense_layers:
            return prefix_schema("ffn", ffn_schema(d, cfg.moe.d_ff_dense))
        n_ep = 1
        return prefix_schema("moe", moe_schema(d, cfg.moe, _ep_count(cfg)))
    if cfg.d_ff:
        return prefix_schema("ffn", ffn_schema(d, cfg.d_ff))
    return {}


def _ep_count(cfg: ModelConfig) -> int:
    # padding target for routed experts (mesh-independent: the production
    # mesh has data=16, model=16 -> ep in {16, 256}; pad to lcm-friendly 16ths)
    n = 1
    for a in cfg.moe.ep_axes:
        n *= 16
    return n


def block_schema(cfg: ModelConfig, kind: str, layer_idx: int) -> Schema:
    d = cfg.d_model
    if kind in ("A", "E", "D"):
        mixer = (mla_schema(cfg) if cfg.mla is not None
                 else gqa_schema(cfg))
        s = merge_schemas(
            prefix_schema("norm_attn", norm_schema(d)),
            prefix_schema("attn", mixer),
        )
        if kind == "D":
            s = merge_schemas(
                s, prefix_schema("norm_cross", norm_schema(d)),
                prefix_schema("cross", gqa_schema(cfg, cross=True)))
        ffn = _ffn_part_schema(cfg, layer_idx)
        if ffn:
            s = merge_schemas(s, prefix_schema("norm_ffn", norm_schema(d)),
                              ffn)
        return s
    if kind == "R":
        s = merge_schemas(
            prefix_schema("norm_attn", norm_schema(d)),
            prefix_schema("rglru", rglru_schema(d, cfg.rnn_width or d)),
        )
        ffn = _ffn_part_schema(cfg, layer_idx)
        if ffn:
            s = merge_schemas(s, prefix_schema("norm_ffn", norm_schema(d)),
                              ffn)
        return s
    if kind == "m":
        return merge_schemas(prefix_schema("norm_attn", norm_schema(d)),
                             prefix_schema("mlstm", mlstm_schema(d, cfg.n_heads)))
    if kind == "s":
        return merge_schemas(prefix_schema("norm_attn", norm_schema(d)),
                             prefix_schema("slstm", slstm_schema(d, cfg.n_heads)))
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, smax: int,
                     enc_len: int = 0):
    """Zeroed cache slice for one layer (decode mode)."""
    hd = cfg.resolved_head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if kind in ("A", "E", "D"):
        nkv = cfg.n_kv_heads * hd
        if cfg.mla is not None:
            c = {"ckv": jnp.zeros((batch, smax, cfg.mla.kv_lora_rank), dt),
                 "kr": jnp.zeros((batch, smax, cfg.mla.qk_rope_dim), dt)}
        elif cfg.window is not None:
            c = {"k": jnp.zeros((batch, cfg.window, nkv), dt),
                 "v": jnp.zeros((batch, cfg.window, nkv), dt),
                 "pos": jnp.full((cfg.window,), -1, jnp.int32)}
        else:
            c = {"k": jnp.zeros((batch, smax, nkv), dt),
                 "v": jnp.zeros((batch, smax, nkv), dt)}
        if kind == "D":
            c["xk"] = jnp.zeros((batch, enc_len, nkv), dt)
            c["xv"] = jnp.zeros((batch, enc_len, nkv), dt)
        return c
    if kind == "R":
        return rglru_init_state(batch, cfg.rnn_width or cfg.d_model)
    if kind == "m":
        dm = 2 * cfg.d_model
        return mlstm_init_state(batch, cfg.n_heads, dm // cfg.n_heads)
    if kind == "s":
        return slstm_init_state(batch, cfg.d_model)
    raise ValueError(kind)


def block_cache_axes(cfg: ModelConfig, kind: str):
    """Logical-axes tree mirroring ``init_block_cache``."""
    if kind in ("A", "E", "D"):
        if cfg.mla is not None:
            c = {"ckv": ("batch", "seq_kv", None),
                 "kr": ("batch", "seq_kv", None)}
        elif cfg.window is not None:
            c = {"k": ("batch", None, "kv_heads"),
                 "v": ("batch", None, "kv_heads"),
                 "pos": (None,)}
        else:
            c = {"k": ("batch", None, "kv_heads"),
                 "v": ("batch", None, "kv_heads")}
        if kind == "D":
            c["xk"] = ("batch", None, "kv_heads")
            c["xv"] = ("batch", None, "kv_heads")
        return c
    if kind == "R":
        return {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
    if kind == "m":
        # mLSTM has too few heads for a 16-way axis; shard the value dim
        return (("batch", None, None, "rnn"), ("batch", None, "rnn"),
                ("batch", None))
    if kind == "s":
        return {"c": ("batch", "rnn"), "n": ("batch", "rnn"),
                "h": ("batch", "rnn"), "m": ("batch", "rnn")}
    raise ValueError(kind)


def apply_block(cfg: ModelConfig, kind: str, layer_idx: int, p, x,
                ctx: BlockCtx):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm_attn"]["scale"], cfg.norm_eps)
    if kind in ("A", "E", "D"):
        if cfg.mla is not None:
            out, cache = mla_attention(cfg, p["attn"], h, ctx)
        else:
            if kind == "E":
                q, k, v = _qkv(cfg, p["attn"], h)
                q = attn.apply_rope(q, ctx.positions, cfg.rope_theta)
                k = attn.apply_rope(k, ctx.positions, cfg.rope_theta)
                o = attn.gqa_attention(q, k, v, causal=False, impl="chunked")
                out = jnp.einsum("bsn,nd->bsd",
                                 o.reshape(h.shape[0], h.shape[1], -1),
                                 p["attn"]["wo"])
                cache = None
            else:
                out, cache = gqa_self_attention(cfg, p["attn"], h, ctx)
        x = x + lc(out, "batch", "seq_sp", None)
        new_cache = cache
        if kind == "D":
            h2 = rms_norm(x, p["norm_cross"]["scale"], cfg.norm_eps)
            out2, xc = cross_attention(cfg, p["cross"], h2, ctx)
            x = x + out2
            if new_cache is not None and xc is not None:
                new_cache = {**new_cache, **xc}
    elif kind == "R":
        out, new_cache = rglru_apply(
            p["rglru"], h, None if ctx.mode == "train" and ctx.cache is None
            else ctx.cache)
        x = x + lc(out, "batch", "seq_sp", None)
    elif kind == "m":
        out, new_cache = mlstm_apply(p["mlstm"], h, cfg.n_heads,
                                     None if ctx.cache is None else ctx.cache)
        return x + out, new_cache, aux
    elif kind == "s":
        out, new_cache = slstm_apply(p["slstm"], h, cfg.n_heads,
                                     None if ctx.cache is None else ctx.cache)
        return x + out, new_cache, aux
    else:
        raise ValueError(kind)

    # FFN / MoE sublayer — purely per-token: stays in SP (sequence-sharded)
    # layout; only attention ever gathers the sequence dim
    if "norm_ffn" in p:
        x = lc(x, "batch", "seq_sp", None)
        h = lc(rms_norm(x, p["norm_ffn"]["scale"], cfg.norm_eps),
               "batch", "seq_sp", None)
        if "moe" in p:
            out, aux = moe_apply(p["moe"], h, cfg.moe)
        else:
            out = ffn_apply(p["ffn"], h)
        x = x + lc(out, "batch", "seq_sp", None)
    return x, new_cache, aux
