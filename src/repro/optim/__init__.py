"""Optimizers: AdamW and Adafactor (factored second moment, for 100B+ models),
global-norm clipping, WSD schedule, and int8 gradient compression with error
feedback (optional distributed-optimization trick).

Functional optax-like API:
    opt = adamw(lr=...) | adafactor(lr=...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer state mirrors param sharding: ``opt_state_axes`` maps a param
logical-axes tree onto the state tree so the dry-run can shard it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    state_axes: Callable[[Any], Any]   # param_axes tree -> state axes tree


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def wsd_schedule(peak_lr: float, warmup: int = 100, decay_start: int = 10**9,
                 decay_steps: int = 1):
    """Warmup-stable-decay schedule."""
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / warmup)
        decay = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        return warm * (1.0 - 0.9 * decay)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
          max_grad_norm=1.0):
    lr_fn = lr if callable(lr) else (lambda _s: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        tf = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** tf
        bc2 = 1.0 - b2 ** tf
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_ = b1 * m + (1 - b1) * gf
            v_ = b2 * v + (1 - b2) * gf * gf
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return -lr_t * u, m_, v_

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamWState(step, m, v)

    def state_axes(param_axes, _params=None):
        return AdamWState((), param_axes, param_axes)

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
# Adafactor (beta1=0, factored second moments)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any      # row statistics   (shape[:-1])
    vc: Any      # col statistics   (shape[:-2] + shape[-1:])
    v: Any       # unfactored for <2D params


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor(lr=1e-2, eps=1e-30, clip_threshold=1.0, min_dim=128,
              max_grad_norm=1.0, blockwise=False):
    # blockwise: scan the update over layer-stacked leaves.  Measured on the
    # deepseek train cell: the loop's input copies cost MORE than the fp32
    # temps saved (54.7 -> 65.2 GiB) — kept as an option, off by default.
    lr_fn = lr if callable(lr) else (lambda _s: lr)

    def init(params):
        def vr(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros((1,), jnp.float32))
        def vc(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((1,), jnp.float32))
        def v(p):
            return (jnp.zeros((1,), jnp.float32) if _factored(p)
                    else jnp.zeros(p.shape, jnp.float32))
        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(vr, params),
                              jax.tree.map(vc, params),
                              jax.tree.map(v, params))

    def update(grads, state, params):
        grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        tf = step.astype(jnp.float32)
        rho = 1.0 - tf ** -0.8
        lr_t = lr_fn(step)

        def upd_flat(g, vr, vc, v, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                vr_ = rho * vr + (1 - rho) * g2.mean(axis=-1)
                vc_ = rho * vc + (1 - rho) * g2.mean(axis=-2)
                r = vr_ / jnp.maximum(
                    vr_.mean(axis=-1, keepdims=True), 1e-30)
                u = gf * jax.lax.rsqrt(r)[..., None] * jax.lax.rsqrt(
                    jnp.maximum(vc_, 1e-30))[..., None, :]
                v_ = v
            else:
                v_ = rho * v + (1 - rho) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v_, 1e-30))
                vr_, vc_ = vr, vc
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            scale = jnp.maximum(
                jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2)), 0.01)
            return -lr_t * scale * u, vr_, vc_, v_

        def upd(g, vr, vc, v, p):
            # blockwise update for layer-stacked leaves: a (58, 7168, 2048)
            # expert stack otherwise holds several multi-GiB fp32 temps at
            # once — lax.map bounds the update working set to one slice
            if blockwise and _factored(p) and p.ndim >= 3 and p.shape[0] >= 8:
                def one(args):
                    gi, vri, vci, pi = args
                    du, vr_, vc_, _ = upd_flat(gi, vri, vci,
                                               jnp.zeros((1,), jnp.float32),
                                               pi)
                    return du, vr_, vc_
                du, vr_, vc_ = jax.lax.map(one, (g, vr, vc, p))
                return du, vr_, vc_, v
            return upd_flat(g, vr, vc, v, p)

        out = jax.tree.map(upd, grads, state.vr, state.vc, state.v, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), AdafactorState(step, pick(1), pick(2), pick(3))

    def state_axes(param_axes, params):
        isl = lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x)
        vr = jax.tree.map(
            lambda a, p: (tuple(a[:-1]) or (None,)) if _factored(p)
            else (None,), param_axes, params, is_leaf=isl)
        vc = jax.tree.map(
            lambda a, p: (tuple(a[:-2]) + (a[-1],)) if _factored(p)
            else (None,), param_axes, params, is_leaf=isl)
        v = jax.tree.map(
            lambda a, p: (None,) if _factored(p) else tuple(a),
            param_axes, params, is_leaf=isl)
        return AdafactorState((), vr, vc, v)

    return Optimizer(init, update, state_axes)


def make_optimizer(name: str, lr=None) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr if lr is not None else wsd_schedule(3e-4))
    if name == "adafactor":
        return adafactor(lr=lr if lr is not None else wsd_schedule(1e-2))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback (optional)
# ---------------------------------------------------------------------------

def compress_int8(g, err):
    """Quantize g+err to int8 per-tensor; returns (q, scale, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale
