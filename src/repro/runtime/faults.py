"""Deterministic fault injection for the serving runtime.

A real embedded FPGA-GPU deployment sees transient device faults as the
norm, not the exception — but CI has neither device.  This module makes
every failure mode of the serving stack *testable* by injecting faults at
the host-side dispatch points the compiled engines and ``HeteroServer``
already go through:

  * ``op="dispatch"``  — an engine ``__call__`` (monolithic or pipelined);
                         the site reports the devices its plan touches, so
                         a rule pinned to ``device="fpga"`` fires on the
                         hybrid plan but never on the GPU-only fallback.
  * ``op="stage"``     — one ``PipelinedEngine`` stage dispatch; the site
                         reports the stage index and its device tag, so
                         "fail stage k of batch n" is expressible exactly.
  * ``op="prepare"``   — ``engine.prepare`` (weight quantization /
                         calibration).
  * ``op="refresh"``   — a server-side stale-engine recompile.

Process-level trigger points (``repro.frontend``) sit ABOVE the engines,
at the serving process boundary, so router/front-door failure handling is
just as CI-testable as the in-process paths:

  * ``op="http"``      — the front door's request handler, after decode
                         and before ``submit`` (a fired rule surfaces as
                         a typed 500 wire response, never a hung socket).
  * ``op="worker"``    — the router's per-worker forward; the site
                         reports the target worker's name as ``device``,
                         so "fail every dispatch to worker w1" is
                         expressible exactly (a fired rule looks like a
                         transport failure: the retry/ejection path runs).
  * ``op="conn"``      — the front door's keep-alive connection loop,
                         once per parsed request head; a fired rule is
                         answered as a typed 500 while the SOCKET
                         SURVIVES — the test hook for "one request on a
                         persistent connection failed, the rest keep
                         flowing".

Faults are **deterministic**: a rule fires on an explicit trigger window
(``after`` skips the first N matching events, ``times`` bounds how many
fire) or on a seeded Bernoulli draw (``p``), never on wall-clock state.
The same plan against the same call sequence always injects the same
faults — which is what lets the failover/retry/shed paths run in CI
without real hardware.

    plan = FaultPlan([FaultRule(op="dispatch", device="fpga", times=3)])
    with inject(plan):
        ...                      # first 3 hybrid dispatches raise
    plan.fired                   # -> list of FaultEvent records

``kind="delay"`` injects latency (``delay_s`` of host-side sleep at the
dispatch point) instead of raising — the straggler/overload knob.
Raised faults are ``InjectedFault`` instances carrying the attributed
``device``/``stage``/``op`` so the serving layer's circuit breaker can
tell an FPGA-path failure from a GPU one.
"""
from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import NamedTuple


class InjectedFault(RuntimeError):
    """A deliberately injected failure.  ``device`` is the attributed
    device path ("fpga"/"gpu"/None), ``stage`` the pipelined stage index
    (None outside stage dispatch), ``op`` the injection point."""

    def __init__(self, msg: str, *, op: str, device: str | None = None,
                 stage: int | None = None):
        super().__init__(msg)
        self.op = op
        self.device = device
        self.stage = stage


class FaultEvent(NamedTuple):
    """One injected fault (or delay), as recorded on the plan."""
    op: str
    device: str | None
    stage: int | None
    kind: str
    hit: int                   # 1-based index among the rule's matches


def fault_device(exc: BaseException) -> str | None:
    """The device a failure is attributed to, if any.  ``InjectedFault``
    carries it directly; real exceptions raised inside a pipelined stage
    are tagged by the engine's dispatch wrapper."""
    dev = getattr(exc, "device", None)
    return dev if isinstance(dev, str) else None


@dataclass
class FaultRule:
    """One injection rule.  Matching is by site predicates (``op``, and —
    where the site reports them — ``stage`` and ``device``); firing is by
    a deterministic window over the rule's *matching* events (``after`` /
    ``times``) or a seeded Bernoulli draw (``p``).  For sites that report
    no device of their own (``prepare``/``refresh``), ``device`` is pure
    attribution: it labels the raised fault without restricting the match.
    """
    op: str = "dispatch"            # dispatch | stage | prepare | refresh
    kind: str = "fail"              # fail | delay
    device: str | None = None       # site matcher + attribution label
    stage: int | None = None        # pipelined stage index matcher
    after: int = 0                  # skip the first `after` matching events
    times: int | None = 1           # fire this many times (None = forever)
    p: float | None = None          # seeded Bernoulli instead of a window
    delay_s: float = 0.05           # kind="delay": injected latency
    hits: int = 0                   # matching events seen (runtime state)
    fired: int = 0                  # faults actually injected

    def matches(self, op: str, device, stage: int | None) -> bool:
        if op != self.op:
            return False
        if self.stage is not None and stage != self.stage:
            return False
        if self.device is not None and device is not None:
            site = device if isinstance(device, (tuple, list, set)) \
                else (device,)
            if self.device not in site:
                return False
        return True


class FaultPlan:
    """A set of rules plus the deterministic state that drives them.
    Thread-safe: serving dispatch runs across drain/completion threads.
    ``fired`` records every injected event for test assertions."""

    def __init__(self, rules: list[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.fired: list[FaultEvent] = []
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def check(self, op: str, device=None, stage: int | None = None) -> None:
        """Evaluate every rule against one dispatch site.  Delay rules
        sleep; fail rules raise ``InjectedFault`` (first firing rule
        wins).  Called from the engines via ``trip``."""
        delay = 0.0
        boom: InjectedFault | None = None
        with self._lock:
            for r in self.rules:
                if not r.matches(op, device, stage):
                    continue
                r.hits += 1
                if r.p is not None:
                    fire = self._rng.random() < r.p
                else:
                    fire = (r.hits > r.after
                            and (r.times is None
                                 or r.fired < r.times))
                if not fire:
                    continue
                r.fired += 1
                dev = r.device if r.device is not None else (
                    device if isinstance(device, str) else None)
                self.fired.append(FaultEvent(op, dev, stage, r.kind,
                                             r.hits))
                if r.kind == "delay":
                    delay = max(delay, r.delay_s)
                elif boom is None:
                    boom = InjectedFault(
                        f"injected {op} fault "
                        f"(device={dev}, stage={stage}, hit={r.hits})",
                        op=op, device=dev, stage=stage)
        if delay > 0.0:
            time.sleep(delay)
        if boom is not None:
            raise boom


# -- global injection point ---------------------------------------------------
# One process-wide active plan: the compiled engines are cached and shared
# across servers/threads, so the injection point must be too.  ``trip`` is
# a single attribute read when no plan is installed — the production hot
# path pays one ``is None`` check per dispatch.

_ACTIVE: FaultPlan | None = None
_ACTIVE_LOCK = threading.Lock()


def install(plan: FaultPlan | None) -> None:
    global _ACTIVE
    with _ACTIVE_LOCK:
        _ACTIVE = plan


def active() -> FaultPlan | None:
    return _ACTIVE


@contextmanager
def inject(plan: FaultPlan):
    """Scope a fault plan: install on entry, uninstall on exit.  Keep
    oracle/reference engine calls OUTSIDE the scope — the injection point
    is process-global, exactly like the engine cache."""
    install(plan)
    try:
        yield plan
    finally:
        install(None)


def trip(op: str, device=None, stage: int | None = None) -> None:
    """Fault-injection hook: no-op unless a plan is installed."""
    plan = _ACTIVE
    if plan is not None:
        plan.check(op, device=device, stage=stage)
