"""Runtime resilience: straggler watchdog, fault-tolerant loop, elastic
resharding.  On a real multi-pod deployment the same loop runs per process;
here the failure paths are exercised by tests via simulated crashes.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint import CheckpointManager


@dataclass
class StragglerMonitor:
    """Flags steps slower than ``threshold`` x rolling median.

    At DC scale the flag feeds the scheduler (issue backup step on a spare
    slice / evict the slow host); the serving completion loop uses
    ``budget()`` the same way — a dispatch lagging the budget triggers a
    watchdog event and, for pipelined entries, a backup monolithic
    dispatch (``repro.serving.server``).  ``times`` is trimmed to the
    rolling window so a long-lived server never grows it without bound;
    ``flagged`` keeps at most ``window`` recent events for the same
    reason (the aggregate count lives in ``ServerMetrics``).
    """
    threshold: float = 2.0
    window: int = 50
    min_samples: int = 5
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        if len(self.times) > self.window:
            del self.times[:-self.window]
        if len(self.times) >= self.min_samples:
            med = statistics.median(self.times)
            if seconds > self.threshold * med:
                self.flagged.append((step, seconds, med))
                if len(self.flagged) > self.window:
                    del self.flagged[:-self.window]
                return True
        return False

    def median(self) -> float | None:
        """Rolling-median step time; None until ``min_samples`` samples
        have been recorded (no budget before there is a baseline)."""
        if len(self.times) < self.min_samples:
            return None
        return statistics.median(self.times)

    def budget(self) -> float | None:
        """Straggler budget: ``threshold`` x the rolling median — the
        wait beyond which a completion counts as lagging."""
        med = self.median()
        return None if med is None else self.threshold * med


class FaultTolerantLoop:
    """Checkpoint-every-k training loop with resume-from-latest.

    ``run`` executes steps [resume_step, total); a crash (simulated via
    ``crash_at``) raises after the checkpoint logic of that step, so a
    relaunch resumes exactly where a real preemption would.
    """

    def __init__(self, step_fn, ckpt: CheckpointManager, save_every: int = 10,
                 monitor: StragglerMonitor | None = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.save_every = save_every
        self.monitor = monitor or StragglerMonitor()

    def run(self, state, batches, total: int, crash_at: int | None = None,
            shardings=None):
        start = 0
        if self.ckpt.latest_step() is not None:
            state, start = self.ckpt.restore(None, state, shardings)
            start += 1
        metrics = None
        for step in range(start, total):
            t0 = time.monotonic()
            state, metrics = self.step_fn(state, batches(step))
            jax.block_until_ready(metrics)
            self.monitor.record(step, time.monotonic() - t0)
            if step % self.save_every == 0 or step == total - 1:
                self.ckpt.save(step, state)
            if crash_at is not None and step == crash_at:
                self.ckpt.wait()
                raise RuntimeError(f"simulated preemption at step {step}")
        self.ckpt.wait()
        return state, metrics


def reshard(state, shardings):
    """Elastic re-admission: place a restored state onto a new mesh."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
