"""Runtime robustness: deterministic fault injection (``faults``) and the
straggler/checkpoint resilience loop (``resilience``).  Submodules are
imported directly (``from repro.runtime import faults``) — this package
init stays import-light so the serving hot path never pays for the
checkpoint/training machinery.
"""
