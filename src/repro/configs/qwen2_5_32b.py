"""Qwen2.5-32B [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-*]"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    policy=ShardingPolicy(fsdp=True, seq_parallel=True, remat="block"),
    optimizer="adamw",
))
