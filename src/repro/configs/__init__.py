from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS, SHAPES, CNNConfig, MLAConfig, MoEConfig, ModelConfig,
    ShapeSpec, ShardingPolicy, cell_applicable, get_cnn_config, get_config,
    list_archs, list_cnns, reduced, register, register_cnn,
)
