"""Mistral-Large-123B [dense] — GQA kv=8. [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    policy=ShardingPolicy(fsdp=True, seq_parallel=True, remat="block"),
    # Adafactor (factored second moment, no first moment) — AdamW state for
    # 123B does not fit 256 x 16 GiB alongside activations.
    optimizer="adafactor",
))
