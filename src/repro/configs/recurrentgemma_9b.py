"""RecurrentGemma-9B [hybrid] — RG-LRU + local attention, pattern (R,R,A).

[arXiv:2402.19427] Griffin architecture: 2 recurrent blocks per 1 local
(sliding-window 2048) MQA attention block.  Sub-quadratic: long_500k runs.
"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,          # MQA
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    block_pattern=("R", "R", "A"),
    rnn_width=4096,
    window=2048,
    attn_impl="local",
    rope_theta=10000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
    policy=ShardingPolicy(fsdp=True, seq_parallel=True, remat="block"),
    optimizer="adamw",
))
