"""InternVL2-1B [vlm] — InternViT frontend (STUB) + Qwen2-0.5B backbone.

[arXiv:2404.16821] Per the assignment spec the modality frontend is a stub:
``input_specs()`` provides precomputed patch embeddings (B, 256, d_model)
which the backbone consumes prepended to the token embeddings.
"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    tie_embeddings=True,
    vlm_patches=256,
    policy=ShardingPolicy(fsdp=False, seq_parallel=True, remat="block"),
    optimizer="adamw",
))
