"""xLSTM-125M [ssm] — sLSTM + mLSTM blocks, ratio ~7:1. [arXiv:2405.04517]

12 blocks: mLSTM everywhere, sLSTM at every 8th position (index 7) — the
xLSTM[7:1] ratio of the paper's 125M config.  Attention-free: long_500k runs.
"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                # xLSTM blocks embed their own up/down projections
    vocab=50304,
    block_pattern=("m", "m", "m", "m", "m", "m", "m", "s"),
    norm_eps=1e-6,
    tie_embeddings=True,
    # 125M params: pure data parallelism over all 256/512 chips (heads=4
    # cannot use a 16-way tensor axis) — "model" folds into the batch axes.
    policy=ShardingPolicy(fsdp=False, seq_parallel=False, remat="block",
                          batch_axes=("pod", "data", "model")),
    optimizer="adamw",
))
