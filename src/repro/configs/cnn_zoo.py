"""The paper's CNN workloads: SqueezeNet, MobileNetV2 (0.5x), ShuffleNetV2 (0.5x)."""
from repro.configs.base import CNNConfig, register_cnn

SQUEEZENET = register_cnn(CNNConfig(name="squeezenet", width_mult=1.0))
MOBILENETV2 = register_cnn(CNNConfig(name="mobilenetv2", width_mult=0.5))
SHUFFLENETV2 = register_cnn(CNNConfig(name="shufflenetv2", width_mult=0.5))
