"""DeepSeek-V3-671B [moe] — MLA + 1 shared + 256 routed top-8. [arXiv:2412.19437]

Deviations from the released model, recorded per DESIGN.md:
 - plain top-8 routing (no node-limited group routing), sigmoid gate kept;
 - MTP head omitted (single-token LM head);
 - first 3 layers dense FFN (d_ff 18432) as in the paper.
Expert parallelism spans the flattened (data, model) product = 256 groups
(1 expert per device on the single-pod mesh), replicated over pods.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,        # MLA: per-head latent-expanded KV
    d_ff=18432,            # dense-layer FFN width
    vocab=129280,
    rope_theta=10000.0,
    norm_eps=1e-6,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_routed=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared=1,
        d_ff_shared=2048,
        first_dense_layers=3,
        d_ff_dense=18432,
        capacity_factor=1.25,
        ep_axes=("data", "model"),
        dispatch="ep",
    ),
    policy=ShardingPolicy(fsdp=True, seq_parallel=True, remat="block"),
    optimizer="adafactor",
))
