"""StarCoder2-3B [dense] — GQA kv=2, RoPE, bias. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    qkv_bias=True,
    rope_theta=100_000.0,
    norm_eps=1e-5,
    # Treated as full attention per the assignment line (GQA, RoPE);
    # long_500k is therefore skipped (see DESIGN.md).
    policy=ShardingPolicy(fsdp=False, seq_parallel=True, remat="block"),
    optimizer="adamw",
))
