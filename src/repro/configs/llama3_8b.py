"""Llama-3-8B [dense] — GQA kv=8, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=500_000.0,
    norm_eps=1e-5,
    policy=ShardingPolicy(fsdp=True, seq_parallel=True, remat="block"),
    optimizer="adamw",
))
