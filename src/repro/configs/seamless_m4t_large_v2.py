"""SeamlessM4T-Large-v2 [audio] — enc-dec backbone. [arXiv:2308.11596]

The speech/text modality frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, enc_len, d).
enc_len = seq_len // 4 (conformer downsampling stand-in).  n_layers is the
decoder depth; the encoder has 24 layers as well.
"""
from repro.configs.base import ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    enc_dec=True,
    n_enc_layers=24,
    enc_ratio=4,
    rope_theta=10000.0,
    norm_eps=1e-5,
    policy=ShardingPolicy(fsdp=False, seq_parallel=True, remat="block"),
    optimizer="adamw",
))
