"""Config system: model configs, input shapes, sharding policies, registry.

Every assigned architecture is a ``ModelConfig`` registered under its id and
selectable via ``--arch <id>`` in the launchers.  The paper's own CNN
workloads (SqueezeNet / MobileNetV2 / ShuffleNetV2) are ``CNNConfig``s used by
the heterogeneous-partitioning reproduction path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    first_dense_layers: int = 0       # leading layers use a dense FFN
    d_ff_dense: int = 0               # width of those dense FFNs
    capacity_factor: float = 2.0
    router_aux_coef: float = 0.001
    # expert-parallel axes ("model",) or ("data", "model"); dispatch strategy
    ep_axes: tuple[str, ...] = ("model",)
    dispatch: str = "ep"              # "ep" (shard_map all_to_all) | "dense"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class ShardingPolicy:
    """How a config maps onto the (pod, data, model) mesh."""
    fsdp: bool = False                # shard weights over the data axis too
    seq_parallel: bool = True         # residual stream sharded over data x model
    remat: str = "block"              # "none" | "block" — per-layer rematerialisation
    shard_vocab: bool = True
    kv_replicated: bool = False       # replicate KV heads instead of (padded) sharding
    # mesh axes carrying the batch dim; tiny models fold "model" in (pure DP)
    batch_axes: tuple[str, ...] = ("pod", "data")
    # train/prefill: expand GQA KV to full head count before attention so the
    # head dim shards evenly over the model axis (kills the padded-Kh
    # reshard/replicate churn inside chunked attention; KV mem is tiny there).
    # Default ON after §Perf cell 1 (llama3-8b train: 6.7x collective cut).
    gqa_expand_kv: bool = True


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_impl: str = "chunked"         # "chunked" | "full" | "local"
    window: Optional[int] = None       # sliding-window size for local attention
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # Hybrid / SSM block pattern, tiled over layers, e.g. ("R","R","A") for
    # recurrentgemma, ("m",)*7+("s",) for xlstm.  None -> all attention.
    block_pattern: Optional[tuple[str, ...]] = None
    rnn_width: int = 0                 # RG-LRU recurrent width (recurrentgemma)
    # Encoder-decoder (seamless-m4t): n_layers is the DECODER depth.
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_ratio: int = 4                 # enc_len = seq_len // enc_ratio
    # VLM: number of prepended image-patch embeddings (stub frontend).
    vlm_patches: int = 0
    policy: ShardingPolicy = field(default_factory=ShardingPolicy)
    optimizer: str = "adamw"           # "adamw" | "adafactor"
    dtype: str = "bfloat16"
    # attention logits soft cap (gemma-style), 0 = off
    attn_logit_softcap: float = 0.0
    # embedding rows padded to a multiple of this so the vocab dim shards
    # evenly over a 16-way model axis (padded logits masked to -inf)
    vocab_pad_to: int = 128

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return -(-self.vocab // p) * p

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def pattern_at(self, i: int) -> str:
        if self.block_pattern is None:
            return "A"
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_kinds(self) -> list[str]:
        return [self.pattern_at(i) for i in range(self.n_layers)]

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run 500k-token decode (no full attention)."""
        if self.block_pattern is None and self.window is None:
            return False
        kinds = set(self.layer_kinds())
        if "A" in kinds and self.window is None:
            return False
        return True

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.pattern_at(i)
            if kind in ("A",):
                if self.mla is not None:
                    m = self.mla
                    total += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    total += d * (m.kv_lora_rank + m.qk_rope_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            elif kind == "R":      # RG-LRU block (qkv-free)
                w = self.rnn_width or d
                total += 2 * d * w + w * d + 3 * w  # in-proj x2, out-proj, gates
            elif kind in ("m", "s"):   # xLSTM blocks
                total += 8 * d * d    # rough: proj up/down + gates
            # FFN
            if self.moe is not None and kind != "s":
                if i < self.moe.first_dense_layers:
                    total += 3 * d * self.moe.d_ff_dense
                else:
                    total += self.moe.n_routed * 3 * d * self.moe.d_ff_expert
                    total += self.moe.n_shared * 3 * d * self.moe.d_ff_shared
                    total += d * self.moe.n_routed
            elif self.d_ff:
                total += 3 * d * self.d_ff
        if self.enc_dec:
            # encoder blocks + decoder cross-attention
            total += self.n_enc_layers * (4 * d * self.n_heads * hd + 3 * d * self.d_ff)
            total += self.n_layers * 4 * d * self.n_heads * hd
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        total = self.n_params()
        moe_layers = self.n_layers - m.first_dense_layers
        total -= moe_layers * m.n_routed * 3 * self.d_model * m.d_ff_expert
        total += moe_layers * m.top_k * 3 * self.d_model * m.d_ff_expert
        return int(total)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch is paired with these four shapes.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch x shape) is a runnable dry-run cell, else reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skipped(full-attention: quadratic at 524288)"
    return True, ""


# ---------------------------------------------------------------------------
# CNN configs (paper workloads)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CNNConfig:
    name: str
    width_mult: float = 1.0
    num_classes: int = 1000
    image_size: int = 224


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}
_CNN_REGISTRY: dict[str, CNNConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def register_cnn(cfg: CNNConfig) -> CNNConfig:
    _CNN_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def get_cnn_config(name: str) -> CNNConfig:
    _ensure_loaded()
    return _CNN_REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def list_cnns() -> list[str]:
    _ensure_loaded()
    return sorted(_CNN_REGISTRY)


def reduced(cfg: ModelConfig, **over) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 2 * (len(cfg.block_pattern) if cfg.block_pattern else 1)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        head_dim=32 if cfg.head_dim else 0,
        rnn_width=160 if cfg.rnn_width else 0,
        window=min(cfg.window, 64) if cfg.window else None,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_routed=8, top_k=2, d_ff_expert=64,
            d_ff_shared=128 if cfg.moe.n_shared else 0,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            d_ff_dense=256 if cfg.moe.first_dense_layers else 0,
            dispatch="dense")
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_dim=16, qk_rope_dim=16, v_head_dim=32)
        kw["head_dim"] = 0
    if cfg.enc_dec:
        kw["n_enc_layers"] = 2
    if cfg.vlm_patches:
        kw["vlm_patches"] = 8
    kw["policy"] = ShardingPolicy(fsdp=False, seq_parallel=False, remat="none")
    kw.update(over)
    return dataclasses.replace(cfg, **kw)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        qwen2_5_32b, mistral_large_123b, starcoder2_3b, llama3_8b,
        recurrentgemma_9b, internvl2_1b, deepseek_v3_671b, qwen2_moe_a2_7b,
        xlstm_125m, seamless_m4t_large_v2, cnn_zoo,
    )


ASSIGNED_ARCHS = [
    "qwen2.5-32b", "mistral-large-123b", "starcoder2-3b", "llama3-8b",
    "recurrentgemma-9b", "internvl2-1b", "deepseek-v3-671b",
    "qwen2-moe-a2.7b", "xlstm-125m", "seamless-m4t-large-v2",
]
