"""Qwen2-MoE-A2.7B [moe] — 4 shared + 60 routed top-4. [hf:Qwen/Qwen1.5-MoE-A2.7B]

60 routed experts are padded to 64 for expert parallelism over the model
axis (16 groups x 4 experts); the 4 pad experts are never routed to.
"""
from repro.configs.base import MoEConfig, ModelConfig, ShardingPolicy, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    moe=MoEConfig(
        n_routed=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared=4,
        d_ff_shared=1408,
        capacity_factor=2.0,
        ep_axes=("model",),
        dispatch="ep",
    ),
    policy=ShardingPolicy(fsdp=True, seq_parallel=True, remat="block"),
    optimizer="adamw",
))
