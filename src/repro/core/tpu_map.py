"""The paper's partitioner applied to the TPU's two substrates.

On the embedded board the choice per module is FPGA-DHM vs GPU; on a TPU
chip the same decision structure chooses between:

  generic  — each op jit'd separately: every intermediate feature map
             makes an HBM round trip;
  fused    — the VMEM-resident Pallas kernel (repro/kernels/fused_block):
             weights + intermediates stay on-chip, exactly DHM's memory
             insight, subject to a VMEM resource budget instead of LEs.

Costs come from the TPUv5e roofline model; the same admissibility /
argmin-selection code shape as `repro.core.partitioner`.  Executed by
`benchmarks.run tpu_map` and tested in tests/test_tpu_map.py.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import costmodel as cm
from repro.core.costmodel import ConvSpec, TPUv5e
from repro.core.graph import ModuleGraph

ACT_BYTES = 2      # bf16 feature maps


@dataclass(frozen=True)
class TpuPlan:
    module: str
    substrate: str          # "generic" | "fused"
    t_generic: float
    t_fused: float
    vmem_bytes: int

    @property
    def speedup(self) -> float:
        return self.t_generic / max(min(self.t_fused, self.t_generic), 1e-12)


def _op_time(tpu: TPUv5e, spec: ConvSpec, batch: int,
             read_in: bool, write_out: bool) -> float:
    flops = spec.flops * batch
    bytes_ = spec.n_weights * ACT_BYTES
    if read_in:
        bytes_ += spec.in_bytes(ACT_BYTES) * batch
    if write_out:
        bytes_ += spec.out_bytes(ACT_BYTES) * batch
    return max(flops / tpu.peak_flops, bytes_ / tpu.mem_bw)


def vmem_usage(m: ModuleGraph) -> int:
    """Weights + (k-1)-line buffers that must be VMEM-resident when fused."""
    convs = [n.spec for n in m.nodes
             if n.spec.kind in ("conv", "dwconv", "pwconv")]
    return sum((s.n_weights + (s.k - 1) * s.w * s.c_in) * ACT_BYTES
               for s in convs)


def plan_module(m: ModuleGraph, batch: int = 8,
                tpu: TPUv5e | None = None) -> TpuPlan:
    tpu = tpu or cm.TPU
    convs = [n for n in m.nodes
             if n.spec.kind in ("conv", "dwconv", "pwconv")]
    if not convs:
        return TpuPlan(m.name, "generic", 1e-9, 1e-9, 0)
    # generic: every op pays the intermediate HBM round trip
    t_gen = sum(_op_time(tpu, n.spec, batch, True, True) for n in convs)
    # fused: only module input read + output write cross HBM
    flops = sum(n.spec.flops for n in convs) * batch
    io = (convs[0].spec.in_bytes(ACT_BYTES)
          + convs[-1].spec.out_bytes(ACT_BYTES)) * batch
    w = sum(n.spec.n_weights for n in convs) * ACT_BYTES
    t_fus = max(flops / tpu.peak_flops, (io + w) / tpu.mem_bw)
    vm = vmem_usage(m)
    feasible = vm <= tpu.vmem_bytes // 2        # leave half for activations
    sub = "fused" if (feasible and t_fus < t_gen) else "generic"
    return TpuPlan(m.name, sub, t_gen, t_fus if feasible else t_gen, vm)


def plan_network(mods: list[ModuleGraph], batch: int = 8) -> list[TpuPlan]:
    return [plan_module(m, batch) for m in mods]


def summarize(plans: list[TpuPlan]) -> dict:
    t_gen = sum(p.t_generic for p in plans)
    t_opt = sum(p.t_fused if p.substrate == "fused" else p.t_generic
                for p in plans)
    return {
        "generic_us": t_gen * 1e6,
        "planned_us": t_opt * 1e6,
        "speedup": t_gen / max(t_opt, 1e-12),
        "fused_modules": sum(p.substrate == "fused" for p in plans),
        "n_modules": len(plans),
    }
