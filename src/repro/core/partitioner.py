"""The paper's contribution: module-level FPGA-GPU partition search with a
single network-wide FPGA resource budget.

DHM is dedicated silicon per mapped layer, so every FPGA placement consumes
resident MACs + on-chip weight bytes for the lifetime of the network.  The
partitioner therefore works in two stages:

  1. per module: enumerate the paper's schemes (DWConv split / GConv split /
     fused-layer / parallel-branch / homogeneous) across channel-parallelism
     options, pricing each with the device+link models;
  2. network level: greedy knapsack — upgrade modules from GPU-only in order
     of energy-saving density (J saved per resident MAC) while the
     Cyclone10GX budget lasts, under the latency objective:

        minimise energy s.t. module latency <= gpu_only * slack.
"""
from __future__ import annotations

from repro.core import costmodel as cm
from repro.core.costmodel import Cost, CostScales, ZERO
from repro.core.graph import ModuleGraph, Node
from repro.core.schedule import (Plan, Resources, fpga_chain_cost,
                                 fpga_resources, gpu_cost, module_gpu_only,
                                 network_stage_components, parallel_cost,
                                 pipelined_cost, split_spec_in)

ACT_BYTES = 1          # int8 feature maps on the link (paper's 8-bit)
# channel-parallel slices per mapped layer; high values = full spatial
# unroll (Fig. 1 regime) for layers cheap enough to afford it
G_PAR_GRID = (1, 4, 16, 64, 256)


def _plan(m: ModuleGraph, scheme: str, cost: Cost, gpu_only: Cost,
          fpga_nodes: list[Node], g_par: int = 1, assign=None, fused=(),
          gconv=None, note="") -> Plan:
    return Plan(m.name, m.kind, scheme, assign or {}, tuple(fused),
                gconv or {}, g_par, cost, gpu_only,
                fpga_resources(fpga_nodes, g_par), note)


def candidates(m: ModuleGraph,
               scales: CostScales | None = None) -> list[Plan]:
    base = module_gpu_only(m, scales)
    out: list[Plan] = [Plan(m.name, m.kind, "gpu_only",
                            {n.name: "gpu" for n in m.nodes},
                            cost=base, gpu_only=base)]
    conv_nodes = [n for n in m.nodes
                  if n.spec.kind in ("conv", "dwconv", "pwconv")]
    if not conv_nodes:
        return out

    for g_par in G_PAR_GRID:
        # --- whole module fused on the FPGA (fused-layer, Fig. 2c) --------
        i_b, o_b = (conv_nodes[0].spec.in_bytes(ACT_BYTES),
                    conv_nodes[-1].spec.out_bytes(ACT_BYTES))
        c = fpga_chain_cost(conv_nodes, i_b, o_b, g_par, scales)
        glue = gpu_cost([n for n in m.nodes if n not in conv_nodes], scales)
        out.append(_plan(m, "fpga_fused", c + glue, base, conv_nodes, g_par,
                         {n.name: ("fpga" if n in conv_nodes else "gpu")
                          for n in m.nodes},
                         fused=[n.name for n in conv_nodes]))
        if m.kind == "fire":
            out += _fire_candidates(m, base, g_par, scales)
        elif m.kind == "bottleneck":
            out += _bottleneck_candidates(m, base, g_par, scales)
        elif m.kind.startswith("shuffle_unit"):
            out += _shuffle_candidates(m, base, g_par, scales)
    return out


# --- SqueezeNet Fire: squeeze on GPU, expand3x3 ‖ expand1x1 ---------------

def _fire_candidates(m: ModuleGraph, base: Cost, g_par: int,
                     scales: CostScales | None = None) -> list[Plan]:
    sq, e1, e3 = m.node("squeeze"), m.node("exp1"), m.node("exp3")
    plans = []
    # 3x3 slices cost 9x the area of a 1x1 slice: DHM maps k>1 layers at
    # g_par=1 (the paper's fires are latency-neutral for exactly this reason)
    if g_par != 1:
        return plans
    # paper scheme: Conv3x3 on FPGA hidden under Conv1x1 (+squeeze) on GPU
    pre = gpu_cost([sq], scales)
    par = parallel_cost([e1], [e3], e3.spec.in_bytes(ACT_BYTES),
                        e3.spec.out_bytes(ACT_BYTES), g_par, scales)
    cost = pre + par + gpu_cost([m.node("cat")], scales)
    plans.append(_plan(m, "parallel_branch", cost, base, [e3], g_par,
                       {"squeeze": "gpu", "exp1": "gpu", "exp3": "fpga",
                        "cat": "gpu"},
                       note="exp3 on FPGA ‖ exp1 on GPU (paper Fig.4a)"))
    # GConv split of exp3 input channels across devices (Fig. 2b)
    for frac in (0.25, 0.5):
        f_spec, g_spec = split_spec_in(e3.spec, frac)
        pre = gpu_cost([sq], scales)
        par = parallel_cost(
            [e1, Node("exp3_gpu", g_spec, e3.inputs)],
            [Node("exp3_fpga", f_spec, e3.inputs)],
            f_spec.in_bytes(ACT_BYTES), f_spec.out_bytes(ACT_BYTES), g_par,
            scales)
        cost = pre + par + gpu_cost([m.node("cat")], scales)
        plans.append(_plan(m, "gconv_split", cost, base,
                           [Node("exp3_fpga", f_spec, e3.inputs)], g_par,
                           {"squeeze": "gpu", "exp1": "gpu", "cat": "gpu"},
                           gconv={"exp3": frac},
                           note=f"exp3 gconv {frac:.2f} in-ch to FPGA"))
    return plans


# --- MobileNetV2 bottleneck: 1x1 convs on FPGA (paper DWConv partition) ---

def _bottleneck_candidates(m: ModuleGraph, base: Cost, g_par: int,
                           scales: CostScales | None = None) -> list[Plan]:
    plans = []
    names = [n.name for n in m.nodes]
    has_exp = "pw_exp" in names
    dw, proj = m.node("dw"), m.node("pw_proj")
    # paper scheme: every 1x1 on FPGA, dw kxk on GPU, sequential
    pw_nodes = ([m.node("pw_exp")] if has_exp else []) + [proj]
    cost = ZERO
    if has_exp:
        e = m.node("pw_exp")
        cost = cost + fpga_chain_cost(
            [e], e.spec.in_bytes(ACT_BYTES), e.spec.out_bytes(ACT_BYTES),
            g_par, scales)
    cost = cost + gpu_cost([dw], scales)
    cost = cost + fpga_chain_cost(
        [proj], proj.spec.in_bytes(ACT_BYTES), proj.spec.out_bytes(ACT_BYTES),
        g_par, scales)
    assign = {n.name: ("gpu" if n.name == "dw" else "fpga") for n in m.nodes}
    plans.append(_plan(m, "dwconv_split", cost, base, pw_nodes, g_par, assign,
                       note="1x1 on FPGA, kxk dw on GPU (paper Fig.2a)"))
    # fused tail: dw + proj together on FPGA (fused-layer, Fig.2c)
    cost = (gpu_cost([m.node("pw_exp")], scales) if has_exp else ZERO)
    cost = cost + fpga_chain_cost(
        [dw, proj], dw.spec.in_bytes(ACT_BYTES),
        proj.spec.out_bytes(ACT_BYTES), g_par, scales)
    assign = {n.name: ("fpga" if n.name in ("dw", "pw_proj") else "gpu")
              for n in m.nodes}
    plans.append(_plan(m, "fused_layer", cost, base, [dw, proj], g_par,
                       assign, fused=("dw", "pw_proj"),
                       note="dw+proj fused on FPGA (paper Fig.2c)"))
    return plans


# --- ShuffleNetV2 units ----------------------------------------------------

def _shuffle_candidates(m: ModuleGraph, base: Cost, g_par: int,
                        scales: CostScales | None = None) -> list[Plan]:
    plans = []
    tail = [m.node("cat"), m.node("shuffle")]
    if m.kind == "shuffle_unit_down":
        b1 = [m.node("b1_dw"), m.node("b1_pw")]
        b2 = [m.node("b2_pw1"), m.node("b2_dw"), m.node("b2_pw2")]
        i_b = b1[0].spec.in_bytes(ACT_BYTES)
        o_b = b1[-1].spec.out_bytes(ACT_BYTES)
        cost = (parallel_cost(b2, b1, i_b, o_b, g_par, scales)
                + gpu_cost(tail, scales))
        assign = {n.name: "fpga" for n in m.nodes}
        assign.update({n.name: "gpu" for n in b2 + tail})
        plans.append(_plan(m, "parallel_branch", cost, base, b1, g_par,
                           assign, fused=("b1_dw", "b1_pw"),
                           note="branch1 fused on FPGA ‖ branch2 GPU"))
        return plans
    b2 = [m.node("b2_pw1"), m.node("b2_dw"), m.node("b2_pw2")]
    # identity half stays on GPU; working half fused on FPGA
    i_b = b2[0].spec.in_bytes(ACT_BYTES)
    o_b = b2[-1].spec.out_bytes(ACT_BYTES)
    cost = (gpu_cost([m.node("split")], scales)
            + fpga_chain_cost(b2, i_b, o_b, g_par, scales)
            + gpu_cost(tail, scales))
    assign = {n.name: "gpu" for n in m.nodes}
    assign.update({n.name: "fpga" for n in b2})
    plans.append(_plan(m, "fused_layer", cost, base, b2, g_par, assign,
                       fused=tuple(n.name for n in b2),
                       note="working half fused on FPGA (seq)"))
    # pw convs to FPGA, dw stays GPU (MBv2-style)
    pw = [m.node("b2_pw1"), m.node("b2_pw2")]
    cost = (gpu_cost([m.node("split"), m.node("b2_dw")], scales)
            + gpu_cost(tail, scales))
    for n in pw:
        cost = cost + fpga_chain_cost(
            [n], n.spec.in_bytes(ACT_BYTES), n.spec.out_bytes(ACT_BYTES),
            g_par, scales)
    assign = {x.name: "gpu" for x in m.nodes}
    assign.update({n.name: "fpga" for n in pw})
    plans.append(_plan(m, "dwconv_split", cost, base, pw, g_par, assign,
                       note="1x1s on FPGA, dw on GPU"))
    return plans


# ---------------------------------------------------------------------------
# Network-level selection under the FPGA resource budget
# ---------------------------------------------------------------------------

def admissible(p: Plan, latency_slack: float) -> bool:
    return (p.cost.latency <= p.gpu_only.latency * latency_slack
            and p.cost.energy < p.gpu_only.energy)


# The schemes the paper actually deployed per module family (Sec. IV/V).
PAPER_SCHEMES = {
    "fire": ("parallel_branch",),
    "bottleneck": ("dwconv_split",),
    "shuffle_unit_down": ("parallel_branch",),
    "shuffle_unit": ("dwconv_split",),
    "stem": (),
    "head": (),
}


VALID_OBJECTIVES = ("paper", "gpu_only", "latency", "edp")


def _edp(c: Cost) -> float:
    return c.energy * c.latency


def partition_network(modules: list[ModuleGraph], objective: str = "paper",
                      latency_slack: float = 1.05,
                      mac_budget: int | None = None,
                      byte_budget: int | None = None,
                      paper_faithful: bool = False,
                      scales: CostScales | None = None) -> list[Plan]:
    """``scales`` re-prices every candidate under fitted latency
    coefficients (``repro.core.replan``) — identity/None reproduces the
    a-priori paper model.  The returned plans' ``cost``/``gpu_only``
    fields carry the scaled accounting."""
    if objective not in VALID_OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {VALID_OBJECTIVES}")
    mac_budget = cm.FPGA.mac_budget if mac_budget is None else mac_budget
    byte_budget = cm.FPGA.onchip_bytes if byte_budget is None else byte_budget

    all_cands = {m.name: candidates(m, scales) for m in modules}
    if paper_faithful:
        for m in modules:
            keep = PAPER_SCHEMES.get(m.kind, ())
            all_cands[m.name] = [
                p for p in all_cands[m.name]
                if p.scheme == "gpu_only" or p.scheme in keep]
    chosen: dict[str, Plan] = {
        m.name: next(p for p in all_cands[m.name] if p.scheme == "gpu_only")
        for m in modules}

    if objective == "gpu_only":
        return [chosen[m.name] for m in modules]

    # hetero options, best-saving-density first
    options = []
    for name, cands in all_cands.items():
        for p in cands:
            if p.scheme == "gpu_only":
                continue
            if objective == "paper" and not admissible(p, latency_slack):
                continue
            if objective == "latency":
                # rank by latency saved per resident resource (was: energy
                # saving, which let an energy-dense but latency-neutral
                # plan crowd out the actual latency wins)
                saving = p.gpu_only.latency - p.cost.latency
                if saving <= 0:
                    continue
            elif objective == "edp":
                # energy-delay product: only admit plans that strictly
                # improve EDP, and rank by EDP saved per resident resource
                saving = _edp(p.gpu_only) - _edp(p.cost)
                if saving <= 0:
                    continue
            else:
                saving = p.saving
            density = saving / max(p.res.macs + p.res.bytes / 64.0, 1.0)
            options.append((density, p))
    options.sort(key=lambda dp: -dp[0])

    macs_left, bytes_left = mac_budget, byte_budget
    for _d, p in options:
        cur = chosen[p.module]
        if cur.scheme != "gpu_only":
            continue                       # module already upgraded
        if p.res.macs > macs_left or p.res.bytes > bytes_left:
            continue
        chosen[p.module] = p
        macs_left -= p.res.macs
        bytes_left -= p.res.bytes
    return [chosen[m.name] for m in modules]


def fused_chain_coverage(modules: list[ModuleGraph],
                         plans: list[Plan]) -> dict:
    """How much of the FPGA-assigned conv work the fusion pass captures:
    the fraction of FPGA conv-ish nodes that land inside a fused group of
    length >= 2 (the paper's DHM wins hinge on whole chains staying
    on-fabric, so this is the coverage number the benchmarks report)."""
    from repro.core.passes import chain_groups
    convish = ("conv", "dwconv", "pwconv", "fc")
    plan_by = {p.module: p for p in plans}
    fpga_nodes = fused_nodes = 0
    for m in modules:
        p = plan_by.get(m.name)
        if p is None:
            continue
        fpga_nodes += sum(1 for n in m.nodes
                          if n.spec.kind in convish
                          and p.assign.get(n.name) == "fpga")
        fused_nodes += sum(len(g) for g in chain_groups(m, p) if len(g) > 1)
    return {"fpga_nodes": fpga_nodes, "fused_nodes": fused_nodes,
            "coverage": fused_nodes / fpga_nodes if fpga_nodes else 0.0}


def pipelined_summary(modules: list[ModuleGraph], plans: list[Plan],
                      n_inflight: int = 8,
                      scales: CostScales | None = None) -> dict:
    """Price the stage-pipelined schedule of a partitioned network: the
    same per-node costs as ``summarize``, but stages (maximal same-device
    runs, merged across module boundaries — the exact cut
    ``repro.core.passes.stage`` executes) overlap across inputs, so the
    steady-state beat is the max stage latency rather than the serial sum.
    This is how the partitioner prices the paper's overlap argument: a
    balanced FPGA/GPU split can beat a faster-but-lopsided one once k
    inputs are in flight."""
    stages = [sc.cost(scales)
              for sc in network_stage_components(modules, plans, ACT_BYTES)]
    serial = pipelined_cost(stages, 1)             # fill == serial walk
    piped = pipelined_cost(stages, n_inflight)
    serial_n = Cost(serial.latency * n_inflight, serial.energy * n_inflight)
    beat = max(c.latency for c in stages) if stages else 0.0
    return {
        "n_stages": len(stages),
        "n_inflight": n_inflight,
        "fill_ms": serial.latency * 1e3,
        "serial_ms_per_input": serial.latency * 1e3,
        "steady_ms_per_input": beat * 1e3,
        "pipelined_ms_per_input": piped.latency / max(n_inflight, 1) * 1e3,
        "pipelined_rps": 1.0 / max(beat, 1e-12),
        "overlap_speedup": serial_n.latency / max(piped.latency, 1e-12),
    }


def summarize(plans: list[Plan]) -> dict:
    tot = ZERO
    base = ZERO
    for p in plans:
        tot = tot + p.cost
        base = base + p.gpu_only
    used = Resources()
    for p in plans:
        used = used + p.res
    return {
        "latency_ms": tot.latency * 1e3,
        "energy_mJ": tot.energy * 1e3,
        "gpu_only_latency_ms": base.latency * 1e3,
        "gpu_only_energy_mJ": base.energy * 1e3,
        "energy_gain": base.energy / max(tot.energy, 1e-12),
        "speedup": base.latency / max(tot.latency, 1e-12),
        "fpga_macs": used.macs,
        "fpga_bytes": used.bytes,
    }
