"""Online re-partitioning: measurement -> fit -> repartition -> migrate.

The partitioner picks the FPGA/GPU cut from an a-priori cost model
(``repro.core.costmodel``), but a deployed host never matches that model
exactly — and the paper's central claim is that the cut point is what
latency and energy hinge on.  This module closes the loop:

  1. **Observe.**  ``Replanner.observe`` ingests measured per-stage wall
     times (``PipelinedEngine.timed_call``; monolithic engines report one
     total) together with the model's stage decomposition
     (``schedule.network_stage_components``), normalized per input row.
     Observations accumulate in a sliding window per (network, resolution),
     each tagged with the plan that produced it.
  2. **Fit.**  ``fit_scales`` regresses measured stage time against the
     three UNSCALED model features of each stage — GPU compute, FPGA
     compute, PCIe transfer — by ridge-regularized least squares:

         wall ~= gpu * t_gpu_model + fpga * t_fpga_model + xfer * t_pcie

     The ridge prior pins any coefficient the window carries no signal for
     (e.g. the FPGA column while serving an all-GPU plan) at its previous
     fitted value instead of letting it drift, so migrating away from a
     device does not erase what was learned about it.
  3. **Decide.**  ``Replanner.consider`` re-runs the existing partitioner
     under the fitted ``CostScales`` and compares the candidate plan's
     *modelled* serial latency against the live plan's *measured* one.
     Hysteresis: the modelled win must clear ``threshold`` (default 15%)
     for ``patience`` consecutive windows before a migration is ordered —
     a noisy window can never flap the plan.
  4. **Migrate.**  The decision carries the candidate plans; the serving
     layer (``HeteroServer``) executes it with the shadow-prepare /
     atomic-redirect machinery generalized from the PR-6 breaker failover
     — live traffic never drains, and every served row keeps bit-matching
     the batch-1 oracle of the plan generation that served it.

Everything here is plain host-side arithmetic — deterministic, no JAX,
thread-safe — so the convergence contract is testable in tier-1 CI with
synthetic measurements and in serving CI with injected stage delays.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.costmodel import CostScales
from repro.core.graph import ModuleGraph
from repro.core.schedule import Plan, StageCost, network_stage_components


# ---------------------------------------------------------------------------
# Observations
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageSample:
    """One measured stage execution attributed to model features: the
    modelled (unscaled) seconds of GPU compute / FPGA compute / PCIe
    transfer inside the stage, and the measured wall seconds per input
    row.  The regression design matrix is rows of the first three."""
    gpu_s: float
    fpga_s: float
    xfer_s: float
    measured_s: float


def stage_samples(components: list[StageCost], times: list[float],
                  batch: int = 1) -> list[StageSample]:
    """Attribute measured wall times to the model's stage decomposition.

    ``len(times) == len(components)`` is the pipelined case — one sample
    per stage, maximal attribution signal.  A monolithic engine reports a
    single total; the components then collapse into ONE summed sample (the
    regression still sees the device mix, just without per-stage
    resolution).  Times are normalized per input row."""
    b = max(1, int(batch))

    def feat(sc: StageCost) -> tuple[float, float, float]:
        return (sc.comp.latency if sc.device == "gpu" else 0.0,
                sc.comp.latency if sc.device == "fpga" else 0.0,
                sc.xfer.latency)

    if len(times) == len(components):
        return [StageSample(*feat(sc), t / b)
                for sc, t in zip(components, times)]
    gpu = sum(feat(sc)[0] for sc in components)
    fpga = sum(feat(sc)[1] for sc in components)
    xfer = sum(feat(sc)[2] for sc in components)
    return [StageSample(gpu, fpga, xfer, sum(times) / b)]


def fit_scales(samples: list[StageSample],
               prior: CostScales | None = None,
               ridge: float = 0.1) -> CostScales:
    """Ridge-regularized least squares for the three latency coefficients.

    Within one plan the transfer feature is collinear with FPGA compute
    (every FPGA stage pays PCIe in+out), and a window observed under an
    all-GPU plan has *zero* FPGA/transfer signal.  The ridge term pulls
    each coefficient toward ``prior`` with a weight proportional to its
    feature's magnitude in the window (plus a tiny absolute floor), so
    well-observed coefficients follow the data and unobserved ones stay
    exactly at the prior.  Results are clamped positive."""
    prior = prior or CostScales()
    if not samples:
        return prior
    A = np.array([[s.gpu_s, s.fpga_s, s.xfer_s] for s in samples])
    t = np.array([s.measured_s for s in samples])
    p = np.array([prior.gpu, prior.fpga, prior.xfer])
    col = np.sqrt((A * A).mean(axis=0))
    lam = ridge * col + 1e-9 * max(col.max(), 1e-6)
    A_aug = np.vstack([A, np.diag(lam)])
    t_aug = np.concatenate([t, lam * p])
    sol, *_ = np.linalg.lstsq(A_aug, t_aug, rcond=None)
    return CostScales(float(sol[0]), float(sol[1]),
                      float(sol[2])).clamped()


# ---------------------------------------------------------------------------
# Plan identity and distance
# ---------------------------------------------------------------------------

def assign_signature(plans: list[Plan] | None) -> tuple:
    """Hashable identity of a plan set's ROUTING decisions only — the part
    a migration actually changes.  Cost fields are excluded on purpose:
    the same cut re-priced under fitted scales is still the same plan."""
    if plans is None:
        return ("gpu_only",)
    return tuple((p.module, tuple(sorted(p.assign.items())),
                  tuple(sorted(p.gconv.items())), p.g_par)
                 for p in plans)


def _device_walk(modules: list[ModuleGraph],
                 plans: list[Plan] | None) -> list[str]:
    """Flat per-node device tape of a network under a plan set — the
    sequence whose device flips are exactly the pipeline's cut points."""
    plan_by = {p.module: p for p in plans} if plans else {}
    tape: list[str] = []
    for m in modules:
        p = plan_by.get(m.name)
        for n in m.nodes:
            if p is not None and (p.assign.get(n.name) == "fpga"
                                  or n.name in p.gconv):
                tape.append("fpga")
            else:
                tape.append("gpu")
        if m.residual:
            tape.append("gpu")
    tape.append("gpu")                     # network output reshape
    return tape


def cut_positions(modules: list[ModuleGraph],
                  plans: list[Plan] | None) -> frozenset:
    """Indices where the device tape flips — the FPGA<->GPU boundary
    edges ``passes/stage.py`` cuts at."""
    tape = _device_walk(modules, plans)
    return frozenset(i for i in range(len(tape) - 1)
                     if tape[i] != tape[i + 1])


def boundary_distance(modules: list[ModuleGraph],
                      plans_a: list[Plan] | None,
                      plans_b: list[Plan] | None) -> int:
    """How many boundary edges two plan sets disagree on (symmetric
    difference of their cut positions).  0 = the same pipeline cut;
    "within one boundary edge of the oracle plan" is the convergence
    contract the replanner is tested against."""
    return len(cut_positions(modules, plans_a)
               ^ cut_positions(modules, plans_b))


# ---------------------------------------------------------------------------
# The replanner
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReplanDecision:
    """One ``consider`` outcome.  ``migrate=True`` carries the candidate
    plans; otherwise ``reason`` says why the loop is holding still."""
    network: str
    migrate: bool
    reason: str
    scales: CostScales | None = None     # fitted coefficients (post-warmup)
    plans: list | None = None            # candidate plan set (when it differs)
    modelled_s: float = 0.0              # candidate serial latency under fit
    measured_s: float = 0.0              # live plan measured serial latency
    win: float = 0.0                     # 1 - modelled/measured
    streak: int = 0                      # consecutive over-threshold windows


@dataclass
class _NetState:
    """Per-network fitter state: observation sweeps (sliding window,
    tagged with the plan that produced them), the accumulated coefficient
    belief, and the hysteresis streak."""
    sweeps: deque = field(default_factory=lambda: deque(maxlen=64))
    prior: CostScales = field(default_factory=CostScales)
    streak: int = 0
    migrations: int = 0


class Replanner:
    """Online cost observer + hysteresis-gated repartition policy.

    One instance serves a whole ``HeteroServer``: observations are keyed
    by (network, resolution) but pooled per network for fitting (the
    coefficients describe the HOST, not a resolution).  ``consider`` is
    called from the server's drain thread; ``observe``/``snapshot`` may
    be called from anywhere — all state is lock-guarded.

    Knobs:
      * ``threshold`` — minimum modelled win (fraction of measured
        latency) before a window counts toward migration.  Below it the
        streak resets: the loop cannot flap on noise.
      * ``patience`` — consecutive qualifying windows required.
      * ``window`` — observation sweeps retained per (network, res).
      * ``min_samples`` — sweeps of the CURRENT plan required before any
        decision (a fresh migration therefore starts a natural cooldown).
      * ``ridge`` — regularization strength of the fit; the prior it
        pulls toward is the previous fit, so coefficients for devices the
        current plan never touches keep their learned values.
    """

    def __init__(self, objective: str = "latency",
                 threshold: float = 0.15, patience: int = 3,
                 window: int = 64, min_samples: int = 8,
                 ridge: float = 0.1, act_bytes: int = 1,
                 paper_faithful: bool = False):
        self.objective = objective
        self.threshold = float(threshold)
        self.patience = max(1, int(patience))
        self.window = max(2, int(window))
        self.min_samples = max(1, int(min_samples))
        self.ridge = float(ridge)
        self.act_bytes = int(act_bytes)
        self.paper_faithful = paper_faithful
        self._lock = threading.Lock()
        self._nets: dict[str, _NetState] = {}
        self.events: list[dict] = []       # migration log, oldest first

    def _state(self, network: str) -> _NetState:
        st = self._nets.get(network)
        if st is None:
            st = self._nets[network] = _NetState(
                sweeps=deque(maxlen=self.window))
        return st

    # -- observation ingest ------------------------------------------------

    def observe(self, network: str, res, plans: list[Plan] | None,
                components: list[StageCost], times: list[float],
                batch: int = 1) -> None:
        """Record one measured sweep: per-stage wall times (or one total)
        for a batch served under ``plans``.  ``components`` must be the
        ``network_stage_components`` of the same (modules, plans) pair
        the engine executed."""
        samples = stage_samples(components, times, batch)
        key = tuple(res) if res is not None else None
        tag = assign_signature(plans)
        with self._lock:
            self._state(network).sweeps.append((tag, key, samples))

    def fitted(self, network: str) -> CostScales:
        """Current fitted coefficients for a network (the stored prior
        when nothing has been observed yet)."""
        with self._lock:
            st = self._state(network)
            sweeps = list(st.sweeps)
            prior = st.prior
        flat = [s for _tag, _res, samples in sweeps for s in samples]
        return fit_scales(flat, prior=prior, ridge=self.ridge)

    # -- decision ----------------------------------------------------------

    def consider(self, network: str, modules: list[ModuleGraph],
                 plans: list[Plan] | None) -> ReplanDecision:
        """Fit the window, repartition under the fit, compare against the
        live plan's measured latency, and apply hysteresis.  Returns a
        ``ReplanDecision``; the CALLER executes any migration (and keeps
        calling ``observe`` afterward — the window deliberately retains
        pre-migration sweeps, which is what pins the coefficients of the
        device just migrated away from)."""
        cur_tag = assign_signature(plans)
        with self._lock:
            st = self._state(network)
            sweeps = list(st.sweeps)
            prior = st.prior
        cur = [samples for tag, _res, samples in sweeps if tag == cur_tag]
        if len(cur) < self.min_samples:
            return ReplanDecision(network, False,
                                  f"warming: {len(cur)}/{self.min_samples} "
                                  f"windows on the current plan")
        flat = [s for _tag, _res, samples in sweeps for s in samples]
        scales = fit_scales(flat, prior=prior, ridge=self.ridge)
        with self._lock:
            st.prior = scales          # accumulated belief survives windows
        cand = partition_with(modules, self.objective, scales,
                              paper_faithful=self.paper_faithful)
        if assign_signature(cand) == cur_tag:
            with self._lock:
                st.streak = 0
            return ReplanDecision(network, False,
                                  "current plan optimal under fitted model",
                                  scales=scales)
        comps = network_stage_components(modules, cand, self.act_bytes)
        modelled = sum(sc.latency(scales) for sc in comps)
        measured = float(np.mean([sum(s.measured_s for s in samples)
                                  for samples in cur]))
        win = 1.0 - modelled / max(measured, 1e-12)
        if win < self.threshold:
            with self._lock:
                st.streak = 0
            return ReplanDecision(
                network, False,
                f"candidate win {win:.1%} below threshold "
                f"{self.threshold:.0%}", scales=scales, plans=cand,
                modelled_s=modelled, measured_s=measured, win=win)
        with self._lock:
            st.streak += 1
            streak = st.streak
            if streak < self.patience:
                return ReplanDecision(
                    network, False,
                    f"hysteresis: win {win:.1%} for {streak}/"
                    f"{self.patience} consecutive windows",
                    scales=scales, plans=cand, modelled_s=modelled,
                    measured_s=measured, win=win, streak=streak)
            st.streak = 0
            st.migrations += 1
            self.events.append({
                "network": network, "win": win,
                "modelled_s": modelled, "measured_s": measured,
                "scales": scales.as_dict(),
                "migration": st.migrations})
        return ReplanDecision(network, True,
                              f"modelled win {win:.1%} >= "
                              f"{self.threshold:.0%} for {self.patience} "
                              f"windows", scales=scales, plans=cand,
                              modelled_s=modelled, measured_s=measured,
                              win=win, streak=self.patience)

    def snapshot(self) -> dict:
        """Fitted coefficients + decision state per network (metrics)."""
        with self._lock:
            nets = {name: {"windows": len(st.sweeps),
                           "streak": st.streak,
                           "migrations": st.migrations,
                           "scales": st.prior.as_dict()}
                    for name, st in self._nets.items()}
            return {"networks": nets, "events": list(self.events)}


def partition_with(modules: list[ModuleGraph], objective: str,
                   scales: CostScales,
                   paper_faithful: bool = False) -> list[Plan]:
    """Run the existing partitioner under fitted scales.  Function-level
    import: partitioner imports schedule, which this module also uses —
    keeping replan importable without a cycle."""
    from repro.core.partitioner import partition_network
    return partition_network(modules, objective=objective,
                             paper_faithful=paper_faithful, scales=scales)


def carry_calibration(old: list[Plan] | None,
                      new: list[Plan] | None) -> list[Plan] | None:
    """Candidate plans inherit the live plans' calibration choice per
    module — a migration must never silently change the quantization
    semantics a network registered with."""
    if old is None or new is None:
        return new
    cal_by = {p.module: p.calibrate for p in old}
    return [replace(p, calibrate=cal_by.get(p.module, p.calibrate))
            for p in new]
