"""Analytical device cost models — the paper's measurement substrate.

This container has neither the paper's Jetson TX2 + Cyclone10GX board nor a
TPU, so energy/latency come from explicit models (the paper's own FPGA
numbers are also model-based: Intel Quartus Power Estimator).  Constants are
calibrated so that (a) Fig.1-style conv sweeps show the paper's qualitative
gap (FPGA DHM ~order-of-magnitude energy win, resource ceiling at 64x5x5 on
224x224x3) and (b) the partitioner's module gains land inside Table I ranges
(validated in tests/test_paper_claims.py).

Models:
  TX2GPU       roofline (fp16 peak x batch-1 utilisation curve, LPDDR4 bw)
               + per-launch overhead; power = idle + dynamic.
  DHMFPGA      fully pipelined spatial mapping: one output pixel per clock,
               all weights in logic, zero DRAM traffic; resource = #MACs;
               power = static + per-MAC toggle energy (8-bit fixed point).
  PCIe         2.5 GB/s effective + DMA setup latency (paper's link).
  TPUv5e       197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI — used by
               the datacentre-scale mapping of the same partitioner.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ConvSpec:
    """One operator at module level (the paper's partitioning granularity)."""
    kind: str              # conv | dwconv | pwconv | fc | pool | add | concat | shuffle
    h: int                 # input feature map height
    w: int
    c_in: int
    c_out: int
    k: int = 1
    stride: int = 1
    groups: int = 1

    @property
    def h_out(self) -> int:
        return max(self.h // self.stride, 1)

    @property
    def w_out(self) -> int:
        return max(self.w // self.stride, 1)

    @property
    def macs_per_pixel(self) -> int:
        if self.kind == "dwconv":
            return self.k * self.k * self.c_out
        if self.kind in ("conv", "pwconv"):
            return self.k * self.k * (self.c_in // self.groups) * self.c_out
        if self.kind == "fc":
            return self.c_in * self.c_out
        return 0

    @property
    def macs(self) -> float:
        if self.kind == "fc":
            return float(self.c_in * self.c_out)
        return float(self.h_out * self.w_out * self.macs_per_pixel)

    @property
    def flops(self) -> float:
        return 2.0 * self.macs

    @property
    def n_weights(self) -> int:
        if self.kind == "dwconv":
            return self.k * self.k * self.c_out
        if self.kind in ("conv", "pwconv"):
            return self.k * self.k * (self.c_in // self.groups) * self.c_out
        if self.kind == "fc":
            return self.c_in * self.c_out
        return 0

    def in_bytes(self, dtype_bytes: int = 1) -> int:
        return self.h * self.w * self.c_in * dtype_bytes

    def out_bytes(self, dtype_bytes: int = 1) -> int:
        return self.h_out * self.w_out * self.c_out * dtype_bytes


@dataclass(frozen=True)
class Cost:
    latency: float         # seconds
    energy: float          # joules

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.latency + o.latency, self.energy + o.energy)


ZERO = Cost(0.0, 0.0)


@dataclass(frozen=True)
class CostScales:
    """Multiplicative latency corrections to the a-priori device models.

    The analytical models below predict *model seconds*; a deployed host
    never matches them exactly.  ``CostScales`` is the three-coefficient
    bridge the online re-fitter (``repro.core.replan``) estimates from
    measured per-stage wall times:

        wall_time(stage) ~= gpu  * modelled GPU compute
                          + fpga * modelled FPGA compute
                          + xfer * modelled PCIe transfer

    Identity scales (the default) reproduce the unscaled paper model.
    Only latency is scaled — energy comes from the power model and is not
    observable from host-side timing, so the energy accounting stays the
    paper's own.
    """
    gpu: float = 1.0
    fpga: float = 1.0
    xfer: float = 1.0

    def clamped(self, lo: float = 1e-3, hi: float = 1e6) -> "CostScales":
        """Positive, bounded coefficients — a least-squares fit on a noisy
        window must never drive a modelled latency negative or to zero."""
        clip = lambda v: min(max(v, lo), hi)   # noqa: E731
        return CostScales(clip(self.gpu), clip(self.fpga), clip(self.xfer))

    def as_dict(self) -> dict:
        return {"gpu": self.gpu, "fpga": self.fpga, "xfer": self.xfer}


IDENTITY_SCALES = CostScales()


# ---------------------------------------------------------------------------
# Jetson TX2 GPU (Pascal, 256 CUDA cores)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TX2GPU:
    name: str = "jetson-tx2-gpu"
    peak_flops: float = 1.33e12        # fp16 FMA peak
    mem_bw: float = 59.7e9             # LPDDR4
    launch_overhead: float = 100e-6    # per-op kernel launch + sync (batch 1)
    idle_power: float = 2.5            # W (GPU rail share while active-idle)
    busy_power: float = 5.0            # W dynamic at full tilt
    act_bytes: int = 2                 # fp16 activations
    util_ceiling: float = 0.70
    util_knee: float = 3e5

    def utilisation(self, spec: ConvSpec) -> float:
        """Batch-1 conv efficiency on TX2 (PyTorch/cuDNN), empirical shape:
        small channel counts starve the SMs; saturates near the ceiling."""
        par = spec.c_out * spec.h_out * spec.w_out
        sat = par / (par + self.util_knee)
        depth = 1.0 if spec.kind != "dwconv" else 0.35   # dw convs are bw-bound
        return max(0.04, self.util_ceiling * sat * depth)

    def op_cost(self, spec: ConvSpec) -> Cost:
        if spec.macs == 0:                 # pool/add/concat: bandwidth only
            traffic = (spec.in_bytes(self.act_bytes)
                       + spec.out_bytes(self.act_bytes))
            t = traffic / self.mem_bw + self.launch_overhead * 0.5
            return Cost(t, t * (self.idle_power + 0.3 * self.busy_power))
        t_comp = spec.flops / (self.peak_flops * self.utilisation(spec))
        traffic = (spec.in_bytes(self.act_bytes)
                   + spec.out_bytes(self.act_bytes)
                   + spec.n_weights * self.act_bytes)
        t_mem = traffic / self.mem_bw
        t = max(t_comp, t_mem) + self.launch_overhead
        util_frac = t_comp / max(t, 1e-12)
        return Cost(t, t * (self.idle_power + self.busy_power * util_frac))


# ---------------------------------------------------------------------------
# Cyclone 10 GX with Direct Hardware Mapping
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DHMFPGA:
    """DHM with input-channel time multiplexing.

    Two regimes, both in the paper:
      * Fig. 1 standalone sweep: FULL spatial unroll (all k*k*C_in*N MACs as
        logic) — ceiling 64 filters of 5x5 on a 224x224x3 input
        (25*3*64 = 4800 MACs = ``mac_budget``).
      * Partitioned modules (Sec. IV): one input-channel *slice* is unrolled
        (k*k*N MACs per slice, g_par slices in parallel) and C_in streams
        through over ceil(C_in/g_par) cycles per pixel — this is what makes
        "all the 1x1 convolutions on the FPGA for all layers" feasible.
    MAC count (and thus dynamic energy) is identical in both regimes.
    """
    name: str = "cyclone10gx-dhm"
    f_clk: float = 150e6
    mac_budget: int = 4800             # spatial 8-bit MACs (DSP+ALM)
    onchip_bytes: int = 6 * 2**20      # M20K: weights + line buffers
    static_power: float = 2.60         # W (board-level: core+xcvr+regulators)
    chip_static: float = 0.50          # W (chip-only — the Fig.1 regime:
                                       # the paper's FPGA numbers are Quartus
                                       # Power-Estimator chip estimates)
    mac_energy: float = 2.6e-12        # J per 8-bit MAC (toggling, routed)
    pipeline_fill: float = 30e-6       # line-buffer fill etc.

    def slice_macs(self, spec: ConvSpec) -> int:
        """MACs instantiated for ONE input-channel slice of this layer."""
        if spec.kind == "dwconv":
            return spec.k * spec.k          # per channel; channels multiplex
        if spec.kind in ("conv", "pwconv"):
            return spec.k * spec.k * spec.c_out
        if spec.kind == "fc":
            return spec.c_out
        return 0

    def serial_channels(self, spec: ConvSpec) -> int:
        return spec.c_out if spec.kind == "dwconv" else \
            max(spec.c_in // spec.groups, 1)

    def mac_usage(self, spec: ConvSpec, g_par: int = 1) -> int:
        """Resident MACs for this layer at channel-parallelism g_par."""
        return self.slice_macs(spec) * min(g_par, self.serial_channels(spec))

    def buffer_bytes(self, spec: ConvSpec) -> int:
        # (k-1) input line buffers + all weights resident on-chip
        return (spec.k - 1) * spec.w * spec.c_in + spec.n_weights

    def fits_full_unroll(self, spec: ConvSpec) -> bool:
        """Fig. 1 regime: every MAC spatial (ceiling: 64 x 5x5 on 224^2x3)."""
        return (spec.macs_per_pixel <= self.mac_budget and
                self.buffer_bytes(spec) <= self.onchip_bytes)

    def op_cost(self, spec: ConvSpec, g_par: int = 1) -> Cost:
        """Channel-multiplexed DHM: ceil(C_serial/g_par) cycles per pixel."""
        if self.slice_macs(spec) == 0:
            return Cost(self.pipeline_fill, self.pipeline_fill
                        * self.static_power)
        pixels = spec.h_out * spec.w_out
        steps = -(-self.serial_channels(spec) // g_par)
        t = pixels * steps / self.f_clk + self.pipeline_fill
        e_dyn = spec.macs * self.mac_energy
        return Cost(t, e_dyn + t * self.static_power)

    def full_unroll_cost(self, spec: ConvSpec) -> Cost:
        """Fig. 1 regime: one output pixel per clock, chip-level power."""
        pixels = spec.h_out * spec.w_out
        t = pixels / self.f_clk + self.pipeline_fill
        return Cost(t, spec.macs * self.mac_energy + t * self.chip_static)

    def fused_cost(self, specs: list["ConvSpec"], g_par=None) -> Cost:
        """Fused-layer chain: stages stream concurrently in one pipeline;
        throughput set by the slowest stage; fill paid once."""
        if not specs:
            return ZERO
        g_par = g_par or [1] * len(specs)
        worst = 0.0
        for s, g in zip(specs, g_par):
            if self.slice_macs(s) == 0:
                continue
            steps = -(-self.serial_channels(s) // g)
            worst = max(worst, s.h_out * s.w_out * steps / self.f_clk)
        t = worst + self.pipeline_fill
        e_dyn = sum(s.macs for s in specs) * self.mac_energy
        return Cost(t, e_dyn + t * self.static_power)


# ---------------------------------------------------------------------------
# PCIe gen2 x4 (the paper's inter-device link)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PCIeLink:
    name: str = "pcie-gen2-x4"
    bw: float = 2.5e9                  # effective B/s (paper)
    setup: float = 40e-6               # DMA descriptor + doorbell
    byte_energy: float = 200e-12       # J/B incl. SerDes both ends

    def xfer(self, nbytes: float) -> Cost:
        t = self.setup + nbytes / self.bw
        return Cost(t, nbytes * self.byte_energy + t * 0.15)  # 0.15 W link idle


# ---------------------------------------------------------------------------
# TPU v5e (datacentre mapping of the same machinery)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TPUv5e:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12         # bf16
    peak_flops_int8: float = 394e12
    mem_bw: float = 819e9
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20
    ici_bw: float = 50e9               # per link
    ici_links: int = 4
    busy_power: float = 170.0          # W per chip (typical)
    hbm_byte_energy: float = 120e-12
    flop_energy: float = 0.35e-12

    def roofline(self, flops: float, hbm_bytes: float,
                 coll_bytes: float = 0.0, chips: int = 1) -> dict:
        t_comp = flops / (chips * self.peak_flops)
        t_mem = hbm_bytes / (chips * self.mem_bw)
        t_coll = coll_bytes / (chips * self.ici_bw * self.ici_links)
        return {"compute_s": t_comp, "memory_s": t_mem,
                "collective_s": t_coll,
                "bound": max(("compute_s", t_comp), ("memory_s", t_mem),
                             ("collective_s", t_coll), key=lambda kv: kv[1])[0]}


def pipelined_latency(stage_latencies: list[float], n_inputs: int = 1) -> float:
    """Software-pipeline makespan: the first input pays every stage (fill =
    sum), each further input pays one beat of the slowest stage (steady
    state = max).  The serialized alternative is ``sum * n_inputs`` — the
    gap between the two is exactly the paper's FPGA/GPU overlap argument."""
    if not stage_latencies or n_inputs <= 0:
        return 0.0
    return sum(stage_latencies) + (n_inputs - 1) * max(stage_latencies)


GPU = TX2GPU()
FPGA = DHMFPGA()
PCIE = PCIeLink()
TPU = TPUv5e()
