"""Network-level lowering: compose the pass pipeline across modules.

This is the compile-time half of the heterogeneous engine
(``repro.core.executor`` owns the cache and the public API).  Each module
runs through the ``repro.core.passes`` pipeline — plan annotation, chain
fusion, calibration planning, backend emission (see the README's
"Pass-based lowering pipeline" section for the full rule set) — and this
module stitches the per-module programs into a network-level triple:

  * ``prepare(params, calib_x=None)`` transforms the raw fp32 parameter
    tree once at compile time (weight quantization happens here, never per
    call).  When any plan opted into calibration, a calibration batch is
    REQUIRED: the capture program runs it through the network, records each
    quant site's absolute-max activation, and freezes the resulting
    per-tensor scales into the prepared tree.
  * ``run(prepared, x)`` is pure and jit-traceable: all routing decisions
    were burned in at lowering time.
  * ``needs_calibration`` tells the executor whether ``prepare`` demands a
    calibration batch.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import ModuleGraph
from repro.core.passes import run_pipeline, stage_partition
from repro.core.schedule import Plan
from repro.quant import scale_from_amax


class LoweredNetwork(NamedTuple):
    prepare: Callable        # (params, calib_x=None) -> prepared
    run: Callable            # (prepared, x) -> logits
    needs_calibration: bool
    stages: list             # passes.Stage list (device-boundary cuts);
    #                        # running them back to back == run, bit for bit
    capture: Callable        # jitted (prepared, x) -> {mod: {site: scale}}
    freeze: Callable         # (prepared, scales, alpha=1.0) -> prepared'
    ema_modules: frozenset   # modules whose calibrator refines online


def lower_network(mods: list[ModuleGraph], plans: list[Plan] | None,
                  use_pallas: bool) -> LoweredNetwork:
    plan_by = {p.module: p for p in plans} if plans else {}
    lowered = [(m.name, run_pipeline(m, plan_by.get(m.name), use_pallas))
               for m in mods]
    needs_calibration = any(lm.ir.calib_sites for _name, lm in lowered)
    stages = stage_partition(lowered)

    def prepare_params(params):
        return {name: lm.prepare(params[name]) for name, lm in lowered}

    def capture_scales(prepared, x):
        """Forward the calibration batch (per-sample quantization — the
        uncalibrated fallback) and freeze one per-tensor scale per site."""
        scales = {}
        for name, lm in lowered:
            if lm.ir.calib_sites:
                x, amaxes = lm.capture(prepared[name], x)
                scales[name] = {site: scale_from_amax(a)
                                for site, a in amaxes.items()}
            else:
                x = lm.run(prepared[name], x)
        return scales

    prepare_jit = jax.jit(prepare_params)
    capture_jit = jax.jit(capture_scales)

    def freeze(prepared, scales, alpha: float = 1.0):
        """Merge captured scales into the prepared tree as frozen
        ``x_scale`` entries.  ``alpha < 1`` blends against an existing
        frozen scale (s' = (1-alpha)*s + alpha*s_batch) — the EMA
        refinement step the serving layer runs on live batches; scales
        are linear in the captured amplitude, so blending scales directly
        is the EMA over amplitudes."""
        out = dict(prepared)
        for name, site_scales in scales.items():
            mod_prepared = dict(out[name])
            for site, s in site_scales.items():
                old = mod_prepared[site].get("x_scale")
                if old is not None and alpha < 1.0:
                    # blend on the host: old and s may live on different
                    # replicas' devices (capture runs on one replica, the
                    # refined tree lands on each), and the caller
                    # re-commits the tree to its placement afterwards
                    s = jnp.asarray((1.0 - alpha) * float(old)
                                    + alpha * float(s),
                                    dtype=jnp.asarray(s).dtype)
                mod_prepared[site] = {**mod_prepared[site], "x_scale": s}
            out[name] = mod_prepared
        return out

    def prepare(params, calib_x=None):
        prepared = prepare_jit(params)
        if not needs_calibration:
            return prepared
        if calib_x is None:
            raise ValueError(
                "plans request calibration (Plan.calibrate=True): prepare "
                "needs a calibration batch (prepare(params, calib_x=...))")
        return freeze(prepared, capture_jit(prepared, calib_x))

    def run(prepared, x):
        for name, lm in lowered:
            x = lm.run(prepared[name], x)
        return x.reshape(x.shape[0], -1)

    ema_modules = frozenset(name for name, lm in lowered
                            if lm.ir.calib_sites
                            and lm.ir.calibrator == "ema")
    return LoweredNetwork(prepare, run, needs_calibration, stages,
                          capture_jit, freeze, ema_modules)
