"""Lowering rules: (ModuleGraph, Plan) -> a jit-traceable program.

This is the compile-time half of the heterogeneous engine
(``repro.core.executor`` owns the cache and the public API).  Each module is
lowered once into a list of node *steps* — Python closures over static
metadata — which the executor unrolls inside a single ``jax.jit`` trace, plus
a *prepare* function that transforms the raw fp32 parameter tree once at
compile time (weight quantization happens here, never per call).

Lowering rules, in priority order per node:

  1. **Fused FPGA chain** (DHM analogue): inside a plan's ``fused`` tuple, a
     ``dwconv`` (k=3, stride 1, relu6) immediately followed by its consumer
     ``pwconv`` lowers to the ``fused_block`` Pallas kernel — the depthwise
     intermediate stays VMEM-resident, exactly like DHM keeps inter-layer
     maps inside the FPGA fabric.  Weights are fake-quantized at prepare
     time (per-out-channel int8 grid); the activation entering the chain is
     fake-quantized at run time.
  2. **True-int8 FPGA GEMM**: every FPGA-assigned groups==1 conv (any k,
     via im2col) and ``fc`` node lowers to ``int8_gemm`` — weights are
     quantized ONCE at prepare time and kept resident as int8 (+
     per-channel scale); only the per-sample activation quantization
     remains in the hot path.  This replaces the interpreter's per-call
     ``fake_quant`` round trip, and the order-exact int32 accumulation
     makes the heavy FPGA layers batch-invariant with no row tiling.
  3. **GConv split** (paper Fig. 2b): a node with a ``gconv`` fraction lowers
     to a SINGLE concatenated conv — the FPGA slice's input channels and
     weights are fake-quantized (weights at prepare time), concatenated with
     the fp32 GPU slice, and convolved in one ``conv_general_dilated`` call
     (convolution is linear in input channels, so this equals the summed
     partials).
  4. **Quantized FPGA conv**: remaining FPGA-assigned convs (depthwise /
     grouped) keep the shift-add / XLA conv path with weights
     fake-quantized at prepare time.
  5. **GPU nodes** keep the fp32 XLA path unchanged.

``use_pallas=False`` swaps rules 1-2 onto their pure-XLA reference kernels
(the right choice on CPU, where Pallas runs in interpret mode); the lowered
program and prepared parameters are identical either way.

**Batch invariance** (the serving contract): every run-time step is
row-independent in the batch dimension, so row ``i`` of a batched call is
bit-identical to the same image run alone.  Three rules enforce this:
activation quantization is per-sample (``axis=0`` — scales never couple
requests sharing a batch); the int8 GEMM accumulates order-exactly (int32
on TPU, exact-below-2^24 fp32 on CPU), so the heavy FPGA layers are
invariant for free; and the remaining fp32 GEMMs — including every
groups==1 conv, lowered via im2col — run in fixed row tiles
(``_rowsafe_matmul``) because XLA:CPU picks gemm blocking from the full
operand shapes and different blockings round differently.  ``repro.serving``
relies on this to pad requests into bucket-sized batches without
perturbing anyone's logits; ``tests/test_serving.py`` holds the line.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.costmodel import ConvSpec
from repro.core.graph import ModuleGraph, Node
from repro.core.hetero import apply_act
from repro.core.schedule import Plan
from repro.kernels.fused_block.ops import fused_block
from repro.kernels.int8_gemm.ops import int8_gemm
from repro.quant import fake_quant, quantize


# --------------------------------------------------------------------------
# node-level step builders: each returns (prepare(params_node) -> prepared,
#                                         run(prepared, x) -> y)
# --------------------------------------------------------------------------

_ROW_TILE = 8


def _rowsafe_matmul(a, w, tile: int = _ROW_TILE):
    """a (M,K) @ w (K,N) computed in fixed (tile,K)@(K,N) row blocks.

    XLA:CPU picks gemm strategy (threading, cache blocking, small-M
    kernels) from the FULL operand shapes, and different K-panel groupings
    round differently — so row i of an (M,K) gemm is NOT bit-stable across
    M.  Padding M to a tile multiple and mapping the same fixed-shape gemm
    over row blocks pins the strategy, making every row's accumulation
    chain a function of that row alone.  This is what lets ``repro.serving``
    promise batch-size-independent logits.  Zero pad rows never enter a
    real row's chain; ``tile`` trades scan overhead (small tile, small M)
    against lost inter-block threading (large tile, large M)."""
    M, K = a.shape
    mp = -(-M // tile) * tile
    ap = jnp.pad(a, ((0, mp - M), (0, 0)))
    if mp == tile:
        return (ap @ w)[:M]
    _, out = jax.lax.scan(lambda c, t: (c, t @ w), None,
                          ap.reshape(-1, tile, K), unroll=4)
    return out.reshape(mp, -1)[:M]


def _same_taps(x, k: int, s: int, fill=0.0):
    """SAME-pad x (NHWC) for a k*k/stride-s window (XLA's lo=total//2 split)
    and yield the k*k shifted strided (B,Ho,Wo,C) slices — the building
    block for the shift-and-add conv/pool lowerings below."""
    H, W = x.shape[1], x.shape[2]
    ho, wo = -(-H // s), -(-W // s)
    ph = max((ho - 1) * s + k - H, 0)
    pw = max((wo - 1) * s + k - W, 0)
    xp = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                     (pw // 2, pw - pw // 2), (0, 0)),
                 constant_values=fill)
    return [(dy, dx, xp[:, dy:dy + (ho - 1) * s + 1:s,
                        dx:dx + (wo - 1) * s + 1:s, :])
            for dy in range(k) for dx in range(k)]


def _dw_shift_add(w, x, k: int, s: int):
    """Depthwise conv (multiplier 1) as k*k unrolled shift-and-adds — the
    dataflow of the Pallas fused kernel, and far faster than XLA's generic
    grouped-conv lowering on CPU.  w: (k,k,C)."""
    acc = None
    for dy, dx, sl in _same_taps(x, k, s):
        term = sl * w[dy, dx]
        acc = term if acc is None else acc + term
    return acc


def _xla_conv(spec: ConvSpec, act: str):
    if spec.kind == "dwconv" and spec.c_out == spec.c_in and spec.k <= 5:
        def run(p, x):
            y = _dw_shift_add(p["w"].reshape(spec.k, spec.k, -1), x,
                              spec.k, spec.stride)
            return apply_act(y + p["b"], act)
        return run
    groups = spec.c_in if spec.kind == "dwconv" else spec.groups
    if groups == 1:
        # im2col + fixed-tile GEMM rather than conv_general_dilated: the
        # row-tiled GEMM is batch-invariant (see _rowsafe_matmul) where
        # XLA:CPU's conv — itself a gemm over B*Ho*Wo rows — is not, and
        # for the small late-stage maps it also dodges conv's fixed per-op
        # cost.  The tile is a function of the spatial size only, so every
        # batch size lowers to the same per-block gemm shape.
        def run(p, x):
            y = _conv_im2col(x, p["w"], spec.k, spec.stride)
            return apply_act(y + p["b"], act)
        return run

    def run(p, x):
        # grouped-conv fallback; unused by the paper networks (their only
        # grouped convs are depthwise, handled by the shift-add path) and
        # NOT batch-invariant — keep new graphs off this path if they are
        # to be served batched
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(spec.stride, spec.stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
        return apply_act(y + p["b"], act)
    return run


def _spatial_tile(hw: int) -> int:
    """Row tile for a fp32 (B*Ho*Wo, K) GEMM: one sample's rows per tile,
    so batch 1 pays no padding and every batch size sees the same block
    shape.  Depends on the spatial size only — never on batch.  (The heavy
    FPGA layers take the int8 GEMM path instead, which is order-exact and
    needs no tiling; fp32 tiles only carry the cheap GPU-side glue.)"""
    return -(-hw // _ROW_TILE) * _ROW_TILE


def _conv_im2col(x, w, k: int, s: int):
    """SAME conv as a row-tiled (B*Ho*Wo, k*k*C) @ (k*k*C, Co) GEMM."""
    C, co = x.shape[-1], w.shape[-1]
    if k == 1 and s == 1:
        cols = x
    else:
        cols = jnp.concatenate([sl for _dy, _dx, sl in _same_taps(x, k, s)],
                               axis=-1)
    y = _rowsafe_matmul(cols.reshape(-1, k * k * C), w.reshape(-1, co),
                        tile=_spatial_tile(cols.shape[1] * cols.shape[2]))
    return y.reshape(*cols.shape[:3], co)


def _lower_gpu(n: Node):
    if n.spec.kind == "fc":
        def run(p, x):
            y = _rowsafe_matmul(x.reshape(x.shape[0], -1), p["w"])
            return apply_act(y + p["b"], n.act)
    else:
        run = _xla_conv(n.spec, n.act)
    return (lambda p: {"w": p["w"], "b": p["b"]}), run


def _lower_fpga_fq(n: Node):
    """FPGA conv that cannot use the int8 GEMM: weights fake-quantized once
    at prepare time, activation fake-quantized per call (per-sample scales:
    batching must not change any request's numerics), XLA conv."""
    conv = _xla_conv(n.spec, n.act)

    def prepare(p):
        return {"w": fake_quant(p["w"], axis=-1), "b": p["b"]}

    def run(p, x):
        return conv(p, fake_quant(x, axis=0))
    return prepare, run


def _lower_fpga_int8(n: Node, use_pallas: bool):
    """True-int8 path: any groups==1 FPGA conv (via im2col) or fc as an
    int8 GEMM with resident int8 weights.  The int32 accumulation is
    order-exact, so this path is batch-invariant with full cross-sample
    vectorization — no row tiling needed — and it is the faithful DHM
    substrate: the FPGA computes in 8-bit fixed point end to end."""
    spec = n.spec

    def prepare(p):
        w2d = p["w"].reshape(-1, spec.c_out)   # (k*k*C, co) for convs
        w_q, w_s = quantize(w2d, axis=-1)
        return {"w_q": w_q, "w_s": w_s.reshape(-1), "b": p["b"]}

    def run(p, x):
        # per-sample activation scales (axis=0): each request in a served
        # batch quantizes exactly as it would alone
        x_q4, x_s4 = quantize(x, axis=0)
        if spec.kind == "fc":
            y = int8_gemm(x_q4.reshape(x.shape[0], -1), p["w_q"],
                          x_s4.reshape(x.shape[0], 1), p["w_s"],
                          use_pallas=use_pallas)
            return apply_act(y + p["b"], n.act)
        if spec.k == 1 and spec.stride == 1:
            cols = x_q4
        else:
            cols = jnp.concatenate(
                [sl for _dy, _dx, sl in
                 _same_taps(x_q4, spec.k, spec.stride, fill=0)], axis=-1)
        lead = cols.shape[:3]
        x_s = jnp.broadcast_to(x_s4, (*lead, 1)).reshape(-1, 1)
        y = int8_gemm(cols.reshape(-1, cols.shape[-1]), p["w_q"], x_s,
                      p["w_s"], use_pallas=use_pallas)
        y = (y + p["b"]).reshape(*lead, spec.c_out)
        return apply_act(y, n.act)
    return prepare, run


def _lower_fused_pair(dw: Node, pw: Node, use_pallas: bool):
    """dw3x3(relu6) + pw1x1 through the fused_block Pallas kernel; the
    intermediate never leaves VMEM (no fake-quant round trip between the
    stages — the DHM on-chip residency semantics)."""
    def prepare(p_dw, p_pw):
        dw_w = fake_quant(p_dw["w"].reshape(3, 3, -1), axis=-1)
        pw_w = fake_quant(p_pw["w"].reshape(-1, pw.spec.c_out), axis=-1)
        return {"dw_w": dw_w, "dw_b": p_dw["b"],
                "pw_w": pw_w, "pw_b": p_pw["b"]}

    if use_pallas:
        def run(p, x):
            y = fused_block(fake_quant(x, axis=0), p["dw_w"], p["dw_b"],
                            p["pw_w"], p["pw_b"], use_pallas=True)
            return apply_act(y, pw.act)
    else:
        def run(p, x):
            # same fused dataflow in plain XLA: shift-add dw, relu6, one GEMM
            x = fake_quant(x, axis=0)
            h = jnp.clip(_dw_shift_add(p["dw_w"], x, 3, 1) + p["dw_b"],
                         0.0, 6.0)
            y = _rowsafe_matmul(h.reshape(-1, h.shape[-1]), p["pw_w"],
                                tile=_spatial_tile(h.shape[1] * h.shape[2]))
            y = y + p["pw_b"]
            return apply_act(y.reshape(*h.shape[:-1], pw.spec.c_out), pw.act)
    return prepare, run


def _lower_gconv(n: Node, frac: float):
    """Paper Fig. 2b input-channel split, lowered to ONE concatenated conv:
    channels [:g] carry the FPGA's quantized slice, [g:] the GPU's fp32
    slice; linearity in input channels makes the single conv equal the
    summed partials."""
    spec = n.spec
    g = max(1, int(round(spec.c_in * frac)))
    conv = _xla_conv(spec, n.act)

    def prepare(p):
        w = p["w"]
        w_cat = jnp.concatenate(
            [fake_quant(w[..., :g, :], axis=-1), w[..., g:, :]], axis=-2)
        return {"w": w_cat, "b": p["b"]}

    def run(p, x):
        x_cat = jnp.concatenate([fake_quant(x[..., :g], axis=0), x[..., g:]],
                                axis=-1)
        return conv(p, x_cat)
    return prepare, run


def _pool_shift(x, k: int, s: int, fill, combine):
    """Pooling as k*k shifted strided slices combined elementwise — the
    same trick as ``_dw_shift_add``; XLA:CPU's ``reduce_window`` is a
    fixed-cost scalar loop that dwarfs the actual work."""
    acc = None
    for _dy, _dx, sl in _same_taps(x, k, s, fill=fill):
        acc = sl if acc is None else combine(acc, sl)
    return acc


def _lower_pointfree(n: Node):
    """Parameter-free ops (pool/gap/concat/add/split/shuffle)."""
    spec = n.spec
    kind = spec.kind
    if kind == "maxpool":
        return lambda xs: _pool_shift(xs[0], spec.k, spec.stride,
                                      -jnp.inf, jnp.maximum)
    if kind == "avgpool":
        def run(xs):
            s = _pool_shift(xs[0], spec.k, spec.stride, 0.0, jnp.add)
            return s / (spec.k * spec.k)
        return run
    if kind == "gap":
        return lambda xs: xs[0].mean(axis=(1, 2), keepdims=True)
    if kind == "concat":
        return lambda xs: jnp.concatenate(xs, axis=-1)
    if kind == "add":
        return lambda xs: xs[0] + xs[1]
    if kind == "split":
        return lambda xs: xs[0][..., :spec.c_out]
    if kind == "shuffle":
        def run(xs):
            x = xs[0]
            b, h, w, c = x.shape
            return (x.reshape(b, h, w, 2, c // 2)
                    .transpose(0, 1, 2, 4, 3).reshape(b, h, w, c))
        return run
    raise ValueError(kind)


# --------------------------------------------------------------------------
# module-level lowering
# --------------------------------------------------------------------------

_CONVISH = ("conv", "dwconv", "pwconv", "fc")


def _fused_pairs(m: ModuleGraph, plan: Plan | None) -> dict[str, str]:
    """dw->pw pairs inside the plan's fused chain that fused_block can take:
    dw3x3 stride 1 with relu6, immediately consumed by a 1x1 pwconv."""
    if not plan or not plan.fused:
        return {}
    pairs: dict[str, str] = {}
    names = [nm for nm in plan.fused if any(n.name == nm for n in m.nodes)]
    for a_nm, b_nm in zip(names, names[1:]):
        a, b = m.node(a_nm), m.node(b_nm)
        sole_consumer = all(a.name not in n.inputs for n in m.nodes
                            if n.name != b.name)
        if (a.spec.kind == "dwconv" and a.spec.k == 3 and a.spec.stride == 1
                and a.act == "relu6" and b.spec.kind == "pwconv"
                and b.spec.k == 1 and b.spec.stride == 1
                and b.inputs == (a.name,) and sole_consumer
                and a.name not in pairs.values()):
            pairs[a.name] = b.name
    return pairs


def lower_module(m: ModuleGraph, plan: Plan | None, use_pallas: bool):
    """Returns (prepare(params_m) -> prepared_m, run(prepared_m, x) -> y)."""
    assign = plan.assign if plan else {}
    gconv = plan.gconv if plan else {}
    pairs = _fused_pairs(m, plan)
    consumed = set(pairs.values())

    preps: dict[str, Callable] = {}
    # steps: (value_name, kind, payload) unrolled in node order at trace time
    steps: list[tuple] = []
    for n in m.nodes:
        if m.kind == "shuffle_unit" and n.name in ("split", "cat"):
            steps.append((n.name, "shuffle_glue", None))
            continue
        if n.name in consumed:
            continue                       # produced by the fused pair step
        if n.spec.kind in _CONVISH:
            fpga = assign.get(n.name) == "fpga"
            if n.name in pairs:
                pw = m.node(pairs[n.name])
                prep, run = _lower_fused_pair(n, pw, use_pallas)
                preps[n.name] = prep
                steps.append((pairs[n.name], "fused", (n.name, n.inputs, run)))
                continue
            if n.name in gconv:
                prep, run = _lower_gconv(n, gconv[n.name])
            elif fpga and (n.spec.kind == "fc"
                           or (n.spec.kind in ("conv", "pwconv")
                               and n.spec.groups == 1)):
                prep, run = _lower_fpga_int8(n, use_pallas)
            elif fpga:
                prep, run = _lower_fpga_fq(n)
            else:
                prep, run = _lower_gpu(n)
            preps[n.name] = prep
            steps.append((n.name, "param", (n.name, n.inputs, run)))
        else:
            steps.append((n.name, "free", (n.inputs, _lower_pointfree(n))))

    def prepare(params_m):
        out = {}
        for nm, prep in preps.items():
            if nm in pairs:                # fused pair: two raw param leaves
                out[nm] = prep(params_m[nm], params_m[pairs[nm]])
            else:
                out[nm] = prep(params_m[nm])
        return out

    def run(prepared_m, x):
        values = {"in": x}
        for out_name, kind, payload in steps:
            if kind == "shuffle_glue":
                if out_name == "split":
                    half = m.node("split").spec.c_out
                    values["split"] = x[..., half:]
                    values["_identity"] = x[..., :half]
                else:
                    values["cat"] = jnp.concatenate(
                        [values["_identity"],
                         values[m.node("cat").inputs[1]]], axis=-1)
                continue
            if kind == "free":
                inputs, fn = payload
                values[out_name] = fn([values[i] for i in inputs])
                continue
            pname, inputs, fn = payload
            values[out_name] = fn(prepared_m[pname], values[inputs[0]])
        out = values[m.output]
        if m.residual:
            out = out + x
        return out

    return prepare, run


def lower_network(mods: list[ModuleGraph], plans: list[Plan] | None,
                  use_pallas: bool):
    """Lower the whole network; returns (prepare(params) -> prepared,
    run(prepared, x) -> logits).  ``run`` is pure and jit-traceable: all
    routing decisions were burned in here, at lowering time."""
    plan_by = {p.module: p for p in plans} if plans else {}
    lowered = [(m.name, lower_module(m, plan_by.get(m.name), use_pallas))
               for m in mods]

    def prepare(params):
        return {name: prep(params[name]) for name, (prep, _run) in lowered}

    def run(prepared, x):
        for name, (_prep, run_m) in lowered:
            x = run_m(prepared[name], x)
        return x.reshape(x.shape[0], -1)

    return prepare, run
