"""Interpreted reference executor for heterogeneous plans.

Runs a ModuleGraph in JAX with substrate routing, node by node in Python:
"gpu" nodes compute in fp32/bf16; "fpga" nodes go through the paper's 8-bit
fixed-point path (per-channel weight + per-sample activation quantization,
via repro.quant — per-sample so a request's numerics are independent of its
batch-mates, the contract ``repro.serving`` batching relies on).  GConv splits execute both channel slices and sum partials
— so every Plan is runnable and testable against the monolithic fp32
network, not just priced.

This is deliberately the SLOW, readable oracle: unjitted, re-quantizing
weights on every call.  The production path is ``repro.core.executor``,
which lowers the same (modules, plans) pair once into a single jitted
callable and is parity-tested against ``run_network`` here.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import ConvSpec
from repro.core.graph import ModuleGraph, Node
from repro.core.schedule import Plan
from repro.quant import fake_quant


def apply_act(x, kind: str):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    return x


def _conv_params(key, spec: ConvSpec):
    cin_g = spec.c_in // spec.groups
    if spec.kind == "dwconv":
        shape = (spec.k, spec.k, 1, spec.c_out)
    elif spec.kind in ("conv", "pwconv"):
        shape = (spec.k, spec.k, cin_g, spec.c_out)
    elif spec.kind == "fc":
        shape = (spec.c_in, spec.c_out)
    else:
        return None
    fan_in = int(np.prod(shape[:-1]))
    w = jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)
    return {"w": w, "b": jnp.zeros((spec.c_out,), jnp.float32)}


def init_network(mods: list[ModuleGraph], key) -> dict:
    params: dict = {}
    for m in mods:
        # crc32, not hash(): builtin str hashing is salted per process, which
        # would make "identical" networks draw different weights across runs
        keys = jax.random.split(
            jax.random.fold_in(key, zlib.crc32(m.name.encode()) % 2**31),
            len(m.nodes))
        params[m.name] = {}
        for n, k in zip(m.nodes, keys):
            p = _conv_params(k, n.spec)
            if p is not None:
                params[m.name][n.name] = p
    return params


def _run_conv(n: Node, p, x, quantized: bool):
    spec = n.spec
    w = p["w"]
    if quantized:                       # the FPGA's 8-bit fixed point
        # per-sample activation scales (axis=0), matching the compiled
        # engine: a request's numerics never depend on its batch-mates
        x = fake_quant(x, axis=0)
        w = fake_quant(w, axis=-1)
    if spec.kind == "fc":
        y = x.reshape(x.shape[0], -1) @ w + p["b"]
        return apply_act(y, n.act)
    groups = spec.c_in if spec.kind == "dwconv" else spec.groups
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(spec.stride, spec.stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return apply_act(y + p["b"], n.act)


def _run_node(n: Node, params_m, values, assign, gconv):
    spec = n.spec
    xs = [values[i] for i in n.inputs]
    x = xs[0]
    if spec.kind in ("conv", "dwconv", "pwconv", "fc"):
        quantized = assign.get(n.name) == "fpga"
        if n.name in gconv:             # paper Fig.2b: input-channel split
            frac = gconv[n.name]
            g = max(1, int(round(spec.c_in * frac)))
            x_f, x_g = x[..., :g], x[..., g:]
            w = params_m[n.name]["w"]
            p_f = {"w": w[..., :g, :], "b": params_m[n.name]["b"]}
            p_g = {"w": w[..., g:, :], "b": jnp.zeros_like(params_m[n.name]["b"])}
            nf = Node(n.name, spec, n.inputs, "none")
            y = (_run_conv(nf, p_f, x_f, True)
                 + _run_conv(nf, p_g, x_g, False))
            return apply_act(y, n.act)
        return _run_conv(n, params_m[n.name], x, quantized)
    if spec.kind == "maxpool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, spec.k, spec.k, 1),
            (1, spec.stride, spec.stride, 1), "SAME")
    if spec.kind == "avgpool":
        s = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, spec.k, spec.k, 1),
            (1, spec.stride, spec.stride, 1), "SAME")
        return s / (spec.k * spec.k)
    if spec.kind == "gap":
        return x.mean(axis=(1, 2), keepdims=True)
    if spec.kind == "concat":
        return jnp.concatenate(xs, axis=-1)
    if spec.kind == "add":
        return xs[0] + xs[1]
    if spec.kind == "split":
        return x[..., :spec.c_out]      # "split" value = first half; the
                                        # builder wires the second half via
                                        # the same node (see concat inputs)
    if spec.kind == "shuffle":
        b, h, w_, c = x.shape
        return (x.reshape(b, h, w_, 2, c // 2).transpose(0, 1, 2, 4, 3)
                .reshape(b, h, w_, c))
    raise ValueError(spec.kind)


def run_module(m: ModuleGraph, params_m, x, plan: Plan | None = None):
    assign = plan.assign if plan else {}
    gconv = plan.gconv if plan else {}
    values = {"in": x}
    for n in m.nodes:
        if m.kind == "shuffle_unit" and n.name == "split":
            half = n.spec.c_out
            values["split"] = x[..., half:]
            values["_identity"] = x[..., :half]
            continue
        if m.kind == "shuffle_unit" and n.name == "cat":
            values["cat"] = jnp.concatenate(
                [values["_identity"], values[n.inputs[1]]], axis=-1)
            continue
        values[n.name] = _run_node(n, params_m, values, assign, gconv)
    out = values[m.output]
    if m.residual:
        out = out + x
    return out


def run_network(mods: list[ModuleGraph], params, x,
                plans: list[Plan] | None = None):
    plan_by = {p.module: p for p in plans} if plans else {}
    for m in mods:
        x = run_module(m, params[m.name], x, plan_by.get(m.name))
    return x.reshape(x.shape[0], -1)
