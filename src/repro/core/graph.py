"""Module-level dataflow IR — the granularity at which the paper partitions.

A network is a list of ``ModuleGraph``s (Fire module, MBv2 bottleneck,
ShuffleNetV2 unit, stem, head).  Each node carries a ``ConvSpec`` so the cost
models can price it on either substrate, and the same IR is executable in
JAX (``repro.core.hetero``) so partition plans are *runnable*, not just
priced.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.costmodel import ConvSpec


@dataclass(frozen=True)
class Node:
    name: str
    spec: ConvSpec
    inputs: tuple[str, ...]            # "in" = module input
    act: str = "none"                  # none | relu | relu6


@dataclass
class ModuleGraph:
    name: str
    kind: str                          # fire | bottleneck | shuffle_unit* | stem | head
    nodes: list[Node]
    output: str
    residual: bool = False             # bottleneck: add input to output

    def __post_init__(self):
        self._by_name = {n.name: n for n in self.nodes}

    def node(self, name: str) -> Node:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"{self.name}: no node {name!r}") from None

    def has_node(self, name: str) -> bool:
        return name in self._by_name

    def consumers(self, name: str) -> list[Node]:
        """Nodes reading ``name``'s value (computed from the cached map's
        node list, so it stays O(nodes) per call, not O(nodes^2) per scan)."""
        return [n for n in self.nodes if name in n.inputs]

    def total_macs(self) -> float:
        return sum(n.spec.macs for n in self.nodes)


def _conv(name, kind, h, w, cin, cout, k=1, s=1, groups=1, inputs=("in",),
          act="relu"):
    return Node(name, ConvSpec(kind, h, w, cin, cout, k, s, groups),
                tuple(inputs), act)


def make_divisible(v: float, d: int = 8) -> int:
    out = max(d, int(v + d / 2) // d * d)
    if out < 0.9 * v:
        out += d
    return out


# ---------------------------------------------------------------------------
# SqueezeNet v1.1 (paper workload #1)
# ---------------------------------------------------------------------------

def fire(name: str, h: int, c_in: int, squeeze: int, expand: int):
    """squeeze 1x1 -> [expand 1x1 || expand 3x3] -> concat."""
    return ModuleGraph(name, "fire", [
        _conv("squeeze", "pwconv", h, h, c_in, squeeze),
        _conv("exp1", "pwconv", h, h, squeeze, expand, inputs=("squeeze",)),
        _conv("exp3", "conv", h, h, squeeze, expand, k=3,
              inputs=("squeeze",)),
        Node("cat", ConvSpec("concat", h, h, 2 * expand, 2 * expand),
             ("exp1", "exp3")),
    ], "cat")


def squeezenet(num_classes: int = 1000) -> list[ModuleGraph]:
    mods = [ModuleGraph("stem", "stem", [
        _conv("conv1", "conv", 224, 224, 3, 64, k=3, s=2),
        Node("pool1", ConvSpec("maxpool", 112, 112, 64, 64, k=3, stride=2),
             ("conv1",)),
    ], "pool1")]
    mods += [fire("fire2", 56, 64, 16, 64), fire("fire3", 56, 128, 16, 64)]
    mods += [ModuleGraph("pool3", "stem", [
        Node("pool", ConvSpec("maxpool", 56, 56, 128, 128, k=3, stride=2),
             ("in",))], "pool")]
    mods += [fire("fire4", 28, 128, 32, 128), fire("fire5", 28, 256, 32, 128)]
    mods += [ModuleGraph("pool5", "stem", [
        Node("pool", ConvSpec("maxpool", 28, 28, 256, 256, k=3, stride=2),
             ("in",))], "pool")]
    mods += [fire("fire6", 14, 256, 48, 192), fire("fire7", 14, 384, 48, 192),
             fire("fire8", 14, 384, 64, 256), fire("fire9", 14, 512, 64, 256)]
    mods += [ModuleGraph("head", "head", [
        _conv("conv10", "pwconv", 14, 14, 512, num_classes),
        Node("gap", ConvSpec("gap", 14, 14, num_classes, num_classes),
             ("conv10",)),
    ], "gap")]
    return mods


# ---------------------------------------------------------------------------
# MobileNetV2 (0.5x) (paper workload #2)
# ---------------------------------------------------------------------------

def bottleneck(name: str, h: int, c_in: int, c_out: int, stride: int,
               expand_ratio: int):
    hidden = c_in * expand_ratio
    nodes = []
    src = "in"
    if expand_ratio != 1:
        nodes.append(_conv("pw_exp", "pwconv", h, h, c_in, hidden,
                           act="relu6"))
        src = "pw_exp"
    nodes.append(_conv("dw", "dwconv", h, h, hidden, hidden, k=3, s=stride,
                       groups=hidden, inputs=(src,), act="relu6"))
    h2 = h // stride
    nodes.append(_conv("pw_proj", "pwconv", h2, h2, hidden, c_out,
                       inputs=("dw",), act="none"))
    return ModuleGraph(name, "bottleneck", nodes, "pw_proj",
                       residual=(stride == 1 and c_in == c_out))


def mobilenetv2(width: float = 0.5, num_classes: int = 1000):
    cfgs = [  # t, c, n, s
        (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
        (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    c_stem = make_divisible(32 * width)
    mods = [ModuleGraph("stem", "stem", [
        _conv("conv1", "conv", 224, 224, 3, c_stem, k=3, s=2, act="relu6")],
        "conv1")]
    h, c_in = 112, c_stem
    idx = 0
    for t, c, n, s in cfgs:
        c_out = make_divisible(c * width)
        for i in range(n):
            stride = s if i == 0 else 1
            mods.append(bottleneck(f"bneck{idx}", h, c_in, c_out, stride, t))
            h //= stride
            c_in = c_out
            idx += 1
    c_last = make_divisible(1280 * max(1.0, width))
    mods.append(ModuleGraph("head", "head", [
        _conv("conv_last", "pwconv", h, h, c_in, c_last, act="relu6"),
        Node("gap", ConvSpec("gap", h, h, c_last, c_last), ("conv_last",)),
        _conv("fc", "fc", 1, 1, c_last, num_classes, inputs=("gap",),
              act="none"),
    ], "fc"))
    return mods


# ---------------------------------------------------------------------------
# ShuffleNetV2 (0.5x) (paper workload #3)
# ---------------------------------------------------------------------------

def shuffle_unit(name: str, h: int, c: int, downsample: bool):
    """ShuffleNetV2 basic/down unit.  c = output channels (split in half)."""
    half = c // 2
    if downsample:
        # branch1: dw3x3/2 -> pw ; branch2: pw -> dw3x3/2 -> pw ; concat
        cin = c // 2  # input channels (stage input = half of output width)
        h2 = h // 2
        nodes = [
            _conv("b1_dw", "dwconv", h, h, cin, cin, k=3, s=2, groups=cin,
                  act="none"),
            _conv("b1_pw", "pwconv", h2, h2, cin, half, inputs=("b1_dw",)),
            _conv("b2_pw1", "pwconv", h, h, cin, half),
            _conv("b2_dw", "dwconv", h, h, half, half, k=3, s=2, groups=half,
                  inputs=("b2_pw1",), act="none"),
            _conv("b2_pw2", "pwconv", h2, h2, half, half, inputs=("b2_dw",)),
            Node("cat", ConvSpec("concat", h2, h2, c, c),
                 ("b1_pw", "b2_pw2")),
            Node("shuffle", ConvSpec("shuffle", h2, h2, c, c), ("cat",)),
        ]
        return ModuleGraph(name, "shuffle_unit_down", nodes, "shuffle")
    nodes = [
        Node("split", ConvSpec("split", h, h, c, half), ("in",)),
        _conv("b2_pw1", "pwconv", h, h, half, half, inputs=("split",)),
        _conv("b2_dw", "dwconv", h, h, half, half, k=3, groups=half,
              inputs=("b2_pw1",), act="none"),
        _conv("b2_pw2", "pwconv", h, h, half, half, inputs=("b2_dw",)),
        Node("cat", ConvSpec("concat", h, h, c, c), ("split", "b2_pw2")),
        Node("shuffle", ConvSpec("shuffle", h, h, c, c), ("cat",)),
    ]
    return ModuleGraph(name, "shuffle_unit", nodes, "shuffle")


def shufflenetv2(width: float = 0.5, num_classes: int = 1000):
    stage_c = {0.5: (48, 96, 192, 1024), 1.0: (116, 232, 464, 1024)}[width]
    mods = [ModuleGraph("stem", "stem", [
        _conv("conv1", "conv", 224, 224, 3, 24, k=3, s=2),
        Node("pool1", ConvSpec("maxpool", 112, 112, 24, 24, k=3, stride=2),
             ("conv1",)),
    ], "pool1")]
    h, c_in = 56, 24
    for si, (c, reps) in enumerate(zip(stage_c[:3], (4, 8, 4))):
        # NB: the down unit's builder takes input channels = c//2; ShuffleNetV2
        # down-units actually take the previous stage width — we keep the
        # module-level MAC budget equivalent (paper partitions per unit).
        mods.append(shuffle_unit(f"stage{si+2}_down", h, c, True))
        h //= 2
        for i in range(reps - 1):
            mods.append(shuffle_unit(f"stage{si+2}_u{i+1}", h, c, False))
        c_in = c
    mods.append(ModuleGraph("head", "head", [
        _conv("conv5", "pwconv", h, h, c_in, stage_c[3]),
        Node("gap", ConvSpec("gap", h, h, stage_c[3], stage_c[3]),
             ("conv5",)),
        _conv("fc", "fc", 1, 1, stage_c[3], num_classes, inputs=("gap",),
              act="none"),
    ], "fc"))
    return mods


NETWORKS = {
    "squeezenet": squeezenet,
    "mobilenetv2": lambda: mobilenetv2(0.5),
    "shufflenetv2": lambda: shufflenetv2(0.5),
}
