"""Compiled heterogeneous inference engine: jit-once plan execution.

The interpreter in ``repro.core.hetero`` walks a ``(modules, plans)`` pair
node by node in Python, re-quantizing FPGA weights on every call — correct,
readable, slow.  This module is the production path: it lowers the same pair
ONCE into a single end-to-end ``jax.jit``-compiled callable and caches the
result under a hashable *plan signature*, so repeated calls (and repeated
``compile_network`` invocations with an equivalent plan) never re-trace.

API::

    engine   = compile_network(mods, plans)      # cached by plan signature
    prepared = engine.prepare(params)            # one-time: quantize FPGA
                                                 # weights -> resident int8
    logits   = engine(prepared, x)               # single jitted call

Plans that opted into prepare-time calibration (``Plan.calibrate``) freeze
their activation scales from a calibration batch::

    prepared = engine.prepare(params, calib_x=calib_batch)

``prepare`` is the compile-time half of the paper's DHM story: FPGA-assigned
weights leave fp32 exactly once (int8 + per-channel scale for the GEMM path,
fake-quantized grids for the fused/conv paths) and stay resident across
calls, the analogue of weights living in FPGA logic.  ``engine(prepared, x)``
is a pure function of arrays — no Python dispatch, no per-call quantization.

Lowering goes through the ``repro.core.passes`` pipeline (annotate ->
fuse -> calibrate -> backend; full detail in the README):

  - fused FPGA chains ([pw1x1 ->] dw3x3/stride -> pw1x1, stride 1 or 2)
                                   -> ``fused_chain`` Pallas kernel
                                      (VMEM-resident intermediates)
  - FPGA pwconv / fc               -> ``int8_gemm`` with resident int8
                                      weights quantized at prepare time
  - gconv input-channel splits     -> one concatenated XLA conv
  - other FPGA convs               -> XLA conv, weights fake-quantized at
                                      prepare time
  - GPU nodes                      -> unchanged fp32 XLA path

``use_pallas`` defaults to auto: Pallas kernels on TPU/GPU backends, their
pure-XLA reference implementations on CPU (where Pallas only interprets).
The interpreted ``hetero.run_network`` remains the oracle the engine is
parity-tested against (``tests/test_executor.py``).
"""
from __future__ import annotations

import threading
from dataclasses import astuple

import jax
import jax.numpy as jnp

from repro.core.graph import ModuleGraph
from repro.core.lowering import lower_network
from repro.core.passes import chain_groups
from repro.core.schedule import Plan


def _default_use_pallas() -> bool:
    return jax.default_backend() != "cpu"


def plan_signature(mods: list[ModuleGraph], plans: list[Plan] | None,
                   use_pallas: bool) -> tuple:
    """Hashable signature of everything lowering depends on: the graph
    topology/specs, each plan's routing decisions, the fused chains the
    fusion pass will actually form, and the calibration choice.  Two equal
    signatures lower to byte-identical programs, so the compile cache may
    share them — and calibrated plans NEVER alias uncalibrated ones (their
    numerics differ)."""
    plan_by = {p.module: p for p in plans} if plans else {}
    sig = []
    for m in mods:
        p = plan_by.get(m.name)
        if p:
            fused_sig = tuple(tuple(n.name for n in g)
                              for g in chain_groups(m, p) if len(g) > 1)
            psig = (p.scheme, tuple(sorted(p.assign.items())),
                    tuple(p.fused), tuple(sorted(p.gconv.items())),
                    fused_sig, bool(p.calibrate))
        else:
            psig = None
        sig.append((m.name, m.kind, m.output, m.residual,
                    tuple((n.name, astuple(n.spec), n.inputs, n.act)
                          for n in m.nodes),
                    psig))
    return (use_pallas, tuple(sig))


class CompiledNetwork:
    """A (modules, plans) pair lowered and jitted once.  Call ``prepare``
    once per parameter tree, then treat the instance as the forward fn.

    ``jax.jit`` still traces once per distinct input SHAPE — a serving
    layer that pads requests into bucket-sized batches should ``warmup``
    each bucket shape ahead of traffic so no live request ever pays a
    trace.  ``exec_stats`` surfaces that accounting (one "trace" per new
    shape, everything after is a cache hit inside jit)."""

    def __init__(self, mods: list[ModuleGraph], plans: list[Plan] | None,
                 use_pallas: bool):
        self.signature = plan_signature(mods, plans, use_pallas)
        self.use_pallas = use_pallas
        self.generation = _GENERATION[0]
        lowered = lower_network(mods, plans, use_pallas)
        self._prepare_fn = lowered.prepare      # jits its own internals
        self.needs_calibration = lowered.needs_calibration
        self._jitted = jax.jit(lowered.run)
        self._shapes_seen: set = set()
        self._exec = {"calls": 0, "traces": 0}
        # cached engines are shared across threads (serving drain loop +
        # direct callers); keep the accounting race-free
        self._stats_lock = threading.Lock()

    def prepare(self, params, calib_x=None) -> dict:
        """One-time parameter lowering: FPGA weights quantized here (int8
        resident for the GEMM path), GPU weights passed through.  When the
        plans opted into calibration (``needs_calibration``), a calibration
        batch is required and activation scales are frozen from it."""
        return self._prepare_fn(params, calib_x)

    def __call__(self, prepared, x):
        key = (tuple(x.shape), str(getattr(x, "dtype", "f32")))
        with self._stats_lock:
            if key not in self._shapes_seen:
                self._shapes_seen.add(key)
                self._exec["traces"] += 1
            self._exec["calls"] += 1
        return self._jitted(prepared, x)

    def warmup(self, prepared, shapes) -> dict:
        """Trace/compile each input shape once on zeros (per-bucket compile
        warm-up for the serving path).  Returns ``exec_stats()``."""
        for s in shapes:
            jax.block_until_ready(self(prepared, jnp.zeros(s, jnp.float32)))
        return self.exec_stats()

    def exec_stats(self) -> dict:
        with self._stats_lock:
            return dict(self._exec)

    def is_current(self) -> bool:
        """False once ``clear_cache`` ran after this engine was built —
        a serving layer holding the instance should re-``compile_network``
        (the engine itself keeps working; this only flags staleness)."""
        return self.generation == _GENERATION[0]


_CACHE: dict[tuple, CompiledNetwork] = {}
_STATS = {"hits": 0, "misses": 0}
_GENERATION = [0]       # bumped by clear_cache; engines stamp it at build


def compile_network(mods: list[ModuleGraph], plans: list[Plan] | None = None,
                    *, use_pallas: bool | None = None,
                    cache: bool = True) -> CompiledNetwork:
    """Compile (or fetch from cache) the engine for this (modules, plans)
    pair.  ``plans=None`` compiles the all-GPU fp32 network."""
    if use_pallas is None:
        use_pallas = _default_use_pallas()
    sig = plan_signature(mods, plans, use_pallas)
    if cache and sig in _CACHE:
        _STATS["hits"] += 1
        return _CACHE[sig]
    _STATS["misses"] += 1
    eng = CompiledNetwork(mods, plans, use_pallas)
    if cache:
        _CACHE[sig] = eng
    return eng


def cache_stats() -> dict:
    return {"size": len(_CACHE), "generation": _GENERATION[0], **_STATS}


def clear_cache() -> None:
    """Drop all cached engines and invalidate live ones (their
    ``is_current`` flips false; holders decide when to recompile)."""
    _CACHE.clear()
    _STATS.update(hits=0, misses=0)
    _GENERATION[0] += 1
