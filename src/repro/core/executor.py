"""Compiled heterogeneous inference engine: jit-once plan execution.

The interpreter in ``repro.core.hetero`` walks a ``(modules, plans)`` pair
node by node in Python, re-quantizing FPGA weights on every call — correct,
readable, slow.  This module is the production path: it lowers the same pair
ONCE into a single end-to-end ``jax.jit``-compiled callable and caches the
result under a hashable *plan signature*, so repeated calls (and repeated
``compile_network`` invocations with an equivalent plan) never re-trace.

API::

    engine   = compile_network(mods, plans)      # cached by plan signature
    prepared = engine.prepare(params)            # one-time: quantize FPGA
                                                 # weights -> resident int8
    logits   = engine(prepared, x)               # single jitted call

    pipe = compile_pipelined(mods, plans)        # stage-pipelined variant:
    logits = pipe(prepared, x)                   #  same bits, cut at every
    outs = pipe.run_many(prepared, xs, depth=4)  #  FPGA<->GPU boundary so
                                                 #  micro-batches overlap

    rset = ReplicaSet(engine, mesh)              # data-parallel striping:
    prepared = rset.prepare(params)              #  one prepared copy per
    logits = rset(prepared, x, replica=1)        #  data-axis replica, ONE
                                                 #  shared generation stamp

Plans that opted into prepare-time calibration (``Plan.calibrate``) freeze
their activation scales from a calibration batch::

    prepared = engine.prepare(params, calib_x=calib_batch)

``prepare`` is the compile-time half of the paper's DHM story: FPGA-assigned
weights leave fp32 exactly once (int8 + per-channel scale for the GEMM path,
fake-quantized grids for the fused/conv paths) and stay resident across
calls, the analogue of weights living in FPGA logic.  ``engine(prepared, x)``
is a pure function of arrays — no Python dispatch, no per-call quantization.

Lowering goes through the ``repro.core.passes`` pipeline (annotate ->
fuse -> calibrate -> backend; full detail in the README):

  - fused FPGA chains ([pw1x1 ->] dw3x3/stride -> pw1x1, stride 1 or 2)
                                   -> ``fused_chain`` Pallas kernel
                                      (VMEM-resident intermediates)
  - FPGA pwconv / fc               -> ``int8_gemm`` with resident int8
                                      weights quantized at prepare time
  - gconv input-channel splits     -> one concatenated XLA conv
  - other FPGA convs               -> XLA conv, weights fake-quantized at
                                      prepare time
  - GPU nodes                      -> unchanged fp32 XLA path

``use_pallas`` defaults to auto: Pallas kernels on TPU/GPU backends, their
pure-XLA reference implementations on CPU (where Pallas only interprets).
The interpreted ``hetero.run_network`` remains the oracle the engine is
parity-tested against (``tests/test_executor.py``).
"""
from __future__ import annotations

import threading
import time
import warnings
from collections.abc import Mapping
from contextlib import contextmanager, nullcontext
from dataclasses import astuple

import jax
import jax.numpy as jnp

from repro.core.graph import ModuleGraph
from repro.core.lowering import lower_network
from repro.core.passes import chain_groups
from repro.core.schedule import Plan
from repro.runtime import faults


def _default_use_pallas() -> bool:
    return jax.default_backend() != "cpu"


def plan_devices(plans: list[Plan] | None) -> tuple:
    """The device set a (modules, plans) pair touches — ("gpu",) for the
    all-GPU baseline.  Reported to the fault-injection site so rules
    pinned to ``device="fpga"`` fire on hybrid engines but never on the
    GPU-only fallback plan."""
    devs = {"gpu"}
    for p in plans or []:
        devs.update(p.assign.values())
    return tuple(sorted(devs))


@contextmanager
def _quiet_donation():
    """Scope-limited filter for jax's trace-time "donated buffers were not
    usable" warning: donation is best-effort by design here — buffers whose
    shape matches no computation output simply are not reused, which is not
    actionable for callers.  Applied only around first-trace dispatches so
    steady-state calls pay no filter-manipulation cost."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def plan_signature(mods: list[ModuleGraph], plans: list[Plan] | None,
                   use_pallas: bool) -> tuple:
    """Hashable signature of everything lowering depends on: the graph
    topology/specs, each plan's routing decisions, the fused chains the
    fusion pass will actually form, and the calibration choice.  Two equal
    signatures lower to byte-identical programs, so the compile cache may
    share them — and calibrated plans NEVER alias uncalibrated ones (their
    numerics differ)."""
    plan_by = {p.module: p for p in plans} if plans else {}
    sig = []
    for m in mods:
        p = plan_by.get(m.name)
        if p:
            fused_sig = tuple(tuple(n.name for n in g)
                              for g in chain_groups(m, p) if len(g) > 1)
            psig = (p.scheme, tuple(sorted(p.assign.items())),
                    tuple(p.fused), tuple(sorted(p.gconv.items())),
                    fused_sig, p.calibrator)
        else:
            psig = None
        sig.append((m.name, m.kind, m.output, m.residual,
                    tuple((n.name, astuple(n.spec), n.inputs, n.act)
                          for n in m.nodes),
                    psig))
    return (use_pallas, tuple(sig))


_PREPARE_GEN = [0]                  # process-global monotonic prepare stamp
_PREPARE_GEN_LOCK = threading.Lock()


def _next_prepare_generation() -> int:
    with _PREPARE_GEN_LOCK:
        _PREPARE_GEN[0] += 1
        return _PREPARE_GEN[0]


class PreparedParams(Mapping):
    """Generation-stamped handle over one prepared parameter tree.

    Every ``engine.prepare`` draws from one process-global monotonic
    counter, so a serving layer hot-swapping weights can tell which
    parameter generation served a given batch: no two ``prepare`` calls
    ever share a stamp, and the numbering never rewinds — not even when
    ``clear_cache`` forces a recompile onto a fresh engine instance.

    ``placement`` makes the handle's device residency explicit: None (the
    default) leaves the tree wherever jax put it — byte-identical to the
    pre-placement behaviour — while a ``jax.sharding.NamedSharding``
    means every leaf was committed to it at prepare time, so jit runs the
    whole program on that placement's devices and uncommitted (host)
    batch inputs follow it there.

    The engine unwraps ``.tree`` before dispatch; the ``Mapping``
    interface is preserved so callers that index the raw tree
    (``prepared[mod][site]``) keep working unchanged."""

    __slots__ = ("tree", "generation", "placement")

    def __init__(self, tree: dict, generation: int, placement=None):
        self.tree = tree
        self.generation = generation
        self.placement = placement

    def __getitem__(self, key):
        return self.tree[key]

    def __iter__(self):
        return iter(self.tree)

    def __len__(self):
        return len(self.tree)

    def __repr__(self):  # pragma: no cover - debug aid
        place = "" if self.placement is None else f", placed={self.placement}"
        return (f"PreparedParams(generation={self.generation}, "
                f"modules={list(self.tree)}{place})")


def _unwrap(prepared):
    """Accept both the stamped handle and a raw prepared tree."""
    return getattr(prepared, "tree", prepared)


def place_tree(tree: dict, placement):
    """Commit every leaf of a prepared tree to ``placement`` via the
    elastic-resharding helper (``repro.runtime.resilience.reshard``) —
    the same device_put walk that re-admits a restored training state
    onto a new mesh places serving replicas."""
    from repro.runtime.resilience import reshard
    return reshard(tree, jax.tree.map(lambda _: placement, tree))


class CompiledNetwork:
    """A (modules, plans) pair lowered and jitted once.  Call ``prepare``
    once per parameter tree, then treat the instance as the forward fn.

    ``jax.jit`` still traces once per distinct input SHAPE — a serving
    layer that pads requests into bucket-sized batches should ``warmup``
    each bucket shape ahead of traffic so no live request ever pays a
    trace.  ``exec_stats`` surfaces that accounting (one "trace" per new
    shape, everything after is a cache hit inside jit)."""

    def __init__(self, mods: list[ModuleGraph], plans: list[Plan] | None,
                 use_pallas: bool):
        self.signature = plan_signature(mods, plans, use_pallas)
        self.use_pallas = use_pallas
        self.devices = plan_devices(plans)
        self.generation = _GENERATION[0]
        lowered = lower_network(mods, plans, use_pallas)
        self._prepare_fn = lowered.prepare      # jits its own internals
        self._capture_fn = lowered.capture
        self._freeze_fn = lowered.freeze
        self.needs_calibration = lowered.needs_calibration
        self.ema_modules = lowered.ema_modules
        self._jitted = jax.jit(lowered.run)
        # donating variant of the same program: the caller hands over the
        # input-batch buffer and XLA reuses it instead of allocating (one
        # copy saved per call on the serving hot path, where the padded
        # batch is drain-loop-owned and never read again)
        self._jitted_donate = jax.jit(lowered.run, donate_argnums=(1,))
        self._shapes_seen: set = set()
        self._exec = {"calls": 0, "traces": 0, "prepares": 0,
                      "donated_calls": 0, "donated_bytes": 0,
                      "timed_calls": 0}
        # cached engines are shared across threads (serving drain loop +
        # direct callers); keep the accounting race-free
        self._stats_lock = threading.Lock()

    def prepare(self, params, calib_x=None, *,
                placement=None) -> PreparedParams:
        """One-time parameter lowering: FPGA weights quantized here (int8
        resident for the GEMM path), GPU weights passed through.  When the
        plans opted into calibration (``needs_calibration``), a calibration
        batch is required and activation scales are frozen from it.
        ``placement`` (a ``NamedSharding``) additionally commits the
        prepared tree to specific devices — None keeps today's implicit
        default placement, bit for bit.  Returns a generation-stamped
        ``PreparedParams`` handle (the stamp is a process-global monotonic
        prepare counter — hot-swap bookkeeping that survives engine
        recompiles)."""
        faults.trip("prepare", device=self.devices)
        tree = self._prepare_fn(params, calib_x)
        if placement is not None:
            tree = place_tree(tree, placement)
        with self._stats_lock:
            self._exec["prepares"] += 1
        return PreparedParams(tree, _next_prepare_generation(), placement)

    def capture_scales(self, prepared, x) -> dict:
        """Capture each calibrated quant site's amplitude statistic on a
        live batch, run under the CURRENT frozen scales: ``{module:
        {site: scale}}``.  The online-EMA refinement input
        (``Plan.calibrate("ema")``); the serving layer filters the result
        to ``ema_modules`` so non-EMA calibrators stay frozen."""
        return self._capture_fn(_unwrap(prepared), x)

    def refine_scales(self, prepared, scales, *, alpha: float = 1.0,
                      _generation: int | None = None) -> PreparedParams:
        """A new ``PreparedParams`` with captured scales blended into the
        frozen ones (s' = (1-alpha)*s + alpha*s_batch), re-committed to
        the handle's placement.  Draws a fresh generation unless the
        caller supplies one — a ``ReplicaSet`` refines every replica
        under a single stamp so no batch can mix generations."""
        tree = self._freeze_fn(_unwrap(prepared), scales, alpha)
        placement = getattr(prepared, "placement", None)
        if placement is not None:
            tree = place_tree(tree, placement)
        gen = (_generation if _generation is not None
               else _next_prepare_generation())
        return PreparedParams(tree, gen, placement)

    def _count_call(self, x, donate: bool) -> None:
        key = (tuple(x.shape), str(getattr(x, "dtype", "f32")), donate)
        nbytes = int(getattr(x, "nbytes", 0))
        with self._stats_lock:
            if key not in self._shapes_seen:
                self._shapes_seen.add(key)
                self._exec["traces"] += 1
            self._exec["calls"] += 1
            if donate:
                self._exec["donated_calls"] += 1
                self._exec["donated_bytes"] += nbytes

    def __call__(self, prepared, x, *, donate: bool = False):
        """Run the jitted program.  ``donate=True`` donates ``x``'s buffer
        to the computation — the CALLER'S array becomes unusable after the
        call; only pass buffers you own and will not read again."""
        # fault-injection site, BEFORE any dispatch or donation: an
        # injected dispatch failure leaves the caller's buffer intact
        faults.trip("dispatch", device=self.devices)
        first = ((tuple(x.shape), str(getattr(x, "dtype", "f32")), donate)
                 not in self._shapes_seen)
        self._count_call(x, donate)
        tree = _unwrap(prepared)
        with _quiet_donation() if (first and donate) else nullcontext():
            if donate:
                return self._jitted_donate(tree, x)
            return self._jitted(tree, x)

    def timed_call(self, prepared, x, *, donate: bool = False):
        """Synchronous, measured forward: ``(out, [wall_seconds])``.  The
        monolithic engine has no internal stage boundaries, so the list
        holds ONE element — total dispatch-to-ready wall time.  The shape
        is pre-traced outside the timed region so a first-shape call never
        reports compile time as execution time."""
        key = (tuple(x.shape), str(getattr(x, "dtype", "f32")), donate)
        if key not in self._shapes_seen:
            jax.block_until_ready(
                self(prepared, jnp.zeros(x.shape, x.dtype), donate=donate))
        t0 = time.perf_counter()
        out = self(prepared, x, donate=donate)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        with self._stats_lock:
            self._exec["timed_calls"] += 1
        return out, [dt]

    def warmup(self, prepared, shapes, *, donate: bool = False) -> dict:
        """Trace/compile each input shape once on zeros (per-bucket compile
        warm-up for the serving path; ``donate`` must match how the live
        path will call — the two variants trace separately).  Returns
        ``exec_stats()``."""
        for s in shapes:
            jax.block_until_ready(
                self(prepared, jnp.zeros(s, jnp.float32), donate=donate))
        return self.exec_stats()

    def exec_stats(self) -> dict:
        with self._stats_lock:
            return dict(self._exec)

    def is_current(self) -> bool:
        """False once ``clear_cache`` ran after this engine was built —
        a serving layer holding the instance should re-``compile_network``
        (the engine itself keeps working; this only flags staleness)."""
        return self.generation == _GENERATION[0]


class PipelinedEngine:
    """The same (modules, plans) pair, compiled as a STAGE PIPELINE.

    ``repro.core.passes.stage`` cuts the lowered network at every FPGA<->GPU
    boundary into maximal same-device segments; each segment jits separately
    and the engine threads a dict of live inter-stage values through them.
    Running the stages back to back is bit-identical to the monolithic
    ``CompiledNetwork`` (the parity oracle — ``tests/test_pipeline.py``),
    but the cut exposes the paper's overlap: with JAX's async dispatch,
    stage s of micro-batch i runs while stage s+1 still works on
    micro-batch i-1 (``run_many``), the software analogue of the FPGA
    front-end computing input i+1 under the GPU back-end of input i.

    Inter-stage envs are engine-owned, so every stage after the first
    donates its env (``donate_argnums``) — device hand-offs reuse buffers
    instead of copying.  The network input rides a separate, never-donated
    argument, so caller arrays are never consumed.
    """

    def __init__(self, mods: list[ModuleGraph], plans: list[Plan] | None,
                 use_pallas: bool):
        self.signature = ("pipelined",) + plan_signature(mods, plans,
                                                         use_pallas)
        self.use_pallas = use_pallas
        self.devices = plan_devices(plans)
        self.generation = _GENERATION[0]
        lowered = lower_network(mods, plans, use_pallas)
        self._prepare_fn = lowered.prepare
        self._capture_fn = lowered.capture
        self._freeze_fn = lowered.freeze
        self.needs_calibration = lowered.needs_calibration
        self.ema_modules = lowered.ema_modules
        self.stages = lowered.stages
        self._jitted = [
            jax.jit(s.fn) if i == 0 else jax.jit(s.fn, donate_argnums=(2,))
            for i, s in enumerate(self.stages)]
        self._shapes_seen: set = set()
        self._env_bytes: dict[tuple, int] = {}   # per input shape, at trace
        self._exec = {"calls": 0, "traces": 0, "prepares": 0,
                      "stages": len(self.stages),
                      "donated_calls": 0, "donated_bytes": 0,
                      "timed_calls": 0}
        self._stats_lock = threading.Lock()

    def prepare(self, params, calib_x=None, *,
                placement=None) -> PreparedParams:
        faults.trip("prepare", device=self.devices)
        tree = self._prepare_fn(params, calib_x)
        if placement is not None:
            tree = place_tree(tree, placement)
        with self._stats_lock:
            self._exec["prepares"] += 1
        return PreparedParams(tree, _next_prepare_generation(), placement)

    capture_scales = CompiledNetwork.capture_scales
    refine_scales = CompiledNetwork.refine_scales

    def _slices(self, prepared) -> list:
        """Per-stage prepared-parameter slices (tiny host-side dicts; each
        stage's jit signature only carries the weights it actually uses)."""
        tree = _unwrap(prepared)
        return [{f"{m}.{p}": tree[m][p] for m, p in s.params}
                for s in self.stages]

    def _dispatch(self, slices, x, env, s: int):
        stage = self.stages[s]
        # per-stage fault site: "fail stage k of batch n" is expressible,
        # and the raised fault carries the stage's device tag so failures
        # are attributable to the FPGA or GPU path
        faults.trip("stage", device=stage.device, stage=s)
        xin = x if stage.needs_input else ()
        try:
            return self._jitted[s](slices[s], xin, env)
        except Exception as e:
            # attribute real stage failures too (best effort: some
            # exception types reject new attributes)
            try:
                e.device = getattr(e, "device", None) or stage.device
                e.stage = s
            except AttributeError:
                pass
            raise

    def _count_call(self, x, donated_env_bytes: int) -> None:
        key = (tuple(x.shape), str(getattr(x, "dtype", "f32")))
        with self._stats_lock:
            if key not in self._shapes_seen:
                self._shapes_seen.add(key)
                self._exec["traces"] += 1
            self._exec["calls"] += 1
            if len(self.stages) > 1:
                self._exec["donated_calls"] += 1
                self._exec["donated_bytes"] += donated_env_bytes

    def _env_nbytes(self, x, envs) -> int:
        """Bytes handed over by donation in one full stage sweep — computed
        once per input shape (the env shapes are a function of it)."""
        key = tuple(x.shape)
        if key not in self._env_bytes:
            self._env_bytes[key] = sum(
                int(v.nbytes) for env in envs for v in env.values())
        return self._env_bytes[key]

    def __call__(self, prepared, x, *, donate: bool = False):
        """Single-batch forward through the stage list.  Async dispatch:
        returns as soon as the last stage is enqueued.  ``donate`` is
        accepted for interface parity with ``CompiledNetwork`` — the
        caller's ``x`` is never consumed either way (inter-stage donation
        is always on)."""
        faults.trip("dispatch", device=self.devices)
        first = ((tuple(x.shape), str(getattr(x, "dtype", "f32")))
                 not in self._shapes_seen)
        slices = self._slices(prepared)
        env: dict = {}
        envs = []
        with _quiet_donation() if first else nullcontext():
            for s in range(len(self.stages)):
                env = self._dispatch(slices, x, env, s)
                if s + 1 < len(self.stages):
                    envs.append(env)
        self._count_call(x, self._env_nbytes(x, envs))
        return env["__out"]

    def timed_call(self, prepared, x, *, donate: bool = False):
        """Measured forward with PER-STAGE wall times: ``(out, times)``
        where ``times[s]`` is the dispatch-to-ready wall of stage ``s`` —
        the list aligns 1:1 with ``self.stages`` and therefore with
        ``repro.core.schedule.network_stage_components`` of the same
        (modules, plans) pair.  Blocking at every stage boundary
        serializes the sweep (no cross-stage async overlap), so this is a
        sampling path: the serving layer measures every Nth batch and
        leaves the rest on the async ``__call__``.  Injected stage faults
        (``repro.runtime.faults``, ``op="stage"``) run inside the timed
        region — injected delays are *measured*, which is what lets CI
        drive the replanner without hardware."""
        if ((tuple(x.shape), str(getattr(x, "dtype", "f32")))
                not in self._shapes_seen):
            # trace every stage outside the timed region
            jax.block_until_ready(self(prepared, x))
        faults.trip("dispatch", device=self.devices)
        slices = self._slices(prepared)
        env: dict = {}
        times: list[float] = []
        for s in range(len(self.stages)):
            t0 = time.perf_counter()
            env = self._dispatch(slices, x, env, s)
            jax.block_until_ready(env)
            times.append(time.perf_counter() - t0)
        self._count_call(x, 0)
        with self._stats_lock:
            self._exec["timed_calls"] += 1
        return env["__out"], times

    def run_many(self, prepared, xs, *, depth: int = 2) -> list:
        """Micro-batch software pipeline with at most ``depth`` batches in
        flight: each round advances every active batch one stage (oldest
        first, so stage s of batch i dispatches right after stage s+1 of
        batch i-1 — the skewed schedule), starts a new batch only while
        fewer than ``depth`` are active, and otherwise host-blocks to
        retire the oldest.  The window bounds live inter-stage envs — the
        memory cap ``depth`` promises — during fill as well as steady
        state.  Results are ordered and bit-identical to per-batch
        ``__call__``."""
        depth = max(1, int(depth))
        n, n_stages = len(xs), len(self.stages)
        if n and ((tuple(xs[0].shape), str(getattr(xs[0], "dtype", "f32")))
                  not in self._shapes_seen):
            # trace every stage on the first micro-batch before pipelining
            # (keeps donation warnings scoped and the pipeline trace-free)
            jax.block_until_ready(self(prepared, xs[0]))
        slices = self._slices(prepared) if n else []
        envs: list = [None] * n
        outs: list = [None] * n
        stage_of = [0] * n             # next stage to dispatch per batch
        started = retired = 0
        while retired < n:
            for i in range(retired, started):
                s = stage_of[i]
                if s >= n_stages:
                    continue           # fully dispatched, awaiting retire
                env = self._dispatch(slices, xs[i], envs[i] or {}, s)
                stage_of[i] = s + 1
                if s == n_stages - 1:
                    outs[i] = env["__out"]
                    envs[i] = None
                    self._count_call(xs[i], 0)
                else:
                    envs[i] = env
            if started < n and started - retired < depth:
                started += 1           # admitted; advances next round
            elif outs[retired] is not None:
                jax.block_until_ready(outs[retired])
                retired += 1
        return outs

    def warmup(self, prepared, shapes, *, donate: bool = False) -> dict:
        for s in shapes:
            jax.block_until_ready(
                self(prepared, jnp.zeros(s, jnp.float32), donate=donate))
        return self.exec_stats()

    def exec_stats(self) -> dict:
        with self._stats_lock:
            return dict(self._exec)

    def is_current(self) -> bool:
        return self.generation == _GENERATION[0]


class ReplicaPrepared:
    """Replica-striped prepared state: one placed ``PreparedParams`` per
    data-axis replica, ALL sharing one generation stamp.  The shared
    stamp is the atomic-swap invariant — a swap replaces the whole handle
    at once, so whichever replica serves a batch, the batch carries
    exactly one parameter generation and generations never mix."""

    __slots__ = ("replicas",)

    def __init__(self, replicas):
        self.replicas = tuple(replicas)
        if not self.replicas:
            raise ValueError("ReplicaPrepared needs at least one replica")
        if len({p.generation for p in self.replicas}) != 1:
            raise ValueError("replica handles must share one generation")

    @property
    def generation(self) -> int:
        return self.replicas[0].generation

    def __len__(self):
        return len(self.replicas)

    def __getitem__(self, r: int) -> PreparedParams:
        return self.replicas[r]

    def __repr__(self):  # pragma: no cover - debug aid
        return (f"ReplicaPrepared(n={len(self.replicas)}, "
                f"generation={self.generation})")


class ReplicaSet:
    """Data-parallel replica striping over ONE compiled engine.

    Wraps a ``CompiledNetwork``/``PipelinedEngine`` with the ``data``
    axis of a ``repro.launch.mesh`` mesh: ``prepare`` lowers the
    parameters once (one generation stamp) and commits one copy per
    data-axis replica (``replica_shardings``), and each dispatched batch
    runs wholly on one replica's devices — jit follows the committed
    prepared tree, and the host-side batch input follows it there.  Same
    program, same bits: a row served by any replica equals the batch-1
    call on any other.

    The engine's call surface is preserved (``__call__``/``timed_call``/
    ``warmup``/``exec_stats``/``is_current``/``prepare``), so a serving
    layer treats a ReplicaSet exactly like an engine; the extra
    ``replica=`` keyword pins a dispatch to one replica.  Striping policy
    lives in ``pick``/``release``: ``pick`` claims the least-outstanding
    replica (round-robin tiebreak) and ``release`` returns the slot —
    callers that skip the accounting get plain round-robin."""

    def __init__(self, engine, mesh):
        from repro.launch.mesh import replica_shardings
        self.engine = engine
        self.mesh = mesh
        self.shardings = replica_shardings(mesh)
        self.n_replicas = len(self.shardings)
        self._rr = 0
        self._outstanding = [0] * self.n_replicas
        self._calls = [0] * self.n_replicas
        self._lock = threading.Lock()

    # -- engine surface ----------------------------------------------------

    @property
    def signature(self):
        return self.engine.signature

    @property
    def devices(self):
        return self.engine.devices

    @property
    def use_pallas(self):
        return self.engine.use_pallas

    @property
    def needs_calibration(self):
        return self.engine.needs_calibration

    @property
    def ema_modules(self):
        return self.engine.ema_modules

    def is_current(self) -> bool:
        return self.engine.is_current()

    def prepare(self, params, calib_x=None) -> ReplicaPrepared:
        """Lower the parameters ONCE (weight quantization + optional
        calibration — one prepare, one generation stamp), then commit a
        copy to every replica's placement."""
        base = self.engine.prepare(params, calib_x)
        return ReplicaPrepared([
            PreparedParams(place_tree(base.tree, s), base.generation, s)
            for s in self.shardings])

    # -- striping policy ---------------------------------------------------

    def _least(self, exclude=()) -> int:
        cand = [r for r in range(self.n_replicas) if r not in exclude]
        if not cand:
            cand = list(range(self.n_replicas))
        return min(cand, key=lambda r: (self._outstanding[r],
                                        (r - self._rr) % self.n_replicas))

    def pick(self, exclude=()) -> int:
        """Claim the least-outstanding replica (round-robin tiebreak on
        equal load), skipping ``exclude``.  Pairs with ``release``."""
        with self._lock:
            r = self._least(exclude)
            self._outstanding[r] += 1
            self._rr = (r + 1) % self.n_replicas
            return r

    def peek(self, exclude=()) -> int:
        """The replica ``pick`` would choose, WITHOUT claiming it — the
        cross-replica straggler backup targets this."""
        with self._lock:
            return self._least(exclude)

    def release(self, r: int) -> None:
        with self._lock:
            if self._outstanding[r] > 0:
                self._outstanding[r] -= 1

    def _route(self, prepared, replica):
        if replica is None:
            with self._lock:
                replica = self._rr
                self._rr = (replica + 1) % self.n_replicas
        handle = (prepared[replica] if isinstance(prepared, ReplicaPrepared)
                  else prepared)
        with self._lock:
            self._calls[replica] += 1
        return handle, replica

    # -- dispatch ----------------------------------------------------------

    def __call__(self, prepared, x, *, donate: bool = False, replica=None):
        handle, _ = self._route(prepared, replica)
        return self.engine(handle, x, donate=donate)

    def timed_call(self, prepared, x, *, donate: bool = False, replica=None):
        handle, _ = self._route(prepared, replica)
        return self.engine.timed_call(handle, x, donate=donate)

    def run_many(self, prepared, xs, *, depth: int = 2, replica=None):
        handle, _ = self._route(prepared, replica)
        return self.engine.run_many(handle, xs, depth=depth)

    def warmup(self, prepared, shapes, *, donate: bool = False) -> dict:
        """Warm every (shape, replica) pair: jit compiles per placement,
        so each replica's program must be built before live traffic."""
        for r in range(self.n_replicas):
            self.engine.warmup(prepared[r], shapes, donate=donate)
        return self.exec_stats()

    def capture_scales(self, prepared, x, *, replica: int = 0) -> dict:
        handle = (prepared[replica] if isinstance(prepared, ReplicaPrepared)
                  else prepared)
        return self.engine.capture_scales(handle, x)

    def refine_scales(self, prepared, scales, *,
                      alpha: float = 1.0) -> ReplicaPrepared:
        """EMA-refine every replica under ONE fresh generation stamp."""
        gen = _next_prepare_generation()
        return ReplicaPrepared([
            self.engine.refine_scales(prepared[r], scales, alpha=alpha,
                                      _generation=gen)
            for r in range(self.n_replicas)])

    def exec_stats(self) -> dict:
        with self._lock:
            per = {"replicas": self.n_replicas,
                   "replica_calls": list(self._calls),
                   "replica_outstanding": list(self._outstanding)}
        return {**self.engine.exec_stats(), **per}


_CACHE: dict[tuple, CompiledNetwork] = {}
_STATS = {"hits": 0, "misses": 0}
_GENERATION = [0]       # bumped by clear_cache; engines stamp it at build


def compile_network(mods: list[ModuleGraph], plans: list[Plan] | None = None,
                    *, use_pallas: bool | None = None,
                    cache: bool = True) -> CompiledNetwork:
    """Compile (or fetch from cache) the engine for this (modules, plans)
    pair.  ``plans=None`` compiles the all-GPU fp32 network."""
    if use_pallas is None:
        use_pallas = _default_use_pallas()
    sig = plan_signature(mods, plans, use_pallas)
    if cache and sig in _CACHE:
        _STATS["hits"] += 1
        return _CACHE[sig]
    _STATS["misses"] += 1
    eng = CompiledNetwork(mods, plans, use_pallas)
    if cache:
        _CACHE[sig] = eng
    return eng


def compile_pipelined(mods: list[ModuleGraph],
                      plans: list[Plan] | None = None, *,
                      use_pallas: bool | None = None,
                      cache: bool = True) -> PipelinedEngine:
    """Compile (or fetch from cache) the stage-pipelined engine for this
    (modules, plans) pair.  Pipelined and monolithic engines share the
    executor cache but never alias (distinct signature tags): they are
    different programs with identical numerics."""
    if use_pallas is None:
        use_pallas = _default_use_pallas()
    sig = ("pipelined",) + plan_signature(mods, plans, use_pallas)
    if cache and sig in _CACHE:
        _STATS["hits"] += 1
        return _CACHE[sig]
    _STATS["misses"] += 1
    eng = PipelinedEngine(mods, plans, use_pallas)
    if cache:
        _CACHE[sig] = eng
    return eng


def cache_stats() -> dict:
    return {"size": len(_CACHE), "generation": _GENERATION[0], **_STATS}


def clear_cache() -> None:
    """Drop all cached engines and invalidate live ones (their
    ``is_current`` flips false; holders decide when to recompile)."""
    _CACHE.clear()
    _STATS.update(hits=0, misses=0)
    _GENERATION[0] += 1
