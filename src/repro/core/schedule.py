"""Schedules and their honest cost evaluation.

The paper's central accounting rule: a heterogeneous module is only a win if
it wins *including* the PCIe transfers.  Sequential segments sum; parallel
branches take max(GPU side, FPGA side + comm); energy always sums.

Every FPGA placement also carries a RESOURCE bill (resident MACs + on-chip
weight/linebuffer bytes) because DHM is dedicated silicon per mapped layer:
the network-level partitioner allocates a single Cyclone10GX budget across
all modules (``repro.core.partitioner``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core import costmodel as cm
from repro.core.costmodel import ConvSpec, Cost, ZERO
from repro.core.graph import ModuleGraph, Node


@dataclass(frozen=True)
class Resources:
    macs: int = 0
    bytes: int = 0

    def __add__(self, o: "Resources") -> "Resources":
        return Resources(self.macs + o.macs, self.bytes + o.bytes)


@dataclass
class Plan:
    module: str
    kind: str
    scheme: str
    assign: dict = field(default_factory=dict)     # node -> "gpu"|"fpga"
    fused: tuple = ()                              # fpga nodes fused on-chip
    gconv: dict = field(default_factory=dict)      # node -> fpga input-ch frac
    g_par: int = 1                                 # channel parallel slices
    cost: Cost = ZERO
    gpu_only: Cost = ZERO
    res: Resources = Resources()
    note: str = ""
    calibrate: bool = False        # freeze activation scales at prepare time

    @property
    def energy_gain(self) -> float:
        return self.gpu_only.energy / max(self.cost.energy, 1e-12)

    @property
    def speedup(self) -> float:
        return self.gpu_only.latency / max(self.cost.latency, 1e-12)

    @property
    def saving(self) -> float:
        return self.gpu_only.energy - self.cost.energy


def fpga_resources(nodes: list[Node], g_par: int = 1) -> Resources:
    return Resources(
        sum(cm.FPGA.mac_usage(n.spec, g_par) for n in nodes),
        sum(cm.FPGA.buffer_bytes(n.spec) for n in nodes))


def gpu_cost(nodes: list[Node]) -> Cost:
    c = ZERO
    for n in nodes:
        c = c + cm.GPU.op_cost(n.spec)
    return c


def fpga_chain_cost(nodes: list[Node], in_bytes: int, out_bytes: int,
                    g_par: int = 1) -> Cost:
    """A chain executed on the FPGA with DHM fusion; PCIe in and out.

    The chain is priced by the SAME grouping the lowering fusion pass
    applies: each kernel-fusable group (dw-pw pair, pw-dw-pw, stride-2
    variants) streams as one pipeline and pays one fill; group boundaries
    restart the pipeline (the intermediate stays on-chip, so no PCIe, but
    the fill is paid again).  Longer fusable chains therefore genuinely
    reduce per-node FPGA overheads — and the partitioner, pricing with
    this function, learns to prefer them."""
    # function-level import: repro.core.passes.backend imports this module
    # for type info only, but passes/__init__ pulls the whole pipeline in —
    # importing it lazily keeps schedule importable first in any order
    from repro.core.passes.fuse import cost_groups
    comp = ZERO
    for group in cost_groups(nodes):
        comp = comp + cm.FPGA.fused_cost([n.spec for n in group],
                                         [g_par] * len(group))
    xin = cm.PCIE.xfer(in_bytes)
    xout = cm.PCIE.xfer(out_bytes)
    return Cost(xin.latency + comp.latency + xout.latency,
                xin.energy + comp.energy + xout.energy)


def parallel_cost(gpu_nodes: list[Node], fpga_nodes: list[Node],
                  fpga_in_bytes: int, fpga_out_bytes: int,
                  g_par: int = 1) -> Cost:
    """GPU branch ‖ (send + FPGA branch + recv): the paper's max() schedule."""
    g = gpu_cost(gpu_nodes)
    f = fpga_chain_cost(fpga_nodes, fpga_in_bytes, fpga_out_bytes, g_par)
    return Cost(max(g.latency, f.latency), g.energy + f.energy)


def split_spec_in(spec: ConvSpec, frac: float) -> tuple[ConvSpec, ConvSpec]:
    """Paper Fig.2b GConv: FPGA takes g input channels, GPU takes C_I - g;
    partial outputs are summed (executor) / concat (grouped semantics)."""
    g = max(1, int(round(spec.c_in * frac)))
    g = min(g, spec.c_in - 1)
    return (replace(spec, c_in=g, groups=1),
            replace(spec, c_in=spec.c_in - g, groups=1))


def module_gpu_only(m: ModuleGraph) -> Cost:
    return gpu_cost(m.nodes)
