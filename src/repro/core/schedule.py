"""Schedules and their honest cost evaluation.

The paper's central accounting rule: a heterogeneous module is only a win if
it wins *including* the PCIe transfers.  Sequential segments sum; parallel
branches take max(GPU side, FPGA side + comm); energy always sums.

Every FPGA placement also carries a RESOURCE bill (resident MACs + on-chip
weight/linebuffer bytes) because DHM is dedicated silicon per mapped layer:
the network-level partitioner allocates a single Cyclone10GX budget across
all modules (``repro.core.partitioner``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core import costmodel as cm
from repro.core.costmodel import (IDENTITY_SCALES, ConvSpec, Cost, CostScales,
                                  ZERO)
from repro.core.graph import ModuleGraph, Node


@dataclass(frozen=True)
class Resources:
    macs: int = 0
    bytes: int = 0

    def __add__(self, o: "Resources") -> "Resources":
        return Resources(self.macs + o.macs, self.bytes + o.bytes)


@dataclass
class Plan:
    module: str
    kind: str
    scheme: str
    assign: dict = field(default_factory=dict)     # node -> "gpu"|"fpga"
    fused: tuple = ()                              # fpga nodes fused on-chip
    gconv: dict = field(default_factory=dict)      # node -> fpga input-ch frac
    g_par: int = 1                                 # channel parallel slices
    cost: Cost = ZERO
    gpu_only: Cost = ZERO
    res: Resources = Resources()
    note: str = ""
    # freeze activation scales at prepare time: False, True (= "amax"),
    # or a calibrator kind name ("amax" | "pct99")
    calibrate: bool | str = False

    @property
    def calibrator(self) -> str | None:
        """Normalized calibrator kind (None when calibration is off); the
        plan-signature component that keeps distinct calibrators from ever
        sharing a compiled engine."""
        from repro.core.passes.calibrate import calibrator_kind
        return calibrator_kind(self.calibrate)

    @property
    def energy_gain(self) -> float:
        return self.gpu_only.energy / max(self.cost.energy, 1e-12)

    @property
    def speedup(self) -> float:
        return self.gpu_only.latency / max(self.cost.latency, 1e-12)

    @property
    def saving(self) -> float:
        return self.gpu_only.energy - self.cost.energy


def fpga_resources(nodes: list[Node], g_par: int = 1) -> Resources:
    return Resources(
        sum(cm.FPGA.mac_usage(n.spec, g_par) for n in nodes),
        sum(cm.FPGA.buffer_bytes(n.spec) for n in nodes))


def gpu_cost(nodes: list[Node], scales: CostScales | None = None) -> Cost:
    c = ZERO
    for n in nodes:
        c = c + cm.GPU.op_cost(n.spec)
    s = scales or IDENTITY_SCALES
    return Cost(c.latency * s.gpu, c.energy)


def fpga_chain_components(nodes: list[Node], in_bytes: int, out_bytes: int,
                          g_par: int = 1) -> tuple[Cost, Cost]:
    """The unscaled ``(compute, transfer)`` halves of an FPGA chain: DHM
    pipeline compute (priced by the SAME grouping the lowering fusion pass
    applies — one fill per kernel-fusable group) and the PCIe in+out
    transfers.  Split out so the online fitter can attribute measured
    stage time to separate device and link coefficients."""
    # function-level import: repro.core.passes.backend imports this module
    # for type info only, but passes/__init__ pulls the whole pipeline in —
    # importing it lazily keeps schedule importable first in any order
    from repro.core.passes.fuse import cost_groups
    comp = ZERO
    for group in cost_groups(nodes):
        comp = comp + cm.FPGA.fused_cost([n.spec for n in group],
                                         [g_par] * len(group))
    return comp, cm.PCIE.xfer(in_bytes) + cm.PCIE.xfer(out_bytes)


def fpga_chain_cost(nodes: list[Node], in_bytes: int, out_bytes: int,
                    g_par: int = 1,
                    scales: CostScales | None = None) -> Cost:
    """A chain executed on the FPGA with DHM fusion; PCIe in and out.

    The chain is priced by the SAME grouping the lowering fusion pass
    applies: each kernel-fusable group (dw-pw pair, pw-dw-pw, stride-2
    variants) streams as one pipeline and pays one fill; group boundaries
    restart the pipeline (the intermediate stays on-chip, so no PCIe, but
    the fill is paid again).  Longer fusable chains therefore genuinely
    reduce per-node FPGA overheads — and the partitioner, pricing with
    this function, learns to prefer them."""
    comp, xfer = fpga_chain_components(nodes, in_bytes, out_bytes, g_par)
    s = scales or IDENTITY_SCALES
    return Cost(comp.latency * s.fpga + xfer.latency * s.xfer,
                comp.energy + xfer.energy)


def parallel_cost(gpu_nodes: list[Node], fpga_nodes: list[Node],
                  fpga_in_bytes: int, fpga_out_bytes: int,
                  g_par: int = 1, scales: CostScales | None = None) -> Cost:
    """GPU branch ‖ (send + FPGA branch + recv): the paper's max() schedule."""
    g = gpu_cost(gpu_nodes, scales)
    f = fpga_chain_cost(fpga_nodes, fpga_in_bytes, fpga_out_bytes, g_par,
                        scales)
    return Cost(max(g.latency, f.latency), g.energy + f.energy)


def split_spec_in(spec: ConvSpec, frac: float) -> tuple[ConvSpec, ConvSpec]:
    """Paper Fig.2b GConv: FPGA takes g input channels, GPU takes C_I - g;
    partial outputs are summed (executor) / concat (grouped semantics)."""
    g = max(1, int(round(spec.c_in * frac)))
    g = min(g, spec.c_in - 1)
    return (replace(spec, c_in=g, groups=1),
            replace(spec, c_in=spec.c_in - g, groups=1))


def module_gpu_only(m: ModuleGraph,
                    scales: CostScales | None = None) -> Cost:
    return gpu_cost(m.nodes, scales)


# ---------------------------------------------------------------------------
# Pipelined (cross-input overlap) cost estimate
# ---------------------------------------------------------------------------
#
# Everything above prices ONE input walking the module: sequential segments
# sum.  With stage-pipelined execution (repro.core.passes.stage) the FPGA
# front-end of input i+1 overlaps the GPU back-end of input i, so the
# steady-state beat is the MAX over stage latencies, and the serial sum is
# only paid once as pipeline fill.  Energy still sums — overlap moves work
# in time, it does not remove it.

@dataclass(frozen=True)
class StageCost:
    """One device-tagged stage, decomposed into the UNSCALED model terms
    the online fitter regresses against: device compute and PCIe transfer
    (zero for GPU stages).  ``cost(scales)`` re-assembles the scaled
    ``Cost`` — identity scales reproduce the paper model exactly."""
    device: str
    comp: Cost = ZERO        # modelled device compute (unscaled)
    xfer: Cost = ZERO        # modelled PCIe in+out (unscaled)

    def __add__(self, o: "StageCost") -> "StageCost":
        return StageCost(self.device, self.comp + o.comp, self.xfer + o.xfer)

    def latency(self, scales: CostScales | None = None) -> float:
        s = scales or IDENTITY_SCALES
        dev = s.fpga if self.device == "fpga" else s.gpu
        return self.comp.latency * dev + self.xfer.latency * s.xfer

    def cost(self, scales: CostScales | None = None) -> Cost:
        return Cost(self.latency(scales), self.comp.energy + self.xfer.energy)


def stage_components(m: ModuleGraph, plan: Plan | None,
                     act_bytes: int = 1) -> list[StageCost]:
    """Per-stage model decomposition of a module under the stage-partition
    cut rule: maximal same-device runs in node order, plus the synthesized
    GPU residual-add step for residual modules (so the segmentation is the
    one ``passes/stage.py`` actually executes — an FPGA-ending residual
    module really hands back to the GPU).  FPGA segments pay PCIe in/out
    (the honest-accounting rule), GPU segments are plain gpu_cost.  A
    plan-less / all-GPU module is a single stage."""
    if plan is None:
        out = [StageCost("gpu", gpu_cost(m.nodes))]
    else:
        segs: list[tuple[str, list[Node]]] = []
        for n in m.nodes:
            dev = "fpga" if (plan.assign.get(n.name) == "fpga"
                             or n.name in plan.gconv) else "gpu"
            if segs and segs[-1][0] == dev:
                segs[-1][1].append(n)
            else:
                segs.append((dev, [n]))
        out = []
        for dev, nodes in segs:
            if dev == "gpu":
                out.append(StageCost(dev, gpu_cost(nodes)))
            else:
                comp, xfer = fpga_chain_components(
                    nodes, nodes[0].spec.in_bytes(act_bytes),
                    nodes[-1].spec.out_bytes(act_bytes), plan.g_par)
                out.append(StageCost(dev, comp, xfer))
    if m.residual:
        out.append(StageCost("gpu"))   # elementwise add: priced free
    return out


def plan_stage_costs(m: ModuleGraph, plan: Plan | None, act_bytes: int = 1,
                     scales: CostScales | None = None
                     ) -> list[tuple[str, Cost]]:
    """Per-stage ``(device, cost)`` view of ``stage_components`` — the
    assembled costs under (optionally fitted) scales."""
    return [(sc.device, sc.cost(scales))
            for sc in stage_components(m, plan, act_bytes)]


def network_stage_components(modules: list[ModuleGraph],
                             plans: list[Plan] | None,
                             act_bytes: int = 1) -> list[StageCost]:
    """The NETWORK-level stage decomposition: per-module segments merged
    across module boundaries, plus the final (free) GPU output-reshape
    step — exactly the stage list ``repro.core.passes.stage`` compiles and
    ``PipelinedEngine`` executes, so measured per-stage wall times from
    ``timed_call`` align 1:1 with these components."""
    plan_by = {p.module: p for p in plans} if plans else {}
    merged: list[StageCost] = []
    segments = [sc for m in modules
                for sc in stage_components(m, plan_by.get(m.name), act_bytes)]
    segments.append(StageCost("gpu"))
    for sc in segments:
        if merged and merged[-1].device == sc.device:
            merged[-1] = merged[-1] + sc
        else:
            merged.append(sc)
    return merged


def pipelined_cost(stages: list[Cost], n_inputs: int = 1) -> Cost:
    """Makespan of ``n_inputs`` through a stage pipeline: fill (every stage
    once) + one max-stage beat per additional input.  Compare against
    ``sum(stages) * n_inputs`` — today's fully-serialized schedule — to see
    what overlap is worth.  Energy is per-input work times n_inputs."""
    if not stages:
        return ZERO
    lat = cm.pipelined_latency([c.latency for c in stages], n_inputs)
    return Cost(lat, sum(c.energy for c in stages) * n_inputs)
