"""Stage-partition pass: cut the lowered network at FPGA<->GPU boundaries.

The backend pass emits each module as a linear step list (the same list its
monolithic ``run`` closure executes).  This pass flattens those lists across
the whole network, tags every step with its device (from the annotation
pass), and cuts the flat sequence at every FPGA<->GPU transition into an
ordered list of ``Stage``s — maximal same-device segments.  Each stage is a
closure over the SAME per-step run closures the monolithic program uses, so
executing the stages back to back is bit-identical to the monolithic call;
the only thing that changes is that every device hand-off now materializes
its live values, which is exactly where a software pipeline can overlap
micro-batch i's front-end with micro-batch i-1's back-end
(``repro.core.executor.PipelinedEngine``).

Liveness is computed over the flat sequence: a stage's ``env`` input/output
carries precisely the values later stages still need (namespaced
``module.value`` keys).  The network input is special-cased: it is routed
to every stage that reads it through a separate, never-donated argument
(``needs_input``), so inter-stage envs can be donated without ever
consuming a caller-owned buffer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from repro.core.passes.ir import LoweredModule

_IN = "__net_in"                       # flat key of the network input
_OUT = "__out"                         # flat key of the network output


@dataclass(frozen=True)
class _Step:
    """One flattened execution step (module-namespaced value keys)."""
    kind: str                          # param | free | glue_split | glue_cat
    #                                  # | residual | reshape
    device: str                        # "gpu" | "fpga"
    reads: tuple[str, ...]
    writes: tuple[str, ...]
    mod: str = ""                      # module name (param steps)
    pname: str = ""                    # prepared-tree key (param steps)
    fn: Callable | None = None
    half: int = 0                      # glue_split channel count


@dataclass(frozen=True)
class Stage:
    """A maximal same-device segment, executable as one closure.

    ``fn(prepared_slice, xin, env) -> env_out`` where ``prepared_slice``
    maps ``"module.pname"`` to that step's prepared params, ``xin`` is the
    network input (or ``()`` when ``needs_input`` is False) and ``env`` is
    the dict of live inter-stage values.  ``env`` is safe to donate: its
    leaves are always engine-owned stage outputs, never caller buffers.
    """
    device: str
    fn: Callable
    params: tuple[tuple[str, str], ...]   # (module, pname) pairs used
    needs_input: bool
    live_in: tuple[str, ...]
    live_out: tuple[str, ...]


def _flatten(lowered: list[tuple[str, LoweredModule]]) -> list[_Step]:
    steps: list[_Step] = []
    cur = _IN                          # key holding the current module input
    for name, lm in lowered:
        m = lm.ir.module

        def key(local: str, _name=name, _cur=cur) -> str:
            return _cur if local == "in" else f"{_name}.{local}"

        for out_name, kind, payload in lm.steps:
            if kind == "shuffle_glue":
                if out_name == "split":
                    steps.append(_Step(
                        "glue_split", "gpu", (cur,),
                        (key("split"), key("_identity")),
                        half=m.node("split").spec.c_out))
                else:
                    steps.append(_Step(
                        "glue_cat", "gpu",
                        (key("_identity"), key(m.node("cat").inputs[1])),
                        (key("cat"),)))
                continue
            if kind == "free":
                inputs, fn = payload
                steps.append(_Step(
                    "free", "gpu", tuple(key(i) for i in inputs),
                    (key(out_name),), fn=fn))
                continue
            pname, inputs, run, _site = payload
            steps.append(_Step(
                "param", lm.ir.ann[pname].device, (key(inputs[0]),),
                (key(out_name),), mod=name, pname=pname, fn=run))
        out_key = key(m.output)
        if m.residual:
            steps.append(_Step("residual", "gpu", (out_key, cur),
                               (f"{name}.__res",)))
            out_key = f"{name}.__res"
        cur = out_key
    steps.append(_Step("reshape", "gpu", (cur,), (_OUT,)))
    return steps


def _run_step(st: _Step, prepared_slice: dict, vals: dict) -> None:
    if st.kind == "param":
        vals[st.writes[0]] = st.fn(prepared_slice[f"{st.mod}.{st.pname}"],
                                   vals[st.reads[0]])
    elif st.kind == "free":
        vals[st.writes[0]] = st.fn([vals[k] for k in st.reads])
    elif st.kind == "glue_split":
        x = vals[st.reads[0]]
        vals[st.writes[0]] = x[..., st.half:]
        vals[st.writes[1]] = x[..., :st.half]
    elif st.kind == "glue_cat":
        vals[st.writes[0]] = jnp.concatenate(
            [vals[st.reads[0]], vals[st.reads[1]]], axis=-1)
    elif st.kind == "residual":
        vals[st.writes[0]] = vals[st.reads[0]] + vals[st.reads[1]]
    else:                              # reshape (network output)
        y = vals[st.reads[0]]
        vals[st.writes[0]] = y.reshape(y.shape[0], -1)


def _make_stage(seg: list[_Step], live_in: tuple[str, ...],
                live_out: tuple[str, ...]) -> Stage:
    needs_input = any(_IN in st.reads for st in seg)
    params = tuple(dict.fromkeys((st.mod, st.pname) for st in seg
                                 if st.kind == "param"))

    def fn(prepared_slice, xin, env):
        vals = dict(env)
        if needs_input:
            vals[_IN] = xin
        for st in seg:
            _run_step(st, prepared_slice, vals)
        return {k: vals[k] for k in live_out}

    return Stage(seg[0].device, fn, params, needs_input, live_in, live_out)


def stage_partition(
        lowered: list[tuple[str, LoweredModule]]) -> list[Stage]:
    """Cut the flattened network into maximal same-device stages with exact
    liveness on the inter-stage envs.  A fully single-device network (e.g.
    plans=None) comes back as one stage — the degenerate pipeline."""
    steps = _flatten(lowered)
    segs: list[list[_Step]] = []
    for st in steps:
        if segs and segs[-1][0].device == st.device:
            segs[-1].append(st)
        else:
            segs.append([st])

    # Backwards liveness sweep: needed[i] = values stage i must receive.
    # _IN is excluded — it travels via the dedicated xin argument.
    stages: list[Stage] = []
    needed: set[str] = {_OUT}
    live_after: list[tuple[str, ...]] = []
    for seg in reversed(segs):
        live_after.append(tuple(sorted(needed)))
        written: set[str] = set()
        read: set[str] = set()       # read before (segment-locally) written
        for st in seg:
            read.update(k for k in st.reads
                        if k != _IN and k not in written)
            written.update(st.writes)
        needed = (needed - written) | read
    live_after.reverse()

    live_in = tuple(sorted(needed - {_IN}))   # empty: env starts as {}
    assert not live_in, f"unbound values at network entry: {live_in}"
    prev_out: tuple[str, ...] = ()
    for seg, lo in zip(segs, live_after):
        stages.append(_make_stage(seg, prev_out, lo))
        prev_out = lo
    return stages
