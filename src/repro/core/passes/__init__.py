"""The lowering pass pipeline: (ModuleGraph, Plan) -> executable program.

Fixed pass order (each pass is a pure IR transform; ``backend_pass`` emits
the closures the executor jits):

    annotate_pass -> fuse_pass -> calibrate_pass -> backend_pass

``run_pipeline`` drives it for one module.  ``repro.core.lowering`` composes
the per-module programs into the network-level prepare/run/capture triple.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

from repro.core.graph import ModuleGraph
from repro.core.passes.annotate import annotate_pass
from repro.core.passes.backend import backend_pass
from repro.core.passes.calibrate import calibrate_pass, calibrator_kind
from repro.core.passes.fuse import chain_groups, cost_groups, fuse_pass
from repro.core.passes.ir import Chain, LoweredModule, ModuleIR, NodeAnn
from repro.core.passes.stage import Stage, stage_partition

if TYPE_CHECKING:
    from repro.core.schedule import Plan

PIPELINE = (annotate_pass, fuse_pass, calibrate_pass)


def build_ir(m: ModuleGraph, plan: "Plan | None",
             use_pallas: bool) -> ModuleIR:
    """Run the analysis passes (everything before backend emission)."""
    ir = ModuleIR(m, plan, use_pallas)
    for p in PIPELINE:
        ir = p(ir)
    return ir


def run_pipeline(m: ModuleGraph, plan: "Plan | None",
                 use_pallas: bool) -> LoweredModule:
    """Full pipeline for one module: analysis passes + backend emission."""
    return backend_pass(build_ir(m, plan, use_pallas))


__all__ = [
    "Chain", "LoweredModule", "ModuleIR", "NodeAnn", "PIPELINE", "Stage",
    "annotate_pass", "backend_pass", "build_ir", "calibrate_pass",
    "calibrator_kind", "chain_groups", "cost_groups", "fuse_pass",
    "run_pipeline", "stage_partition",
]
