"""Backend-lowering pass: emit the executable program from an annotated IR.

Consumes the ``ModuleIR`` produced by annotate/fuse/calibrate and returns a
``LoweredModule`` of three closures over static metadata:

  * ``prepare(params_m)``   one-time parameter lowering — FPGA weights leave
    fp32 exactly once (resident int8 + per-channel scale for the GEMM path,
    fake-quantized grids for the fused/conv paths);
  * ``run(prepared_m, x)``  the jit-traceable forward — node steps unrolled
    in graph order, every routing decision burned in at lowering time;
  * ``capture(prepared_m, x)`` the calibration forward — same steps, but
    records each calibration site's absolute-max activation so the network
    level can freeze scales into the prepared tree.

Batch invariance (the serving contract): every run-time step is
row-independent in the batch dimension.  Activation quantization is either
per-sample (``axis=0``) or a frozen per-tensor constant; the int8 GEMM
accumulates order-exactly; and the remaining fp32 GEMMs run in fixed row
tiles (``rowsafe_matmul``) because XLA:CPU picks gemm blocking from the
full operand shapes and different blockings round differently.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.costmodel import ConvSpec
from repro.core.graph import Node
from repro.core.hetero import apply_act
from repro.core.passes.ir import (PATH_FQ, PATH_FREE, PATH_GCONV, PATH_GLUE,
                                  PATH_GPU, PATH_INT8, Chain, LoweredModule,
                                  ModuleIR)
from repro.kernels.fused_block.ops import fused_chain
from repro.kernels.int8_gemm.ops import int8_gemm
from repro.quant import (fake_quant, fake_quant_with_scale, quantize,
                         quantize_with_scale)


# --------------------------------------------------------------------------
# batch-invariant numeric building blocks
# --------------------------------------------------------------------------

_ROW_TILE = 8


def rowsafe_matmul(a, w, tile: int = _ROW_TILE):
    """a (M,K) @ w (K,N) computed in fixed (tile,K)@(K,N) row blocks.

    XLA:CPU picks gemm strategy (threading, cache blocking, small-M
    kernels) from the FULL operand shapes, and different K-panel groupings
    round differently — so row i of an (M,K) gemm is NOT bit-stable across
    M.  Padding M to a tile multiple and mapping the same fixed-shape gemm
    over row blocks pins the strategy, making every row's accumulation
    chain a function of that row alone.  This is what lets ``repro.serving``
    promise batch-size-independent logits.  Zero pad rows never enter a
    real row's chain; ``tile`` trades scan overhead (small tile, small M)
    against lost inter-block threading (large tile, large M)."""
    M, K = a.shape
    mp = -(-M // tile) * tile
    ap = jnp.pad(a, ((0, mp - M), (0, 0)))
    if mp == tile:
        return (ap @ w)[:M]
    _, out = jax.lax.scan(lambda c, t: (c, t @ w), None,
                          ap.reshape(-1, tile, K), unroll=4)
    return out.reshape(mp, -1)[:M]


def same_taps(x, k: int, s: int, fill=0.0):
    """SAME-pad x (NHWC) for a k*k/stride-s window (XLA's lo=total//2 split)
    and yield the k*k shifted strided (B,Ho,Wo,C) slices — the building
    block for the shift-and-add conv/pool lowerings below."""
    H, W = x.shape[1], x.shape[2]
    ho, wo = -(-H // s), -(-W // s)
    ph = max((ho - 1) * s + k - H, 0)
    pw = max((wo - 1) * s + k - W, 0)
    xp = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                     (pw // 2, pw - pw // 2), (0, 0)),
                 constant_values=fill)
    return [(dy, dx, xp[:, dy:dy + (ho - 1) * s + 1:s,
                        dx:dx + (wo - 1) * s + 1:s, :])
            for dy in range(k) for dx in range(k)]


def dw_shift_add(w, x, k: int, s: int):
    """Depthwise conv (multiplier 1) as k*k unrolled shift-and-adds — the
    dataflow of the Pallas fused kernel, and far faster than XLA's generic
    grouped-conv lowering on CPU.  w: (k,k,C)."""
    acc = None
    for dy, dx, sl in same_taps(x, k, s):
        term = sl * w[dy, dx]
        acc = term if acc is None else acc + term
    return acc


def spatial_tile(hw: int) -> int:
    """Row tile for a fp32 (B*Ho*Wo, K) GEMM: one sample's rows per tile,
    so batch 1 pays no padding and every batch size sees the same block
    shape.  Depends on the spatial size only — never on batch."""
    return -(-hw // _ROW_TILE) * _ROW_TILE


def conv_im2col(x, w, k: int, s: int):
    """SAME conv as a row-tiled (B*Ho*Wo, k*k*C) @ (k*k*C, Co) GEMM."""
    C, co = x.shape[-1], w.shape[-1]
    if k == 1 and s == 1:
        cols = x
    else:
        cols = jnp.concatenate([sl for _dy, _dx, sl in same_taps(x, k, s)],
                               axis=-1)
    y = rowsafe_matmul(cols.reshape(-1, k * k * C), w.reshape(-1, co),
                       tile=spatial_tile(cols.shape[1] * cols.shape[2]))
    return y.reshape(*cols.shape[:3], co)


def _xla_conv(spec: ConvSpec, act: str):
    if spec.kind == "dwconv" and spec.c_out == spec.c_in and spec.k <= 5:
        def run(p, x):
            y = dw_shift_add(p["w"].reshape(spec.k, spec.k, -1), x,
                             spec.k, spec.stride)
            return apply_act(y + p["b"], act)
        return run
    groups = spec.c_in if spec.kind == "dwconv" else spec.groups
    if groups == 1:
        # im2col + fixed-tile GEMM rather than conv_general_dilated: the
        # row-tiled GEMM is batch-invariant (see rowsafe_matmul) where
        # XLA:CPU's conv — itself a gemm over B*Ho*Wo rows — is not, and
        # for the small late-stage maps it also dodges conv's fixed per-op
        # cost.  The tile is a function of the spatial size only, so every
        # batch size lowers to the same per-block gemm shape.
        def run(p, x):
            y = conv_im2col(x, p["w"], spec.k, spec.stride)
            return apply_act(y + p["b"], act)
        return run

    def run(p, x):
        # grouped-conv fallback; unused by the paper networks (their only
        # grouped convs are depthwise, handled by the shift-add path) and
        # NOT batch-invariant — keep new graphs off this path if they are
        # to be served batched
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(spec.stride, spec.stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
        return apply_act(y + p["b"], act)
    return run


# --------------------------------------------------------------------------
# activation-quantization entry (per-sample fallback / frozen calibration)
# --------------------------------------------------------------------------

def _fq_in(p, x):
    """Fake-quant an activation: frozen per-tensor scale when the prepared
    tree carries one (calibrated plans), per-sample ``axis=0`` otherwise.
    The dict-key branch resolves at trace time — prepared structure is
    fixed per compiled signature."""
    if "x_scale" in p:
        return fake_quant_with_scale(x, p["x_scale"])
    return fake_quant(x, axis=0)


def _q_act(p, x):
    """int8-quantize an activation for the GEMM path.  Returns (q, scales)
    with scales shaped like ``quantize(x, axis=0)``'s keepdims output —
    per-sample scales, or the frozen per-tensor scale broadcast to that
    same shape so both modes feed the GEMM identically."""
    if "x_scale" in p:
        q = quantize_with_scale(x, p["x_scale"])
        s = jnp.broadcast_to(
            jnp.asarray(p["x_scale"], jnp.float32).reshape((1,) * x.ndim),
            (x.shape[0],) + (1,) * (x.ndim - 1))
        return q, s
    return quantize(x, axis=0)


# --------------------------------------------------------------------------
# per-path step builders: each returns (prepare(params) -> prepared,
#                                       run(prepared, x) -> y)
# --------------------------------------------------------------------------

def _lower_gpu(n: Node):
    if n.spec.kind == "fc":
        def run(p, x):
            y = rowsafe_matmul(x.reshape(x.shape[0], -1), p["w"])
            return apply_act(y + p["b"], n.act)
    else:
        run = _xla_conv(n.spec, n.act)
    return (lambda p: {"w": p["w"], "b": p["b"]}), run


def _lower_fpga_fq(n: Node):
    """FPGA conv that cannot use the int8 GEMM: weights fake-quantized once
    at prepare time, activation fake-quantized per call (or with the frozen
    calibration scale), XLA conv."""
    conv = _xla_conv(n.spec, n.act)

    def prepare(p):
        return {"w": fake_quant(p["w"], axis=-1), "b": p["b"]}

    def run(p, x):
        return conv(p, _fq_in(p, x))
    return prepare, run


def _lower_fpga_int8(n: Node, use_pallas: bool):
    """True-int8 path: any groups==1 FPGA conv (via im2col) or fc as an
    int8 GEMM with resident int8 weights.  The int32 accumulation is
    order-exact, so this path is batch-invariant with full cross-sample
    vectorization — no row tiling needed — and it is the faithful DHM
    substrate: the FPGA computes in 8-bit fixed point end to end."""
    spec = n.spec

    def prepare(p):
        w2d = p["w"].reshape(-1, spec.c_out)   # (k*k*C, co) for convs
        w_q, w_s = quantize(w2d, axis=-1)
        return {"w_q": w_q, "w_s": w_s.reshape(-1), "b": p["b"]}

    def run(p, x):
        # per-sample activation scales (axis=0) unless calibrated: each
        # request in a served batch quantizes exactly as it would alone
        x_q4, x_s4 = _q_act(p, x)
        if spec.kind == "fc":
            y = int8_gemm(x_q4.reshape(x.shape[0], -1), p["w_q"],
                          x_s4.reshape(x.shape[0], 1), p["w_s"],
                          use_pallas=use_pallas)
            return apply_act(y + p["b"], n.act)
        if spec.k == 1 and spec.stride == 1:
            cols = x_q4
        else:
            cols = jnp.concatenate(
                [sl for _dy, _dx, sl in
                 same_taps(x_q4, spec.k, spec.stride, fill=0)], axis=-1)
        lead = cols.shape[:3]
        x_s = jnp.broadcast_to(x_s4, (*lead, 1)).reshape(-1, 1)
        y = int8_gemm(cols.reshape(-1, cols.shape[-1]), p["w_q"], x_s,
                      p["w_s"], use_pallas=use_pallas)
        y = (y + p["b"]).reshape(*lead, spec.c_out)
        return apply_act(y, n.act)
    return prepare, run


def _lower_chain(chain: Chain, use_pallas: bool):
    """Fused FPGA chain through the ``fused_chain`` kernel: [lead pw] ->
    dw3x3/stride -> pw1x1, every intermediate VMEM-resident (no fake-quant
    round trips between the stages — the DHM on-chip residency
    semantics).  The XLA fallback replays the same dataflow with the
    batch-invariant shift-add + row-tiled GEMM primitives."""
    lead, dw, pw = chain.lead, chain.dw, chain.pw
    stride = chain.stride
    co = pw.spec.c_out

    def prepare(p_nodes):
        out = {"dw_w": fake_quant(p_nodes[dw.name]["w"].reshape(3, 3, -1),
                                  axis=-1),
               "dw_b": p_nodes[dw.name]["b"],
               "pw_w": fake_quant(p_nodes[pw.name]["w"].reshape(-1, co),
                                  axis=-1),
               "pw_b": p_nodes[pw.name]["b"]}
        if lead is not None:
            out["lead_w"] = fake_quant(
                p_nodes[lead.name]["w"].reshape(-1, lead.spec.c_out),
                axis=-1)
            out["lead_b"] = p_nodes[lead.name]["b"]
        return out

    if use_pallas:
        def run(p, x):
            y = fused_chain(_fq_in(p, x), p.get("lead_w"), p.get("lead_b"),
                            p["dw_w"], p["dw_b"], p["pw_w"], p["pw_b"],
                            stride=stride,
                            act_lead=lead.act if lead is not None else "none",
                            act_dw=dw.act, use_pallas=True)
            return apply_act(y, pw.act)
    else:
        def run(p, x):
            h = _fq_in(p, x)
            if lead is not None:
                hw = rowsafe_matmul(h.reshape(-1, h.shape[-1]), p["lead_w"],
                                    tile=spatial_tile(h.shape[1]
                                                      * h.shape[2]))
                h = apply_act(hw + p["lead_b"],
                              lead.act).reshape(*h.shape[:3], -1)
            h = apply_act(dw_shift_add(p["dw_w"], h, 3, stride) + p["dw_b"],
                          dw.act)
            y = rowsafe_matmul(h.reshape(-1, h.shape[-1]), p["pw_w"],
                               tile=spatial_tile(h.shape[1] * h.shape[2]))
            y = y + p["pw_b"]
            return apply_act(y.reshape(*h.shape[:3], co), pw.act)
    return prepare, run


def _lower_gconv(n: Node, frac: float):
    """Paper Fig. 2b input-channel split, lowered to ONE concatenated conv:
    channels [:g] carry the FPGA's quantized slice, [g:] the GPU's fp32
    slice; linearity in input channels makes the single conv equal the
    summed partials."""
    spec = n.spec
    g = max(1, int(round(spec.c_in * frac)))
    conv = _xla_conv(spec, n.act)

    def prepare(p):
        w = p["w"]
        w_cat = jnp.concatenate(
            [fake_quant(w[..., :g, :], axis=-1), w[..., g:, :]], axis=-2)
        return {"w": w_cat, "b": p["b"]}

    def run(p, x):
        x_cat = jnp.concatenate([_fq_in(p, x[..., :g]), x[..., g:]],
                                axis=-1)
        return conv(p, x_cat)
    return prepare, run, g


def _pool_shift(x, k: int, s: int, fill, combine):
    """Pooling as k*k shifted strided slices combined elementwise — the
    same trick as ``dw_shift_add``; XLA:CPU's ``reduce_window`` is a
    fixed-cost scalar loop that dwarfs the actual work."""
    acc = None
    for _dy, _dx, sl in same_taps(x, k, s, fill=fill):
        acc = sl if acc is None else combine(acc, sl)
    return acc


def _lower_pointfree(n: Node):
    """Parameter-free ops (pool/gap/concat/add/split/shuffle)."""
    spec = n.spec
    kind = spec.kind
    if kind == "maxpool":
        return lambda xs: _pool_shift(xs[0], spec.k, spec.stride,
                                      -jnp.inf, jnp.maximum)
    if kind == "avgpool":
        def run(xs):
            s = _pool_shift(xs[0], spec.k, spec.stride, 0.0, jnp.add)
            return s / (spec.k * spec.k)
        return run
    if kind == "gap":
        return lambda xs: xs[0].mean(axis=(1, 2), keepdims=True)
    if kind == "concat":
        return lambda xs: jnp.concatenate(xs, axis=-1)
    if kind == "add":
        return lambda xs: xs[0] + xs[1]
    if kind == "split":
        return lambda xs: xs[0][..., :spec.c_out]
    if kind == "shuffle":
        def run(xs):
            x = xs[0]
            b, h, w, c = x.shape
            return (x.reshape(b, h, w, 2, c // 2)
                    .transpose(0, 1, 2, 4, 3).reshape(b, h, w, c))
        return run
    raise ValueError(kind)


# --------------------------------------------------------------------------
# module-level emission
# --------------------------------------------------------------------------

_SITE_STATS = {
    # amplitude statistic a calibration capture records per quant site;
    # scale_from_amax turns any of them into a frozen per-tensor scale
    "amax": lambda v: jnp.max(jnp.abs(v)),
    "pct99": lambda v: jnp.percentile(jnp.abs(v), 99.0),
    # per-batch statistic is plain amax; the exponential averaging across
    # served batches happens at the serving layer (frozen-scale blending)
    "ema": lambda v: jnp.max(jnp.abs(v)),
}


def backend_pass(ir: ModuleIR) -> LoweredModule:
    m = ir.module
    chains_by_head = {c.head: c for c in ir.chains}
    consumed = {nm for c in ir.chains for nm in c.names()[1:]}
    calib = set(ir.calib_sites)
    site_stat = _SITE_STATS[ir.calibrator]

    preps: dict[str, Callable] = {}
    chain_params: dict[str, tuple[str, ...]] = {}
    # steps: (value_name, kind, payload) unrolled in node order at trace
    # time; param/chain payloads carry (prep_name, inputs, run, amax_site)
    # where amax_site is None (uncalibrated) or a capture spec.
    steps: list[tuple] = []
    for n in m.nodes:
        ann = ir.ann[n.name]
        if ann.path == PATH_GLUE:
            steps.append((n.name, "shuffle_glue", None))
            continue
        if n.name in consumed:
            continue                   # produced by its chain's head step
        if n.name in chains_by_head:
            chain = chains_by_head[n.name]
            prep, run = _lower_chain(chain, ir.use_pallas)
            preps[n.name] = prep
            chain_params[n.name] = chain.names()
            site = ("full",) if n.name in calib else None
            steps.append((chain.out, "param",
                          (n.name, n.inputs, run, site)))
            continue
        if ann.path == PATH_FREE:
            steps.append((n.name, "free", (n.inputs, _lower_pointfree(n))))
            continue
        if ann.path == PATH_GCONV:
            prep, run, g = _lower_gconv(n, ann.gconv_frac)
            site = ("gconv", g) if n.name in calib else None
        elif ann.path == PATH_INT8:
            prep, run = _lower_fpga_int8(n, ir.use_pallas)
            site = ("full",) if n.name in calib else None
        elif ann.path == PATH_FQ:
            prep, run = _lower_fpga_fq(n)
            site = ("full",) if n.name in calib else None
        else:
            assert ann.path == PATH_GPU
            prep, run = _lower_gpu(n)
            site = None
        preps[n.name] = prep
        steps.append((n.name, "param", (n.name, n.inputs, run, site)))

    def prepare(params_m):
        out = {}
        for nm, prep in preps.items():
            if nm in chain_params:     # chain: several raw param leaves
                out[nm] = prep({cn: params_m[cn]
                                for cn in chain_params[nm]})
            else:
                out[nm] = prep(params_m[nm])
        return out

    def _execute(prepared_m, x, record=None):
        values = {"in": x}
        for out_name, kind, payload in steps:
            if kind == "shuffle_glue":
                if out_name == "split":
                    half = m.node("split").spec.c_out
                    values["split"] = x[..., half:]
                    values["_identity"] = x[..., :half]
                else:
                    values["cat"] = jnp.concatenate(
                        [values["_identity"],
                         values[m.node("cat").inputs[1]]], axis=-1)
                continue
            if kind == "free":
                inputs, fn = payload
                values[out_name] = fn([values[i] for i in inputs])
                continue
            pname, inputs, fn, site = payload
            v = values[inputs[0]]
            if record is not None and site is not None:
                probe = v if site[0] == "full" else v[..., :site[1]]
                record[pname] = site_stat(probe)
            values[out_name] = fn(prepared_m[pname], v)
        out = values[m.output]
        if m.residual:
            out = out + x
        return out

    def run(prepared_m, x):
        return _execute(prepared_m, x)

    def capture(prepared_m, x):
        record: dict = {}
        y = _execute(prepared_m, x, record=record)
        return y, record

    return LoweredModule(ir, prepare, run, capture, steps)
