"""Typed lowering IR shared by the compiler passes.

A module is lowered through an explicit pipeline (annotate -> fuse ->
calibrate -> backend).  Each pass reads and refines a ``ModuleIR``:

  * ``annotate``  tags every graph node with its device and lowering path
                  (``NodeAnn``) from the partition plan;
  * ``fuse``      groups FPGA-resident runs of nodes into ``Chain``s the
                  fused kernel can execute in one VMEM-resident sweep;
  * ``calibrate`` marks the activation-quantization sites whose scales can
                  be frozen at prepare time (plan-gated);
  * ``backend``   emits the executable program (prepare / run / capture
                  closures consumed by ``repro.core.executor``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable

from repro.core.graph import ModuleGraph, Node

if TYPE_CHECKING:                       # no runtime import: schedule imports
    from repro.core.schedule import Plan     # the fuse pass for its cost model

# lowering paths a node can take (NodeAnn.path)
PATH_GPU = "gpu"                # fp32 XLA path, unchanged
PATH_INT8 = "int8_gemm"         # true-int8 GEMM, resident int8 weights
PATH_FQ = "fake_quant"          # FPGA conv with fake-quantized weights
PATH_GCONV = "gconv"            # paper Fig.2b input-channel split
PATH_FREE = "free"              # parameter-free op (pool/concat/...)
PATH_GLUE = "shuffle_glue"      # shuffle-unit split/cat bookkeeping

_CONVISH = ("conv", "dwconv", "pwconv", "fc")


@dataclass
class NodeAnn:
    """Per-node device/quantization annotation (plan-annotation pass)."""
    node: Node
    device: str                        # "gpu" | "fpga"
    path: str                          # one of the PATH_* tags
    gconv_frac: float | None = None    # set when path == PATH_GCONV


@dataclass(frozen=True)
class Chain:
    """A fused FPGA chain: [lead pw1x1] -> dw3x3/stride -> pw1x1."""
    nodes: tuple[Node, ...]            # length 2 (dw,pw) or 3 (pw,dw,pw)

    @property
    def lead(self) -> Node | None:
        return self.nodes[0] if len(self.nodes) == 3 else None

    @property
    def dw(self) -> Node:
        return self.nodes[-2]

    @property
    def pw(self) -> Node:
        return self.nodes[-1]

    @property
    def head(self) -> str:
        """Name keying the chain's prepared params and its quant site."""
        return self.nodes[0].name

    @property
    def out(self) -> str:
        """Value name the chain produces (the last node's)."""
        return self.nodes[-1].name

    @property
    def stride(self) -> int:
        return self.dw.spec.stride

    def names(self) -> tuple[str, ...]:
        return tuple(n.name for n in self.nodes)


@dataclass
class ModuleIR:
    """One module's state as it moves through the pass pipeline."""
    module: ModuleGraph
    plan: "Plan | None"
    use_pallas: bool
    ann: dict[str, NodeAnn] = field(default_factory=dict)
    chains: list[Chain] = field(default_factory=list)
    calib_sites: tuple[str, ...] = ()
    calibrator: str = "amax"           # site statistic the capture records


@dataclass
class LoweredModule:
    """Backend-pass output: the executable program for one module.

    ``steps`` is the typed step list the run/capture closures execute —
    ``(value_name, kind, payload)`` tuples in graph order (kinds:
    ``shuffle_glue`` / ``free`` / ``param``).  The stage-partition pass
    (``passes/stage.py``) re-cuts this list at device boundaries, executing
    the SAME per-step closures, which is what makes pipelined stage
    execution bit-identical to the monolithic program.
    """
    ir: ModuleIR
    prepare: Callable                  # params_m -> prepared_m
    run: Callable                      # (prepared_m, x) -> y
    capture: Callable                  # (prepared_m, x) -> (y, {site: stat})
    steps: list[tuple] = field(default_factory=list)
