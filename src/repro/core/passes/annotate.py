"""Plan-annotation pass: tag every graph node with device + lowering path.

This is the pipeline's front door: it turns the partition plan's routing
decisions (``assign`` / ``gconv``) into per-node ``NodeAnn`` records that
the later passes refine.  Priority order per conv-ish node:

  gconv split  >  true-int8 GEMM (fc / groups==1 conv on FPGA)
               >  fake-quantized FPGA conv  >  fp32 GPU path
"""
from __future__ import annotations

from repro.core.passes.ir import (_CONVISH, PATH_FQ, PATH_FREE, PATH_GCONV,
                                  PATH_GLUE, PATH_GPU, PATH_INT8, ModuleIR,
                                  NodeAnn)


def annotate_pass(ir: ModuleIR) -> ModuleIR:
    m, plan = ir.module, ir.plan
    assign = plan.assign if plan else {}
    gconv = plan.gconv if plan else {}
    for n in m.nodes:
        if m.kind == "shuffle_unit" and n.name in ("split", "cat"):
            ir.ann[n.name] = NodeAnn(n, "gpu", PATH_GLUE)
            continue
        if n.spec.kind not in _CONVISH:
            ir.ann[n.name] = NodeAnn(n, "gpu", PATH_FREE)
            continue
        fpga = assign.get(n.name) == "fpga"
        device = "fpga" if fpga or n.name in gconv else "gpu"
        if n.name in gconv:
            ann = NodeAnn(n, device, PATH_GCONV, gconv_frac=gconv[n.name])
        elif fpga and (n.spec.kind == "fc"
                       or (n.spec.kind in ("conv", "pwconv")
                           and n.spec.groups == 1)):
            ann = NodeAnn(n, device, PATH_INT8)
        elif fpga:
            ann = NodeAnn(n, device, PATH_FQ)
        else:
            ann = NodeAnn(n, device, PATH_GPU)
        ir.ann[n.name] = ann
    return ir
