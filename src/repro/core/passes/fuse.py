"""Chain-fusion pass: group FPGA-resident runs into fused-kernel chains.

Generalizes the original dw3x3+pw1x1 pairing to every chain shape the
``fused_chain`` kernel executes in one VMEM-resident sweep:

  * dw3x3 (stride 1 or 2) -> pw1x1                (MBv2 tails, ShuffleNetV2
                                                   down-branch 1)
  * pw1x1 -> dw3x3 (stride 1 or 2) -> pw1x1       (ShuffleNetV2 working
                                                   branches, MBv2 full
                                                   expand+dw+project)

The same grouping drives the partitioner's cost model (``cost_groups``):
each fused group pays one pipeline fill, so longer fusable chains reduce
per-node FPGA overheads — which is exactly why the plan search should
prefer them.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable

from repro.core.graph import ModuleGraph, Node
from repro.core.passes.ir import PATH_GCONV, Chain, ModuleIR

if TYPE_CHECKING:
    from repro.core.schedule import Plan

_CHAIN_ACTS = ("none", "relu", "relu6")


def _is_pw(n: Node) -> bool:
    return (n.spec.kind == "pwconv" and n.spec.k == 1
            and n.spec.stride == 1 and n.spec.groups == 1
            and n.act in _CHAIN_ACTS)


def _is_dw(n: Node) -> bool:
    """dw3x3 multiplier-1, stride 1 or 2 — what the kernel's shift-add
    stage implements."""
    return (n.spec.kind == "dwconv" and n.spec.k == 3
            and n.spec.stride in (1, 2) and n.spec.c_in == n.spec.c_out
            and n.act in _CHAIN_ACTS)


def _group_linear(nodes: list[Node],
                  linked: Callable[[Node, Node], bool]) -> list[list[Node]]:
    """Greedy longest-match grouping of an ordered node list: pw-dw-pw
    first, then dw-pw, else singleton.  ``linked(a, b)`` decides whether
    b may consume a inside a fused pipeline."""
    groups: list[list[Node]] = []
    i = 0
    while i < len(nodes):
        trio = nodes[i:i + 3]
        if (len(trio) == 3 and _is_pw(trio[0]) and _is_dw(trio[1])
                and _is_pw(trio[2]) and linked(trio[0], trio[1])
                and linked(trio[1], trio[2])):
            groups.append(trio)
            i += 3
            continue
        duo = nodes[i:i + 2]
        if (len(duo) == 2 and _is_dw(duo[0]) and _is_pw(duo[1])
                and linked(duo[0], duo[1])):
            groups.append(duo)
            i += 2
            continue
        groups.append([nodes[i]])
        i += 1
    return groups


def chain_groups(m: ModuleGraph, plan: "Plan | None") -> list[list[Node]]:
    """Fusable groups inside ``plan.fused`` (singletons included).  A link
    a->b holds when b is a's sole consumer, a is not the module output,
    and both are FPGA-assigned outside any gconv split."""
    if not plan or not plan.fused:
        return []
    names = [nm for nm in plan.fused if m.has_node(nm)]
    nodes = [m.node(nm) for nm in names]
    eligible = {
        n.name for n in nodes
        if plan.assign.get(n.name) == "fpga" and n.name not in plan.gconv}

    def linked(a: Node, b: Node) -> bool:
        return (a.name in eligible and b.name in eligible
                and b.inputs == (a.name,) and a.name != m.output
                and len(m.consumers(a.name)) == 1)

    return _group_linear(nodes, linked)


def cost_groups(nodes: list[Node]) -> list[list[Node]]:
    """Grouping for the COST model, where chains arrive as bare node lists
    (possibly synthetic): adjacency-only links — the sole-consumer check
    needs the module graph, but a mis-grouped multi-consumer node can only
    appear in non-linear chains that the patterns reject anyway."""
    return _group_linear(nodes, lambda a, b: b.inputs == (a.name,))


def fuse_pass(ir: ModuleIR) -> ModuleIR:
    """Attach ``Chain``s for every fusable group of length >= 2."""
    for group in chain_groups(ir.module, ir.plan):
        if len(group) < 2:
            continue
        if any(ir.ann[n.name].path == PATH_GCONV for n in group):
            continue                    # defensive: gconv never fuses
        ir.chains.append(Chain(tuple(group)))
    return ir
