"""Calibration pass: mark activation-quant sites whose scales freeze at
prepare time.

When a plan opts in (``Plan.calibrate``), every FPGA activation-quantization
site — fused-chain entries, int8-GEMM inputs, fake-quant conv inputs and
gconv FPGA slices — is recorded by name.  The backend then emits a
``capture`` program that runs a calibration batch through the module and
returns one amplitude statistic per site; ``prepare`` freezes those into
per-tensor scales, and the run program drops the per-call amax reductions.

Three calibrator kinds (``Plan.calibrate``):

  * ``True`` / ``"amax"``  absolute max over the calibration batch — no
    clipping, the original behaviour;
  * ``"pct99"``            99th percentile of |activation| — clips the
    outlier tail, trading saturation of rare spikes for finer grid
    resolution on the bulk of the distribution;
  * ``"ema"``              absolute max at prepare time, then refined
    online: the serving layer captures the same statistic on the first K
    served batches and blends it into the frozen scale as an exponential
    moving average (``repro.serving.server``), so scales converge to the
    live traffic distribution instead of the calibration batch's.

Plans that do NOT opt in keep per-sample scales (``axis=0``), preserving
the serving batch-invariance contract exactly as before.  Frozen scales
preserve it trivially — a constant scale can't couple batch rows — but
they change numerics, so every distinct calibrator kind compiles (and
caches, and serves) under a different plan signature.
"""
from __future__ import annotations

from repro.core.passes.ir import PATH_FQ, PATH_GCONV, PATH_INT8, ModuleIR

CALIBRATORS = ("amax", "pct99", "ema")


def calibrator_kind(calibrate) -> str | None:
    """Normalize ``Plan.calibrate`` (False/True/"amax"/"pct99") to a kind
    name, or None when calibration is off.  Raises on unknown kinds so a
    typo fails at plan-signature/lowering time, not silently at serve
    time."""
    if not calibrate:
        return None
    kind = "amax" if calibrate is True else str(calibrate)
    if kind not in CALIBRATORS:
        raise ValueError(f"unknown calibrator {calibrate!r}; expected "
                         f"True or one of {CALIBRATORS}")
    return kind


def calibrate_pass(ir: ModuleIR) -> ModuleIR:
    kind = calibrator_kind(getattr(ir.plan, "calibrate", False)
                           if ir.plan else False)
    if kind is None:
        return ir
    ir.calibrator = kind
    in_chain = {nm for c in ir.chains for nm in c.names()}
    sites = [c.head for c in ir.chains]
    sites += [nm for nm, a in ir.ann.items()
              if a.path in (PATH_INT8, PATH_FQ, PATH_GCONV)
              and nm not in in_chain]
    # execution order (graph node order) keeps capture deterministic
    order = {n.name: i for i, n in enumerate(ir.module.nodes)}
    ir.calib_sites = tuple(sorted(sites, key=lambda nm: order[nm]))
    return ir
