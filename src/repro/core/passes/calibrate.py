"""Calibration pass: mark activation-quant sites whose scales freeze at
prepare time.

When a plan opts in (``Plan.calibrate``), every FPGA activation-quantization
site — fused-chain entries, int8-GEMM inputs, fake-quant conv inputs and
gconv FPGA slices — is recorded by name.  The backend then emits a
``capture`` program that runs a calibration batch through the module and
returns each site's absolute-max activation; ``prepare`` freezes those into
per-tensor scales, and the run program drops the per-call amax reductions.

Plans that do NOT opt in keep per-sample scales (``axis=0``), preserving
the serving batch-invariance contract exactly as before.  Frozen scales
preserve it trivially — a constant scale can't couple batch rows — but
they change numerics, so calibrated and uncalibrated plans compile (and
cache, and serve) under different plan signatures.
"""
from __future__ import annotations

from repro.core.passes.ir import PATH_FQ, PATH_GCONV, PATH_INT8, ModuleIR


def calibrate_pass(ir: ModuleIR) -> ModuleIR:
    if not ir.plan or not getattr(ir.plan, "calibrate", False):
        return ir
    in_chain = {nm for c in ir.chains for nm in c.names()}
    sites = [c.head for c in ir.chains]
    sites += [nm for nm, a in ir.ann.items()
              if a.path in (PATH_INT8, PATH_FQ, PATH_GCONV)
              and nm not in in_chain]
    # execution order (graph node order) keeps capture deterministic
    order = {n.name: i for i, n in enumerate(ir.module.nodes)}
    ir.calib_sites = tuple(sorted(sites, key=lambda nm: order[nm]))
    return ir
