"""Worker process entrypoint: one ``HeteroServer`` + front door per OS
process, shared-nothing.

Each worker owns its own compiled-plan residency: the spec (a plain JSON
dict) names the networks to register, and ``build_server`` compiles,
prepares and bucket-warms them inside THIS process — nothing is shared
with siblings, so a worker crash takes down exactly one plan residency
and a respawned worker re-registers from the same spec (crash-resume is
"re-run the registration", not state recovery).  Parameters are
deterministic per spec (``init_network`` under the spec's seed), so every
worker spawned from one spec serves bit-identical rows — the property
that lets the router retry a request on a DIFFERENT worker without
changing its answer.

Spec schema (everything optional but ``networks``):

    {"networks": [{"kind": "zoo",  "name": "mobilenetv2", "res": [32, 32],
                   "seed": 0, "buckets": [1, 4, 8], "pipelined": false,
                   "paper_faithful": true},
                  {"kind": "fire", "name": "tiny", "hw": [8, 8],
                   "c_in": 16, "squeeze": 4, "expand": 8, "seed": 0}],
     "server":  {"max_wait_ms": 2.0, "max_queue": 64, "in_flight": 1},
     "door":    {"rate": null, "burst": 64, "max_pending": null,
                 "weights": {"0": 3.0, "1": 1.0}},
     "http":    {"idle_timeout_s": 30.0, "conn_inflight": 8},
     "host": "127.0.0.1", "port": 0, "drain_budget_s": 10.0}

Run: ``python -m repro.frontend.worker --spec '<json>'``.  The process
prints ``READY host=<h> port=<p> pid=<pid>`` on stdout once the door is
listening (the supervisor's startup handshake), serves until SIGTERM (or
a ``POST /drain``), gracefully drains — fence, flush, resolve every
admitted future, PR-6 semantics — and exits 0.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import signal
import sys

from repro.frontend.app import DRAIN_BUDGET_S, FrontDoor, LocalBackend


def _build_mods(net: dict):
    kind = net.get("kind", "zoo")
    if kind == "zoo":
        from repro.core.graph import NETWORKS
        return NETWORKS[net["name"]]()
    if kind == "fire":
        # the test-suite workload: one tiny fire module, compiles in
        # seconds — keeps multi-process tests CI-budgetable
        from repro.core.graph import fire
        hw = net.get("hw", [8, 8])
        return [fire(net.get("name", "tiny"), int(hw[0]),
                     int(net.get("c_in", 16)), int(net.get("squeeze", 4)),
                     int(net.get("expand", 8)))]
    raise ValueError(f"unknown network kind {kind!r}")


def _register_name(net: dict) -> str:
    return net.get("as") or net.get("name") or "net"


def build_server(spec: dict):
    """Compile/prepare/warm every network in ``spec`` into a started
    ``HeteroServer`` — the one code path both worker processes and the
    router's in-process workers build from, so a crash-resume respawn
    reconstructs exactly the residency the dead worker had."""
    import jax

    from repro.core.hetero import init_network
    from repro.core.partitioner import partition_network
    from repro.serving import HeteroServer

    server = HeteroServer(**spec.get("server", {}))
    for net in spec["networks"]:
        mods = _build_mods(net)
        plans = None
        if net.get("plans", "partitioned") == "partitioned":
            plans = partition_network(
                mods, paper_faithful=bool(net.get("paper_faithful", True)))
        params = init_network(mods, jax.random.PRNGKey(
            int(net.get("seed", 0))))
        hw = net.get("res") or net.get("hw") or [8, 8]
        kwargs = {}
        if net.get("buckets"):
            kwargs["buckets"] = tuple(net["buckets"])
        server.register(_register_name(net), mods, plans, params,
                        input_hw=tuple(int(v) for v in hw),
                        pipelined=bool(net.get("pipelined", False)),
                        **kwargs)
    return server.start()


def make_door(spec: dict):
    """(FrontDoor, LocalBackend) for a spec — unstarted; the caller owns
    the event loop."""
    server = build_server(spec)
    backend = LocalBackend(
        server,
        drain_budget_s=float(spec.get("drain_budget_s", DRAIN_BUDGET_S)),
        **spec.get("door", {}))
    door = FrontDoor(backend, host=spec.get("host", "127.0.0.1"),
                     port=int(spec.get("port", 0)),
                     **spec.get("http", {}))
    return door, backend


async def _serve(spec: dict) -> int:
    door, backend = make_door(spec)
    await door.start()
    print(f"READY host={door.host} port={door.port} pid={os.getpid()}",
          flush=True)
    done = asyncio.Event()
    loop = asyncio.get_running_loop()

    def _term():
        if not backend.draining:
            asyncio.ensure_future(_drain())

    async def _drain():
        await door.drain_and_close()
        done.set()

    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            loop.add_signal_handler(sig, _term)
        except NotImplementedError:     # non-posix fallback
            signal.signal(sig, lambda *_: _term())
    # a POST /drain must also end the process: wake on the backend fence
    while not done.is_set():
        if backend.draining and backend._drain_result is not None:
            await door.aclose()
            break
        try:
            await asyncio.wait_for(done.wait(), 0.1)
        except asyncio.TimeoutError:
            pass
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.frontend.worker")
    ap.add_argument("--spec", help="worker spec as a JSON string")
    ap.add_argument("--spec-file", help="worker spec as a JSON file path")
    args = ap.parse_args(argv)
    if not args.spec and not args.spec_file:
        ap.error("--spec or --spec-file is required")
    spec = (json.loads(args.spec) if args.spec
            else json.load(open(args.spec_file)))
    return asyncio.run(_serve(spec))


if __name__ == "__main__":
    sys.exit(main())
