"""Asyncio HTTP front door over an in-process ``HeteroServer``.

The last layer between the compiled heterogeneous engine and real
multiplexed traffic: requests arrive over HTTP/1.1 (stdlib asyncio only
— no new dependencies), are admission-checked BEFORE their body is read,
decoded, submitted to the server's batching lanes with their
``deadline_ms``/``priority`` propagated, and answered from the request
future.  The PR-6 typed errors cross the process boundary as stable wire
codes instead of tracebacks (``repro.frontend.wire``): ``Overloaded`` ->
429 + Retry-After, ``DeadlineExceeded`` -> 504, ``ServerClosed``/
``Shutdown`` -> 503.

**Protocol v2 (keep-alive).**  The door honors ``Connection:
keep-alive`` (the HTTP/1.1 default): one socket carries many
request/response rounds.  A reader task parses heads and bodies in
order; each admitted request runs as its own task while the NEXT
request is already being read, and a per-connection writer task sends
the responses back in request order — so a slow inference never
deadlocks the socket and a burst of pipelined requests overlaps with
batching.  Two bounds keep a connection honest: ``idle_timeout_s``
closes a socket with no request in flight and nothing arriving, and
``conn_inflight`` caps unanswered requests per connection (the reader
stops parsing until responses drain — backpressure, not a 429, because
the client self-inflicted the queue).  Both framings of
``repro.frontend.wire`` are served: JSON-base64 (default) and
``application/x-tensor`` request bodies, with the response framing
negotiated via ``Accept``.

**Admission path** (cheapest check first, all before deserialization):

  1. drain fence / server state      -> 503 ``shutdown``/``server_closed``
  2. weighted per-priority token buckets (``rate``/``burst``/
     ``weights``)                    -> 429 ``overloaded`` (gate=rate)
  3. pending-futures bound (``max_pending``, read from the server's
     metrics gauges)                 -> 429 ``overloaded`` (gate=pending)
  4. body size sanity                -> 413 (connection closed)
  5. ``HeteroServer.submit`` itself  -> per-lane queue bound, typed 429

The admission class is read pre-body from the ``X-Priority`` header
(class 1 if absent): ``WeightedTokenBuckets`` splits the refill rate by
per-class weights (default ``{0: 3, 1: 1}``), so when the door
saturates, deadline-critical class-0 traffic sheds LAST instead of
competing in one global bucket.

**Endpoints.**  ``POST /v1/infer`` (inference), ``GET /healthz`` (cheap
liveness: ok flag + the gauges, served from one
``ServerMetrics.snapshot()``), ``GET /metrics`` (the full snapshot),
``POST /drain`` (fence + graceful drain, also wired to SIGTERM).

**Drain.**  ``drain()`` fences new admissions (every later request gets
a typed 503), then runs ``HeteroServer.shutdown`` off-loop under a hard
budget — every already-admitted future resolves (row or typed error; the
PR-6 contract), and the door answers each of them before the sockets
close.  A drain never hangs: the shutdown call itself is bounded and the
fence guarantees the in-flight set only shrinks.

``faults.trip("conn")`` fires per parsed request head (the
connection-loop trigger point: the error is answered typed and the
socket survives) and ``faults.trip("http")`` fires in the handler
between decode and submit, so front-door failures are injectable in CI
exactly like device faults (``repro.runtime.faults``).
"""
from __future__ import annotations

import asyncio
import json
import threading
import time

from repro.frontend import wire
from repro.runtime import faults
from repro.serving.errors import DeadlineExceeded, ServerClosed, Shutdown

DRAIN_BUDGET_S = 10.0
DEFAULT_LANE_WEIGHTS = {0: 3.0, 1: 1.0}


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, ``burst`` capacity.
    ``rate=None`` disables the gate.  Not thread-safe — it lives on the
    event loop (one caller) by construction."""

    def __init__(self, rate: float | None, burst: int = 32):
        self.rate = rate
        self.burst = max(1, int(burst))
        self._tokens = float(self.burst)
        self._t = time.monotonic()

    def _refill(self) -> None:
        now = time.monotonic()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._t) * self.rate)
        self._t = now

    def admit(self) -> bool:
        if self.rate is None:
            return True
        self._refill()
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False

    def retry_after_s(self) -> float:
        """Seconds until one token exists — recomputed from
        ``time.monotonic()`` NOW, not from the last ``admit()`` call's
        time base, so a bucket probed without traffic reports the true
        remaining wait instead of a stale (or zero) one."""
        if self.rate is None or self.rate <= 0:
            return 0.05
        self._refill()
        return max(0.001, (1.0 - self._tokens) / self.rate)


class WeightedTokenBuckets:
    """Per-priority-class admission: one ``TokenBucket`` per class, the
    total refill ``rate`` split by ``weights`` (class -> share).  Under
    saturation each class degrades to its own weighted rate instead of
    racing for one global bucket — the deadline-critical class-0 lane
    (default weight 3) sheds LAST.  Unknown classes ride the
    lowest-weight bucket; ``rate=None`` disables every gate."""

    def __init__(self, rate: float | None, burst: int = 64,
                 weights: dict | None = None):
        self.rate = rate
        ws = {int(k): float(v)
              for k, v in (weights or DEFAULT_LANE_WEIGHTS).items()}
        if not ws or any(v <= 0 for v in ws.values()):
            raise ValueError(f"lane weights must be positive: {ws}")
        total = sum(ws.values())
        self.weights = ws
        self.buckets = {
            p: TokenBucket(None if rate is None else rate * w / total,
                           max(1, round(burst * w / total)))
            for p, w in ws.items()}
        self._fallback = min(ws, key=ws.get)

    def bucket_for(self, priority: int) -> TokenBucket:
        return self.buckets.get(int(priority), self.buckets[self._fallback])

    def admit(self, priority: int = 1) -> bool:
        return self.bucket_for(priority).admit()

    def retry_after_s(self, priority: int = 1) -> float:
        return self.bucket_for(priority).retry_after_s()


class LocalBackend:
    """One in-process ``HeteroServer`` behind the door — the single-worker
    backend, and the request semantics every worker process serves.

    The same object backs the router's in-process workers
    (``repro.frontend.router.LocalWorker``), so wire semantics are ONE
    code path whether a request crossed a socket or not.
    """

    def __init__(self, server, *, rate: float | None = None,
                 burst: int = 64, weights: dict | None = None,
                 max_pending: int | None = None,
                 request_timeout_s: float = 60.0,
                 drain_budget_s: float = DRAIN_BUDGET_S):
        self.server = server
        self.buckets = WeightedTokenBuckets(rate, burst, weights)
        self.max_pending = max_pending
        self.request_timeout_s = request_timeout_s
        self.drain_budget_s = drain_budget_s
        self.draining = False
        self.sheds = 0                     # admission-gate rejections
        self.sheds_by_class: dict[int, int] = {}
        self._drain_result: dict | None = None

    # -- admission (pre-body: nothing here touches the payload) ------------

    def admit(self, priority: int = 1):
        """None to admit, else a (status, body, headers) shed reply.
        Called after the request HEAD is parsed and before the body is
        read — an overloaded door never pays deserialization for a
        request it rejects.  ``priority`` is the admission class from
        the ``X-Priority`` header (weighted buckets)."""
        if self.draining:
            return wire.error_reply(Shutdown("draining: admission fenced"))
        if self.server.state != "running":
            return wire.error_reply(ServerClosed(
                f"server is {self.server.state}, not running"))
        if not self.buckets.admit(priority):
            self.sheds += 1
            key = int(priority)
            self.sheds_by_class[key] = self.sheds_by_class.get(key, 0) + 1
            return wire.shed_reply(
                "rate", retry_after_s=self.buckets.retry_after_s(priority))
        if self.max_pending is not None:
            gauges = self.server.metrics.snapshot()["gauges"]
            if gauges.get("pending_requests", 0) >= self.max_pending:
                self.sheds += 1
                return wire.shed_reply("pending")
        return None

    # -- request path ------------------------------------------------------

    async def infer(self, payload: dict):
        """(status, body, headers) for one /v1/infer payload.  The array
        arrives as JSON-base64 fields, a raw binary frame under
        ``_tensor``, or pre-decoded under ``_x``; a 200 body carries the
        served row un-encoded under ``_row`` (the door encodes it at the
        edge, in the client's negotiated framing)."""
        try:
            faults.trip("http")
            if "_tensor" in payload:
                x = wire.decode_tensor(payload["_tensor"])
            elif "_x" in payload:
                x = payload["_x"]
            else:
                x = wire.decode_array(payload)
            fut = self.server.submit(
                payload["network"], x,
                priority=int(payload.get("priority", 1)),
                deadline_ms=payload.get("deadline_ms"))
        except Exception as e:
            reply = wire.error_reply(e)
            if reply[0] == 400:
                # malformed wire bodies are a tracked failure class, not
                # an anonymous error
                self.server.metrics.count("bad_requests")
            return reply
        try:
            row = await asyncio.wait_for(asyncio.wrap_future(fut),
                                         self.request_timeout_s)
        except asyncio.TimeoutError:
            # the future may still resolve — answer 504 NOT retryable so
            # no router re-issues a possibly-still-running request
            return wire.error_reply(DeadlineExceeded(
                f"no result within {self.request_timeout_s}s",
                waited_s=self.request_timeout_s))
        except Exception as e:
            return wire.error_reply(e)
        return 200, {"network": payload["network"], "_row": row}, {}

    async def health(self):
        snap = self.server.metrics.snapshot()
        gauges = snap.get("gauges", {})
        ok = (not self.draining
              and gauges.get("state", self.server.state) == "running")
        body = {"ok": ok, "state": gauges.get("state", self.server.state),
                "draining": self.draining,
                "uptime_s": snap.get("uptime_s", 0.0),
                "pending_requests": gauges.get("pending_requests", 0),
                "inflight_batches": gauges.get("inflight_batches", 0),
                "queue_total": gauges.get("queue_total", 0),
                "queue_depth": gauges.get("queue_depth", {}),
                "completed": snap.get("completed", 0),
                "bad_requests": snap.get("bad_requests", 0),
                "shed": snap.get("shed", 0) + self.sheds,
                "sheds_by_class": dict(self.sheds_by_class)}
        return (200 if ok else 503), body, {}

    async def metrics(self):
        return 200, self.server.metrics.snapshot(), {}

    async def drain(self, budget_s: float | None = None):
        """Fence admissions, then gracefully shut the server down off-loop
        under a hard budget.  Idempotent; never hangs."""
        if self._drain_result is not None:
            return 200, self._drain_result, {}
        self.draining = True
        budget = budget_s if budget_s is not None else self.drain_budget_s
        t0 = time.monotonic()
        loop = asyncio.get_running_loop()
        try:
            await asyncio.wait_for(
                loop.run_in_executor(None, self.server.shutdown, budget),
                budget + 1.0)
            timed_out = False
        except asyncio.TimeoutError:    # wedged drain thread: report, the
            timed_out = True            # sweep already fenced admissions
        snap = self.server.metrics.snapshot()
        self._drain_result = {
            "drained": not timed_out,
            "elapsed_s": time.monotonic() - t0,
            "pending_requests": snap["gauges"].get("pending_requests", 0),
            "drain_aborted": snap.get("drain_aborted", 0),
            "drain_flushed": snap.get("drain_flushed", 0)}
        return 200, self._drain_result, {}


class FrontDoor:
    """The HTTP surface: routes requests on one asyncio server to any
    backend exposing ``admit``/``infer``/``health``/``metrics``/``drain``
    (``LocalBackend`` for a worker process, ``repro.frontend.router.
    Router`` for the multi-worker door).

    Protocol v2: keep-alive sockets with pipelined in-order responses,
    bounded by ``idle_timeout_s`` (close a quiet connection) and
    ``conn_inflight`` (max unanswered requests per connection before the
    reader stops parsing — per-socket backpressure)."""

    def __init__(self, backend, *, host: str = "127.0.0.1", port: int = 0,
                 idle_timeout_s: float = 30.0, conn_inflight: int = 8):
        self.backend = backend
        self.host = host
        self.port = port
        self.idle_timeout_s = idle_timeout_s
        self.conn_inflight = max(1, int(conn_inflight))
        self._srv: asyncio.AbstractServer | None = None
        self.requests = 0
        self.connections = 0
        self.keepalive_reuses = 0       # requests beyond a socket's first

    async def start(self) -> "FrontDoor":
        self._srv = await asyncio.start_server(self._handle, self.host,
                                               self.port)
        self.port = self._srv.sockets[0].getsockname()[1]
        return self

    async def aclose(self) -> None:
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()
            self._srv = None

    async def drain_and_close(self, budget_s: float | None = None) -> dict:
        """SIGTERM path: fence + drain the backend, then stop listening.
        In-flight handler tasks still hold their sockets and answer."""
        _status, body, _h = await self.backend.drain(budget_s)
        await self.aclose()
        return body

    # -- connection handler ------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        """One keep-alive connection: this reader loop parses request
        heads and bodies IN ORDER, admission-checks between them, and
        enqueues each request's (future, keepalive, accept) for the
        writer task — which answers in the same order while the reader
        is already parsing the next request."""
        self.connections += 1
        queue: asyncio.Queue = asyncio.Queue(maxsize=self.conn_inflight)
        pending = [0]                   # enqueued, not yet answered
        wtask = asyncio.ensure_future(self._writer_loop(writer, queue,
                                                        pending))
        first = True
        try:
            while not wtask.done():
                try:
                    head = await asyncio.wait_for(wire.read_head(reader),
                                                  self.idle_timeout_s)
                except asyncio.TimeoutError:
                    if pending[0] > 0:
                        continue        # responses in flight: not idle
                    break               # idle: close the socket
                if head is None:
                    break               # EOF or unparseable head
                method, path, headers, version = head
                self.requests += 1
                if not first:
                    self.keepalive_reuses += 1
                first = False
                keep = wire.wants_keepalive(version, headers)
                item = await self._read_and_route(method, path, headers,
                                                  reader)
                if item is None:
                    break               # transport died mid-body
                result, force_close = item
                keep = keep and not force_close
                pending[0] += 1
                await queue.put((result, keep, headers.get("accept")))
                if not keep:
                    break
            await queue.put(None)
            await wtask
        except (ConnectionError, asyncio.IncompleteReadError):
            pass                        # client went away: nothing to answer
        except Exception as e:          # defensive: no traceback on the wire
            try:
                writer.write(wire.response_bytes(*wire.error_reply(e)))
                await writer.drain()
            except Exception:
                pass
        finally:
            if not wtask.done():
                wtask.cancel()
                try:
                    await wtask
                except (asyncio.CancelledError, Exception):
                    pass
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _writer_loop(self, writer, queue, pending) -> None:
        """Answer queued requests in order.  On a broken client socket,
        keep CONSUMING (awaiting each result, dropping the bytes) so the
        reader's bounded queue can never wedge a backend task."""
        broken = False
        while True:
            item = await queue.get()
            if item is None:
                return
            result, keep, accept = item
            try:
                if isinstance(result, tuple):
                    status, body, extra = result
                else:
                    status, body, extra = await result
            except Exception as e:
                status, body, extra = wire.error_reply(e)
            pending[0] -= 1
            if broken:
                continue
            try:
                writer.write(self._encode(status, body, extra, keep,
                                          accept))
                await writer.drain()
            except Exception:
                broken = True
                continue
            if not keep:
                return

    @staticmethod
    def _encode(status, body, extra, keep, accept) -> bytes:
        """Serialize one response, encoding a served row (``_row``) at
        the edge in the client's negotiated framing."""
        if isinstance(body, dict) and "_row" in body:
            try:
                out, ctype, xh = wire.encode_result(body, accept)
            except Exception as e:
                return wire.response_bytes(*wire.error_reply(e),
                                           keepalive=keep)
            return wire.response_bytes(status, out, {**(extra or {}), **xh},
                                       keepalive=keep, content_type=ctype)
        if isinstance(body, (bytes, bytearray)):
            # router passthrough: an already-framed worker response
            ct = (extra or {}).get("content-type")
            return wire.response_bytes(status, body, extra, keepalive=keep,
                                       content_type=ct)
        return wire.response_bytes(status, body, extra, keepalive=keep)

    async def _read_and_route(self, method: str, path: str, headers: dict,
                              reader):
        """(result, force_close) for one parsed request head — result is
        a (status, body, headers) tuple answered immediately, or an
        asyncio future for an in-flight inference.  None means the
        transport died mid-body (close without answering)."""
        path = path.split("?", 1)[0]
        try:
            faults.trip("conn")
        except Exception as e:
            if not await self._discard_body(reader, headers):
                return None
            return wire.error_reply(e), False
        if path == "/healthz" and method == "GET":
            return await self.backend.health(), False
        if path == "/metrics" and method == "GET":
            return await self.backend.metrics(), False
        if path == "/drain" and method == "POST":
            await self._discard_body(reader, headers)
            return await self.backend.drain(), False
        if path != "/v1/infer":
            await self._discard_body(reader, headers)
            return (404, {"error": "not_found", "retryable": False,
                          "message": path}, {}), False
        if method != "POST":
            await self._discard_body(reader, headers)
            return (405, {"error": "method_not_allowed", "retryable": False,
                          "message": method}, {}), False
        # admission BEFORE the body: shed work, not just requests.  The
        # class rides in X-Priority so the weighted buckets can act here.
        shed = self.backend.admit(wire.priority_from_headers(headers))
        if shed is not None:
            if not await self._discard_body(reader, headers):
                return None
            return shed, False
        if int(headers.get("content-length", 0) or 0) > wire.MAX_BODY_BYTES:
            # refusing to read the body leaves the socket mid-stream:
            # answer 413 and force the connection closed
            return (413, {"error": "payload_too_large",
                          "retryable": False, "message": ""}, {}), True
        try:
            raw = await wire.read_body(reader, headers)
        except (ConnectionError, asyncio.IncompleteReadError, OSError):
            return None
        ctype = headers.get("content-type", "")
        if ctype.startswith(wire.TENSOR_CONTENT_TYPE):
            try:
                meta = wire.infer_meta_from_headers(headers)
            except Exception as e:
                return wire.error_reply(e), False
            payload = {**meta, "_tensor": raw}
        else:
            try:
                payload = json.loads(raw)
            except Exception as e:
                return (400, {"error": "bad_request", "retryable": False,
                              "message": f"invalid JSON: {e}"}, {}), False
            if not isinstance(payload, dict):
                return (400, {"error": "bad_request", "retryable": False,
                              "message": "request body must be a JSON "
                                         "object"}, {}), False
        if headers.get("accept"):
            # ride along so a router hop can forward the negotiation and
            # pass the worker's framed response through untranscoded
            payload["_accept"] = headers["accept"]
        return asyncio.ensure_future(self.backend.infer(payload)), False

    @staticmethod
    async def _discard_body(reader, headers) -> bool:
        """Drain a rejected request's body so the client can read the
        reply AND the next pipelined request starts at a clean byte
        boundary (a closed pipe mid-upload reads as a transport error,
        and a transport error would be retried — a shed must stay
        typed).  False if the transport died under the read."""
        try:
            await wire.read_body(reader, headers)
            return True
        except Exception:
            return False


class ServerThread:
    """Run a ``FrontDoor`` (and optionally extra startup coroutines, e.g.
    ``Router.start``) on a dedicated event loop in a daemon thread — the
    handle tests, benchmarks and examples drive blocking HTTP clients
    against.

        with ServerThread(FrontDoor(LocalBackend(server))) as h:
            requests -> 127.0.0.1:h.port
    """

    def __init__(self, door: FrontDoor, *, also_start=()):
        self.door = door
        self._also = list(also_start)   # extra "async def start()" objects
        self.loop = asyncio.new_event_loop()
        self._ready = threading.Event()
        self._thread = threading.Thread(target=self._run,
                                        name="frontdoor-loop", daemon=True)

    def _run(self) -> None:
        asyncio.set_event_loop(self.loop)

        async def boot():
            for obj in self._also:
                await obj.start()
            await self.door.start()
            self._ready.set()

        self.loop.run_until_complete(boot())
        self.loop.run_forever()
        # cancel stragglers so the loop closes clean
        for task in asyncio.all_tasks(self.loop):
            task.cancel()
        try:
            self.loop.run_until_complete(
                self.loop.shutdown_asyncgens())
        except Exception:
            pass
        self.loop.close()

    def start(self) -> "ServerThread":
        self._thread.start()
        if not self._ready.wait(30.0):
            raise RuntimeError("front door failed to start in 30s")
        return self

    @property
    def port(self) -> int:
        return self.door.port

    def call(self, coro, timeout: float = 60.0):
        """Run one coroutine on the door's loop from any thread."""
        fut = asyncio.run_coroutine_threadsafe(coro, self.loop)
        return fut.result(timeout)

    def stop(self, drain: bool = True, budget_s: float = DRAIN_BUDGET_S):
        out = None
        if self._thread.is_alive():
            if drain:
                try:
                    out = self.call(self.door.drain_and_close(budget_s),
                                    timeout=budget_s + 5.0)
                except Exception:
                    pass
            self.loop.call_soon_threadsafe(self.loop.stop)
            self._thread.join(10.0)
        return out

    def __enter__(self) -> "ServerThread":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
