"""Asyncio HTTP front door over an in-process ``HeteroServer``.

The last layer between the compiled heterogeneous engine and real
multiplexed traffic: requests arrive as JSON over HTTP/1.1 (stdlib
asyncio only — no new dependencies), are admission-checked BEFORE their
body is read, decoded, submitted to the server's batching lanes with
their ``deadline_ms``/``priority`` propagated, and answered from the
request future.  The PR-6 typed errors cross the process boundary as
stable wire codes instead of tracebacks (``repro.frontend.wire``):
``Overloaded`` -> 429 + Retry-After, ``DeadlineExceeded`` -> 504,
``ServerClosed``/``Shutdown`` -> 503.

**Admission path** (cheapest check first, all before deserialization):

  1. drain fence / server state      -> 503 ``shutdown``/``server_closed``
  2. token bucket (``rate``/``burst``) -> 429 ``overloaded`` (gate=rate)
  3. pending-futures bound (``max_pending``, read from the server's
     metrics gauges)                 -> 429 ``overloaded`` (gate=pending)
  4. body size sanity                -> 413
  5. ``HeteroServer.submit`` itself  -> per-lane queue bound, typed 429

**Endpoints.**  ``POST /v1/infer`` (inference), ``GET /healthz`` (cheap
liveness: ok flag + the gauges, served from one
``ServerMetrics.snapshot()``), ``GET /metrics`` (the full snapshot),
``POST /drain`` (fence + graceful drain, also wired to SIGTERM).

**Drain.**  ``drain()`` fences new admissions (every later request gets
a typed 503), then runs ``HeteroServer.shutdown`` off-loop under a hard
budget — every already-admitted future resolves (row or typed error; the
PR-6 contract), and the door answers each of them before the sockets
close.  A drain never hangs: the shutdown call itself is bounded and the
fence guarantees the in-flight set only shrinks.

``faults.trip("http")`` fires in the handler between decode and submit,
so front-door failures are injectable in CI exactly like device faults
(``repro.runtime.faults``).
"""
from __future__ import annotations

import asyncio
import json
import threading
import time

from repro.frontend import wire
from repro.runtime import faults
from repro.serving.errors import DeadlineExceeded, ServerClosed, Shutdown

DRAIN_BUDGET_S = 10.0


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, ``burst`` capacity.
    ``rate=None`` disables the gate.  Not thread-safe — it lives on the
    event loop (one caller) by construction."""

    def __init__(self, rate: float | None, burst: int = 32):
        self.rate = rate
        self.burst = max(1, int(burst))
        self._tokens = float(self.burst)
        self._t = time.monotonic()

    def admit(self) -> bool:
        if self.rate is None:
            return True
        now = time.monotonic()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._t) * self.rate)
        self._t = now
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False

    def retry_after_s(self) -> float:
        if self.rate is None or self.rate <= 0:
            return 0.05
        return max(0.001, (1.0 - self._tokens) / self.rate)


class LocalBackend:
    """One in-process ``HeteroServer`` behind the door — the single-worker
    backend, and the request semantics every worker process serves.

    The same object backs the router's in-process workers
    (``repro.frontend.router.LocalWorker``), so wire semantics are ONE
    code path whether a request crossed a socket or not.
    """

    def __init__(self, server, *, rate: float | None = None,
                 burst: int = 64, max_pending: int | None = None,
                 request_timeout_s: float = 60.0,
                 drain_budget_s: float = DRAIN_BUDGET_S):
        self.server = server
        self.bucket = TokenBucket(rate, burst)
        self.max_pending = max_pending
        self.request_timeout_s = request_timeout_s
        self.drain_budget_s = drain_budget_s
        self.draining = False
        self.sheds = 0                     # admission-gate rejections
        self._drain_result: dict | None = None

    # -- admission (pre-body: nothing here touches the payload) ------------

    def admit(self):
        """None to admit, else a (status, body, headers) shed reply.
        Called after the request HEAD is parsed and before the body is
        read — an overloaded door never pays deserialization for a
        request it rejects."""
        if self.draining:
            return wire.error_reply(Shutdown("draining: admission fenced"))
        if self.server.state != "running":
            return wire.error_reply(ServerClosed(
                f"server is {self.server.state}, not running"))
        if not self.bucket.admit():
            self.sheds += 1
            return wire.shed_reply("rate",
                                   retry_after_s=self.bucket.retry_after_s())
        if self.max_pending is not None:
            gauges = self.server.metrics.snapshot()["gauges"]
            if gauges.get("pending_requests", 0) >= self.max_pending:
                self.sheds += 1
                return wire.shed_reply("pending")
        return None

    # -- request path ------------------------------------------------------

    async def infer(self, payload: dict):
        """(status, body, headers) for one decoded /v1/infer payload."""
        try:
            faults.trip("http")
            x = wire.decode_array(payload)
            fut = self.server.submit(
                payload["network"], x,
                priority=int(payload.get("priority", 1)),
                deadline_ms=payload.get("deadline_ms"))
        except Exception as e:
            return wire.error_reply(e)
        try:
            row = await asyncio.wait_for(asyncio.wrap_future(fut),
                                         self.request_timeout_s)
        except asyncio.TimeoutError:
            # the future may still resolve — answer 504 NOT retryable so
            # no router re-issues a possibly-still-running request
            return wire.error_reply(DeadlineExceeded(
                f"no result within {self.request_timeout_s}s",
                waited_s=self.request_timeout_s))
        except Exception as e:
            return wire.error_reply(e)
        return 200, {"network": payload["network"],
                     "result": wire.encode_array(row)}, {}

    async def health(self):
        snap = self.server.metrics.snapshot()
        gauges = snap.get("gauges", {})
        ok = (not self.draining
              and gauges.get("state", self.server.state) == "running")
        body = {"ok": ok, "state": gauges.get("state", self.server.state),
                "draining": self.draining,
                "uptime_s": snap.get("uptime_s", 0.0),
                "pending_requests": gauges.get("pending_requests", 0),
                "inflight_batches": gauges.get("inflight_batches", 0),
                "queue_total": gauges.get("queue_total", 0),
                "queue_depth": gauges.get("queue_depth", {}),
                "completed": snap.get("completed", 0),
                "shed": snap.get("shed", 0) + self.sheds}
        return (200 if ok else 503), body, {}

    async def metrics(self):
        return 200, self.server.metrics.snapshot(), {}

    async def drain(self, budget_s: float | None = None):
        """Fence admissions, then gracefully shut the server down off-loop
        under a hard budget.  Idempotent; never hangs."""
        if self._drain_result is not None:
            return 200, self._drain_result, {}
        self.draining = True
        budget = budget_s if budget_s is not None else self.drain_budget_s
        t0 = time.monotonic()
        loop = asyncio.get_running_loop()
        try:
            await asyncio.wait_for(
                loop.run_in_executor(None, self.server.shutdown, budget),
                budget + 1.0)
            timed_out = False
        except asyncio.TimeoutError:    # wedged drain thread: report, the
            timed_out = True            # sweep already fenced admissions
        snap = self.server.metrics.snapshot()
        self._drain_result = {
            "drained": not timed_out,
            "elapsed_s": time.monotonic() - t0,
            "pending_requests": snap["gauges"].get("pending_requests", 0),
            "drain_aborted": snap.get("drain_aborted", 0),
            "drain_flushed": snap.get("drain_flushed", 0)}
        return 200, self._drain_result, {}


class FrontDoor:
    """The HTTP surface: routes requests on one asyncio server to any
    backend exposing ``admit``/``infer``/``health``/``metrics``/``drain``
    (``LocalBackend`` for a worker process, ``repro.frontend.router.
    Router`` for the multi-worker door)."""

    def __init__(self, backend, *, host: str = "127.0.0.1", port: int = 0):
        self.backend = backend
        self.host = host
        self.port = port
        self._srv: asyncio.AbstractServer | None = None
        self.requests = 0

    async def start(self) -> "FrontDoor":
        self._srv = await asyncio.start_server(self._handle, self.host,
                                               self.port)
        self.port = self._srv.sockets[0].getsockname()[1]
        return self

    async def aclose(self) -> None:
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()
            self._srv = None

    async def drain_and_close(self, budget_s: float | None = None) -> dict:
        """SIGTERM path: fence + drain the backend, then stop listening.
        In-flight handler tasks still hold their sockets and answer."""
        _status, body, _h = await self.backend.drain(budget_s)
        await self.aclose()
        return body

    # -- connection handler ------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            head = await wire.read_head(reader)
            if head is None:
                return
            method, path, headers = head
            self.requests += 1
            status, body, extra = await self._route(method, path, headers,
                                                    reader)
            writer.write(wire.response_bytes(status, body, extra))
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass                        # client went away: nothing to answer
        except Exception as e:          # defensive: no traceback on the wire
            try:
                writer.write(wire.response_bytes(*wire.error_reply(e)))
                await writer.drain()
            except Exception:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _route(self, method: str, path: str, headers: dict, reader):
        path = path.split("?", 1)[0]
        if path == "/healthz" and method == "GET":
            return await self.backend.health()
        if path == "/metrics" and method == "GET":
            return await self.backend.metrics()
        if path == "/drain" and method == "POST":
            return await self.backend.drain()
        if path != "/v1/infer":
            return 404, {"error": "not_found", "retryable": False,
                         "message": path}, {}
        if method != "POST":
            return 405, {"error": "method_not_allowed", "retryable": False,
                         "message": method}, {}
        # admission BEFORE the body: shed work, not just requests
        shed = self.backend.admit()
        if shed is not None:
            await self._discard_body(reader, headers)
            return shed
        if int(headers.get("content-length", 0) or 0) > wire.MAX_BODY_BYTES:
            return 413, {"error": "payload_too_large",
                         "retryable": False, "message": ""}, {}
        raw = await wire.read_body(reader, headers)
        try:
            payload = json.loads(raw)
        except Exception as e:
            return 400, {"error": "bad_request", "retryable": False,
                         "message": f"invalid JSON: {e}"}, {}
        return await self.backend.infer(payload)

    @staticmethod
    async def _discard_body(reader, headers) -> None:
        """Drain a shed request's body so the client can read the reply
        (a closed pipe mid-upload reads as a transport error, and a
        transport error would be retried — a shed must stay typed)."""
        try:
            await wire.read_body(reader, headers)
        except Exception:
            pass


class ServerThread:
    """Run a ``FrontDoor`` (and optionally extra startup coroutines, e.g.
    ``Router.start``) on a dedicated event loop in a daemon thread — the
    handle tests, benchmarks and examples drive blocking HTTP clients
    against.

        with ServerThread(FrontDoor(LocalBackend(server))) as h:
            requests -> 127.0.0.1:h.port
    """

    def __init__(self, door: FrontDoor, *, also_start=()):
        self.door = door
        self._also = list(also_start)   # extra "async def start()" objects
        self.loop = asyncio.new_event_loop()
        self._ready = threading.Event()
        self._thread = threading.Thread(target=self._run,
                                        name="frontdoor-loop", daemon=True)

    def _run(self) -> None:
        asyncio.set_event_loop(self.loop)

        async def boot():
            for obj in self._also:
                await obj.start()
            await self.door.start()
            self._ready.set()

        self.loop.run_until_complete(boot())
        self.loop.run_forever()
        # cancel stragglers so the loop closes clean
        for task in asyncio.all_tasks(self.loop):
            task.cancel()
        try:
            self.loop.run_until_complete(
                self.loop.shutdown_asyncgens())
        except Exception:
            pass
        self.loop.close()

    def start(self) -> "ServerThread":
        self._thread.start()
        if not self._ready.wait(30.0):
            raise RuntimeError("front door failed to start in 30s")
        return self

    @property
    def port(self) -> int:
        return self.door.port

    def call(self, coro, timeout: float = 60.0):
        """Run one coroutine on the door's loop from any thread."""
        fut = asyncio.run_coroutine_threadsafe(coro, self.loop)
        return fut.result(timeout)

    def stop(self, drain: bool = True, budget_s: float = DRAIN_BUDGET_S):
        out = None
        if self._thread.is_alive():
            if drain:
                try:
                    out = self.call(self.door.drain_and_close(budget_s),
                                    timeout=budget_s + 5.0)
                except Exception:
                    pass
            self.loop.call_soon_threadsafe(self.loop.stop)
            self._thread.join(10.0)
        return out

    def __enter__(self) -> "ServerThread":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
