"""Multi-worker router: PR 6's circuit breaker lifted to the process level.

``Router`` fronts N shared-nothing workers (each its own ``HeteroServer``
residency — a separate OS process via ``ProcWorker``, or an in-process
``LocalWorker`` for CI-speed tests and benchmarks; both serve the same
``LocalBackend`` request semantics).  It implements the same backend
protocol as ``repro.frontend.app.LocalBackend``, so one ``FrontDoor``
serves either a single worker or a whole fleet.

**Dispatch.**  Least-outstanding among healthy workers (round-robin on
ties).  ``faults.trip("worker", device=<name>)`` fires per forward, so a
worker-path failure is injectable in CI like a device fault.  Since
protocol v2 each ``ProcWorker`` keeps a ``wire.HttpPool`` of persistent
keep-alive connections — forwards ride pooled sockets instead of paying
a dial per request — and a binary-framed request (``_tensor`` payload)
is forwarded as the SAME raw frame with its metadata in headers: the
router hop never transcodes an array, in either direction (a worker's
``application/x-tensor`` response passes through as opaque bytes).

**Retry.**  Exactly ONE re-issue, on a DIFFERENT worker, after a jittered
backoff — and only for failures where the first attempt definitely did
not answer: transport errors (connection refused/reset — the channel is
dead, at most the compute happened twice but the client is answered
once) and wire responses marked ``retryable`` (429/503 typed sheds — the
request was never admitted/served).  504s and other non-retryable codes
return as-is: re-issuing a possibly-still-running request could answer
it twice.

**Health.**  A probe loop GETs each worker's ``/healthz`` (backed by its
``ServerMetrics.snapshot()``): ``eject_after`` consecutive failures —
probe or live-dispatch transport failures alike — eject the worker from
rotation; while ejected, probes continue, and ``reinstate_after``
consecutive passes put it back (the breaker's closed/open/half-open
cycle, per process).  A dead process (``alive()`` False) is ejected
immediately and respawned from its spec — crash-resume re-REGISTERS the
networks (deterministic params per spec, so the respawn serves
bit-identical rows) and rejoins via the same probe-based reinstatement.

**Auto-scaling.**  Give the router a ``worker_factory`` (name -> new
worker) and ``scale_max``, and the probe loop sizes the fleet from the
queue-depth gauge each worker already reports on ``/healthz``
(``pending_requests + queue_total``, plus the router's own outstanding
count): mean depth per healthy worker >= ``scale_up_depth`` spawns a
worker (respecting ``scale_max``); mean depth <= ``scale_down_depth``
retires the least-loaded one down to ``scale_min`` (the starting fleet
size by default).  Retirement reuses the drain machinery — the worker
leaves rotation (state ``"retiring"``), its in-flight forwards settle,
it drains gracefully, THEN the process dies — and scale-ups reuse the
spec-respawn path, so a scaled-up worker serves bit-identical rows.
One scale operation runs at a time, off the probe loop, behind a
``scale_cooldown_s`` hysteresis.

**Admission.**  Weighted per-priority token buckets +
total-outstanding bound at the door, checked before the request body is
even read (``FrontDoor`` calls ``admit()`` between headers and body).

**Drain.**  ``drain()`` fences admission (typed 503 from then on), waits
for the router's own in-flight forwards to settle, then drains every
worker in parallel — each worker's ``HeteroServer.shutdown`` resolves
every admitted future (PR-6 contract) — all under one hard budget, so a
SIGTERM never hangs even with a wedged worker (it is killed at the
budget's edge).
"""
from __future__ import annotations

import asyncio
import json
import os
import random
import signal
import subprocess
import sys
import time

from repro.frontend import wire
from repro.frontend.app import (DRAIN_BUDGET_S, LocalBackend,
                                WeightedTokenBuckets)
from repro.runtime import faults
from repro.serving.errors import Shutdown

RETRYABLE_EXC = (ConnectionError, OSError, asyncio.TimeoutError,
                 asyncio.IncompleteReadError, faults.InjectedFault)


class LocalWorker:
    """An in-process worker: its own ``HeteroServer`` behind the same
    ``LocalBackend`` semantics a worker process serves, minus the socket.
    ``crash()`` emulates process death deterministically: dispatches
    raise ``ConnectionError``, and the orphaned server's admitted futures
    resolve typed via shutdown — exactly what a supervisor sees when a
    real worker dies mid-request."""

    def __init__(self, name: str, factory, *, door: dict | None = None):
        self.name = name
        self.factory = factory               # () -> started HeteroServer
        self._door_cfg = dict(door or {})
        self.server = factory()
        self.backend = LocalBackend(self.server, **self._door_cfg)
        self._dead = False
        self.outstanding = 0
        self.depth = 0                       # queue-depth gauge (probes)
        self.state = "healthy"       # router-managed: | ejected | retiring
        self.fails = 0
        self.oks = 0
        self.restarting = False
        self.restarts = 0

    def alive(self) -> bool:
        return not self._dead

    def crash(self) -> None:
        """Simulate the process dying NOW."""
        self._dead = True
        srv = self.server
        import threading
        threading.Thread(target=lambda: srv.shutdown(2.0),
                         daemon=True).start()

    async def restart(self) -> None:
        loop = asyncio.get_running_loop()
        self.server = await loop.run_in_executor(None, self.factory)
        self.backend = LocalBackend(self.server, **self._door_cfg)
        self._dead = False
        self.restarts += 1

    async def infer(self, payload: dict):
        if self._dead:
            raise ConnectionError(f"{self.name}: worker dead")
        shed = self.backend.admit(int(payload.get("priority", 1)))
        if shed is not None:
            return shed
        out = await self.backend.infer(payload)
        if self._dead:
            # died while serving: the socket would have reset before the
            # response left the process
            raise ConnectionError(f"{self.name}: worker died mid-request")
        return out

    async def healthz(self):
        if self._dead:
            raise ConnectionError(f"{self.name}: worker dead")
        return await self.backend.health()

    async def drain(self, budget_s: float) -> None:
        if not self._dead:
            await self.backend.drain(budget_s)

    def terminate(self) -> None:
        self.crash()


class ProcWorker:
    """A worker OS process (``python -m repro.frontend.worker``) plus the
    HTTP client half: spawn, READY handshake, pooled keep-alive requests
    (``wire.HttpPool`` — no dial per forward), SIGTERM drain, kill.
    ``restart()`` respawns from the same spec — the crash-resume path."""

    def __init__(self, name: str, spec: dict, *,
                 startup_timeout_s: float = 120.0,
                 request_timeout_s: float = 60.0,
                 probe_timeout_s: float = 5.0,
                 pool_size: int = 8):
        self.name = name
        self.spec = dict(spec)
        self.spec.setdefault("port", 0)
        self.startup_timeout_s = startup_timeout_s
        self.request_timeout_s = request_timeout_s
        self.probe_timeout_s = probe_timeout_s
        self.pool_size = pool_size
        self.proc: subprocess.Popen | None = None
        self.host = "127.0.0.1"
        self.port: int | None = None
        self.pool: wire.HttpPool | None = None
        self.outstanding = 0
        self.depth = 0
        self.state = "healthy"
        self.fails = 0
        self.oks = 0
        self.restarting = False
        self.restarts = 0

    # -- process lifecycle -------------------------------------------------

    def _spawn(self) -> None:
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.frontend.worker",
             "--spec", json.dumps(self.spec)],
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
            env=env, text=True)
        t_end = time.monotonic() + self.startup_timeout_s
        while time.monotonic() < t_end:
            line = self.proc.stdout.readline()
            if not line:
                break
            if line.startswith("READY"):
                fields = dict(kv.split("=", 1)
                              for kv in line.split()[1:] if "=" in kv)
                self.host = fields.get("host", "127.0.0.1")
                self.port = int(fields["port"])
                self.pool = wire.HttpPool(self.host, self.port,
                                          size=self.pool_size)
                return
        raise RuntimeError(f"{self.name}: worker never became READY")

    async def start(self) -> "ProcWorker":
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self._spawn)
        return self

    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    async def restart(self) -> None:
        if self.alive():
            self.terminate()
        if self.pool is not None:
            self.pool.close()           # stale sockets die with the corpse
        await self.start()
        self.restarts += 1

    def terminate(self) -> None:
        # the pool's sockets reset with the process; a later checkout
        # fails fast and feeds the ejection count — no cross-thread
        # transport close needed here
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait(5.0)

    # -- request path ------------------------------------------------------

    def _pool(self) -> wire.HttpPool:
        if self.pool is None:
            raise ConnectionError(f"{self.name}: worker not started")
        return self.pool

    async def infer(self, payload: dict):
        """Forward one request on a pooled connection.  ``_tensor``
        payloads go out as the raw binary frame with metadata headers
        (no transcode); a worker's ``x-tensor`` response comes back as
        opaque bytes the door writes straight through."""
        if "_tensor" in payload:
            body = payload["_tensor"]
            headers = {"Content-Type": wire.TENSOR_CONTENT_TYPE,
                       "X-Network": str(payload.get("network", ""))}
            if "priority" in payload:
                headers["X-Priority"] = str(int(payload["priority"]))
            if payload.get("deadline_ms") is not None:
                headers["X-Deadline-Ms"] = \
                    f"{float(payload['deadline_ms']):g}"
        else:
            send = {k: v for k, v in payload.items()
                    if not k.startswith("_")}
            body = json.dumps(send).encode()
            headers = {"Content-Type": "application/json"}
        if payload.get("_accept"):
            headers["Accept"] = payload["_accept"]
        status, rheaders, raw = await self._pool().request(
            "POST", "/v1/infer", body=body, headers=headers,
            timeout=self.request_timeout_s)
        ctype = rheaders.get("content-type", "")
        if ctype.startswith(wire.TENSOR_CONTENT_TYPE):
            return status, raw, {"content-type": ctype,
                                 "x-network": rheaders.get("x-network", "")}
        return status, (json.loads(raw) if raw else None), dict(rheaders)

    async def healthz(self):
        status, _headers, raw = await self._pool().request(
            "GET", "/healthz", timeout=self.probe_timeout_s)
        return status, (json.loads(raw) if raw else None), {}

    async def drain(self, budget_s: float) -> None:
        """SIGTERM-initiated graceful drain; hard-kill at the budget."""
        if not self.alive():
            return
        self.proc.send_signal(signal.SIGTERM)
        loop = asyncio.get_running_loop()
        try:
            await asyncio.wait_for(
                loop.run_in_executor(None, self.proc.wait),
                budget_s)
        except asyncio.TimeoutError:
            self.terminate()
        if self.pool is not None:
            self.pool.close()


class Router:
    """Health-checked least-outstanding dispatch over a worker fleet.
    Implements the front-door backend protocol (``admit``/``infer``/
    ``health``/``metrics``/``drain``)."""

    def __init__(self, workers, *, rate: float | None = None,
                 burst: int = 64, weights: dict | None = None,
                 max_outstanding: int | None = None,
                 eject_after: int = 3, reinstate_after: int = 2,
                 probe_interval_s: float = 0.05,
                 probe_timeout_s: float = 2.0,
                 retry_backoff_s: float = 0.01,
                 auto_restart: bool = True,
                 worker_factory=None, scale_min: int | None = None,
                 scale_max: int | None = None,
                 scale_up_depth: float = 8.0,
                 scale_down_depth: float = 1.0,
                 scale_cooldown_s: float = 1.0,
                 drain_budget_s: float = DRAIN_BUDGET_S,
                 seed: int = 0):
        self.workers = list(workers)
        if not self.workers:
            raise ValueError("Router needs at least one worker")
        self.buckets = WeightedTokenBuckets(rate, burst, weights)
        self.max_outstanding = max_outstanding
        self.eject_after = max(1, int(eject_after))
        self.reinstate_after = max(1, int(reinstate_after))
        self.probe_interval_s = probe_interval_s
        self.probe_timeout_s = probe_timeout_s
        self.retry_backoff_s = retry_backoff_s
        self.auto_restart = auto_restart
        self.worker_factory = worker_factory
        self.scale_min = (len(self.workers) if scale_min is None
                          else max(1, int(scale_min)))
        self.scale_max = scale_max
        self.scale_up_depth = float(scale_up_depth)
        self.scale_down_depth = float(scale_down_depth)
        self.scale_cooldown_s = float(scale_cooldown_s)
        self.drain_budget_s = drain_budget_s
        self.draining = False
        self._rng = random.Random(seed)
        self._rr = 0                          # round-robin tiebreaker
        self._outstanding = 0
        self._probe_task: asyncio.Task | None = None
        self._scaling = False                 # one scale op at a time
        self._scale_task: asyncio.Task | None = None
        self._last_scale = time.monotonic()
        self._auto_seq = 0
        self.counters = {"dispatched": 0, "retries": 0, "sheds": 0,
                         "ejections": 0, "reinstatements": 0,
                         "restarts": 0, "no_worker": 0, "probes": 0,
                         "scale_ups": 0, "scale_downs": 0}

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "Router":
        for w in self.workers:
            if isinstance(w, ProcWorker) and w.port is None:
                await w.start()
        self._probe_task = asyncio.ensure_future(self._probe_loop())
        return self

    async def aclose(self) -> None:
        for task in (self._probe_task, self._scale_task):
            if task is not None and not task.done():
                task.cancel()
                try:
                    await task
                except (asyncio.CancelledError, Exception):
                    pass
        self._probe_task = None
        self._scale_task = None

    # -- admission (pre-body) ----------------------------------------------

    def admit(self, priority: int = 1):
        if self.draining:
            return wire.error_reply(Shutdown("router draining: admission "
                                             "fenced"))
        if not self.buckets.admit(priority):
            self.counters["sheds"] += 1
            return wire.shed_reply(
                "rate", retry_after_s=self.buckets.retry_after_s(priority))
        if (self.max_outstanding is not None
                and self._outstanding >= self.max_outstanding):
            self.counters["sheds"] += 1
            return wire.shed_reply("outstanding")
        return None

    # -- dispatch ----------------------------------------------------------

    def _healthy(self, exclude=()):
        return [w for w in self.workers
                if w.state == "healthy" and w.alive() and w not in exclude]

    def _pick(self, exclude=()):
        pool = self._healthy(exclude)
        if not pool:
            return None
        lo = min(w.outstanding for w in pool)
        pool = [w for w in pool if w.outstanding == lo]
        self._rr += 1
        return pool[self._rr % len(pool)]

    async def _forward(self, w, payload: dict):
        """One attempt on one worker.  Transport failures come back as a
        typed retryable 503 (and feed the worker's ejection count) — the
        retry decision upstream only ever reads (status, body)."""
        w.outstanding += 1
        self._outstanding += 1
        try:
            faults.trip("worker", device=w.name)
            return await w.infer(payload)
        except RETRYABLE_EXC as e:
            self._record_failure(w)
            return 503, {"error": "worker_unreachable", "retryable": True,
                         "worker": w.name,
                         "message": f"{type(e).__name__}: {e}"}, {}
        finally:
            w.outstanding -= 1
            self._outstanding -= 1

    async def infer(self, payload: dict):
        self.counters["dispatched"] += 1
        w = self._pick()
        if w is None:
            self.counters["no_worker"] += 1
            return 503, {"error": "no_healthy_worker", "retryable": True,
                         "message": "every worker ejected or dead"}, {}
        status, body, headers = await self._forward(w, payload)
        if (status != 200 and wire.is_retryable(status, body)
                and not self.draining):
            w2 = self._pick(exclude=(w,))
            if w2 is not None:
                # ONE bounded retry, jittered so synchronized failures
                # don't re-converge on the same instant
                self.counters["retries"] += 1
                await asyncio.sleep(
                    self.retry_backoff_s * (0.5 + self._rng.random()))
                status, body, headers = await self._forward(w2, payload)
                if isinstance(body, dict):
                    body = dict(body)
                    body["retried"] = True
        return status, body, headers

    # -- health: probe loop, ejection, reinstatement, crash-resume ---------

    def _record_failure(self, w) -> None:
        w.oks = 0
        w.fails += 1
        if w.fails >= self.eject_after and w.state == "healthy":
            w.state = "ejected"
            self.counters["ejections"] += 1

    def _record_pass(self, w) -> None:
        w.fails = 0
        if w.state == "ejected":
            w.oks += 1
            if w.oks >= self.reinstate_after:
                w.state = "healthy"
                w.oks = 0
                self.counters["reinstatements"] += 1

    async def _probe_one(self, w) -> None:
        if w.state == "retiring":       # leaving anyway: don't respawn it
            return
        if not w.alive():
            self._record_failure(w)
            if w.state == "healthy":        # eject a corpse immediately
                w.state = "ejected"
                self.counters["ejections"] += 1
            if self.auto_restart and not w.restarting and not self.draining:
                w.restarting = True
                try:
                    await w.restart()
                    self.counters["restarts"] += 1
                except Exception:
                    pass                    # next probe tick tries again
                finally:
                    w.restarting = False
            return
        try:
            status, body, _h = await asyncio.wait_for(
                w.healthz(), self.probe_timeout_s)
            ok = status == 200 and bool((body or {}).get("ok", False))
            if isinstance(body, dict):
                # the autoscaler's signal: queued + admitted-not-served
                w.depth = (int(body.get("pending_requests", 0))
                           + int(body.get("queue_total", 0)))
        except Exception:
            ok = False
        self.counters["probes"] += 1
        if ok:
            self._record_pass(w)
        else:
            self._record_failure(w)

    async def _probe_loop(self) -> None:
        while not self.draining:
            await asyncio.gather(*(self._probe_one(w)
                                   for w in self.workers))
            self._autoscale_tick()
            await asyncio.sleep(self.probe_interval_s)

    # -- auto-scaling ------------------------------------------------------

    def autoscale_enabled(self) -> bool:
        return (self.worker_factory is not None
                and self.scale_max is not None)

    def _autoscale_tick(self) -> None:
        """Size the fleet from the queue-depth gauge.  Decisions are
        taken on the probe loop; the scale operation itself (spawn with
        its compile/warm time, or drain-and-retire) runs as its own task
        so probing — ejection detection — never stalls behind it."""
        if (not self.autoscale_enabled() or self._scaling
                or self.draining):
            return
        if time.monotonic() - self._last_scale < self.scale_cooldown_s:
            return
        healthy = self._healthy()
        if not healthy:
            return
        depth = (sum(w.depth + w.outstanding for w in healthy)
                 / len(healthy))
        n_live = len([w for w in self.workers if w.state != "retiring"])
        if depth >= self.scale_up_depth and n_live < self.scale_max:
            self._scaling = True
            self._scale_task = asyncio.ensure_future(self._scale_up())
        elif (depth <= self.scale_down_depth and n_live > self.scale_min
                and len(healthy) > 1):
            victim = min(healthy, key=lambda w: (w.outstanding, w.depth))
            self._scaling = True
            self._scale_task = asyncio.ensure_future(
                self._scale_down(victim))

    async def _scale_up(self) -> None:
        try:
            name = f"auto{self._auto_seq}"
            self._auto_seq += 1
            w = self.worker_factory(name)
            if isinstance(w, ProcWorker) and w.port is None:
                await w.start()         # spec-respawn path: bit-identical
            self.workers.append(w)      # join AFTER ready: never dispatch
            self.counters["scale_ups"] += 1     # to a half-started worker
        except Exception:
            pass                        # next tick may try again
        finally:
            self._last_scale = time.monotonic()
            self._scaling = False

    async def _scale_down(self, w) -> None:
        try:
            w.state = "retiring"        # out of rotation, probes skip it
            t_end = time.monotonic() + self.drain_budget_s
            while w.outstanding > 0 and time.monotonic() < t_end:
                await asyncio.sleep(0.01)
            try:                        # graceful: resolves admitted work
                await asyncio.wait_for(
                    w.drain(self.drain_budget_s), self.drain_budget_s + 1.0)
            except Exception:
                pass
            try:
                w.terminate()
            except Exception:
                pass
            if w in self.workers:
                self.workers.remove(w)
            self.counters["scale_downs"] += 1
        finally:
            self._last_scale = time.monotonic()
            self._scaling = False

    # -- observability -----------------------------------------------------

    def snapshot(self) -> dict:
        return {"counters": dict(self.counters),
                "draining": self.draining,
                "outstanding": self._outstanding,
                "n_workers": len(self.workers),
                "autoscale": {"enabled": self.autoscale_enabled(),
                              "min": self.scale_min, "max": self.scale_max},
                "workers": {w.name: {"state": w.state,
                                     "alive": w.alive(),
                                     "outstanding": w.outstanding,
                                     "depth": getattr(w, "depth", 0),
                                     "fails": w.fails, "oks": w.oks,
                                     "restarts": w.restarts}
                            for w in self.workers}}

    async def health(self):
        snap = self.snapshot()
        ok = not self.draining and bool(self._healthy())
        snap["ok"] = ok
        return (200 if ok else 503), snap, {}

    async def metrics(self):
        return 200, self.snapshot(), {}

    # -- drain -------------------------------------------------------------

    async def drain(self, budget_s: float | None = None):
        """Fence, settle, drain every worker in parallel, never hang."""
        budget = budget_s if budget_s is not None else self.drain_budget_s
        t0 = time.monotonic()
        self.draining = True                 # fence: admit() rejects now
        await self.aclose()                  # stop probing/respawn/scaling
        # settle the router's own in-flight forwards (they answer their
        # clients through the workers' own drains below)
        while self._outstanding > 0 and time.monotonic() - t0 < budget:
            await asyncio.sleep(0.005)
        remaining = max(0.5, budget - (time.monotonic() - t0))

        async def _drain_one(w):
            try:
                await asyncio.wait_for(w.drain(remaining), remaining + 1.0)
            except Exception:
                try:
                    w.terminate()           # budget's edge: hard stop
                except Exception:
                    pass

        await asyncio.gather(*(_drain_one(w) for w in self.workers))
        return 200, {"drained": True,
                     "elapsed_s": time.monotonic() - t0,
                     "outstanding": self._outstanding,
                     "counters": dict(self.counters)}, {}
