"""Multi-worker router: PR 6's circuit breaker lifted to the process level.

``Router`` fronts N shared-nothing workers (each its own ``HeteroServer``
residency — a separate OS process via ``ProcWorker``, or an in-process
``LocalWorker`` for CI-speed tests and benchmarks; both serve the same
``LocalBackend`` request semantics).  It implements the same backend
protocol as ``repro.frontend.app.LocalBackend``, so one ``FrontDoor``
serves either a single worker or a whole fleet.

**Dispatch.**  Least-outstanding among healthy workers (round-robin on
ties).  ``faults.trip("worker", device=<name>)`` fires per forward, so a
worker-path failure is injectable in CI like a device fault.

**Retry.**  Exactly ONE re-issue, on a DIFFERENT worker, after a jittered
backoff — and only for failures where the first attempt definitely did
not answer: transport errors (connection refused/reset — the channel is
dead, at most the compute happened twice but the client is answered
once) and wire responses marked ``retryable`` (429/503 typed sheds — the
request was never admitted/served).  504s and other non-retryable codes
return as-is: re-issuing a possibly-still-running request could answer
it twice.

**Health.**  A probe loop GETs each worker's ``/healthz`` (backed by its
``ServerMetrics.snapshot()``): ``eject_after`` consecutive failures —
probe or live-dispatch transport failures alike — eject the worker from
rotation; while ejected, probes continue, and ``reinstate_after``
consecutive passes put it back (the breaker's closed/open/half-open
cycle, per process).  A dead process (``alive()`` False) is ejected
immediately and respawned from its spec — crash-resume re-REGISTERS the
networks (deterministic params per spec, so the respawn serves
bit-identical rows) and rejoins via the same probe-based reinstatement.

**Admission.**  Token bucket + total-outstanding bound at the door,
checked before the request body is even read (``FrontDoor`` calls
``admit()`` between headers and body).

**Drain.**  ``drain()`` fences admission (typed 503 from then on), waits
for the router's own in-flight forwards to settle, then drains every
worker in parallel — each worker's ``HeteroServer.shutdown`` resolves
every admitted future (PR-6 contract) — all under one hard budget, so a
SIGTERM never hangs even with a wedged worker (it is killed at the
budget's edge).
"""
from __future__ import annotations

import asyncio
import json
import os
import random
import signal
import subprocess
import sys
import time

from repro.frontend import wire
from repro.frontend.app import DRAIN_BUDGET_S, LocalBackend, TokenBucket
from repro.runtime import faults
from repro.serving.errors import Shutdown

RETRYABLE_EXC = (ConnectionError, OSError, asyncio.TimeoutError,
                 asyncio.IncompleteReadError, faults.InjectedFault)


class LocalWorker:
    """An in-process worker: its own ``HeteroServer`` behind the same
    ``LocalBackend`` semantics a worker process serves, minus the socket.
    ``crash()`` emulates process death deterministically: dispatches
    raise ``ConnectionError``, and the orphaned server's admitted futures
    resolve typed via shutdown — exactly what a supervisor sees when a
    real worker dies mid-request."""

    def __init__(self, name: str, factory, *, door: dict | None = None):
        self.name = name
        self.factory = factory               # () -> started HeteroServer
        self._door_cfg = dict(door or {})
        self.server = factory()
        self.backend = LocalBackend(self.server, **self._door_cfg)
        self._dead = False
        self.outstanding = 0
        self.state = "healthy"               # router-managed: | "ejected"
        self.fails = 0
        self.oks = 0
        self.restarting = False
        self.restarts = 0

    def alive(self) -> bool:
        return not self._dead

    def crash(self) -> None:
        """Simulate the process dying NOW."""
        self._dead = True
        srv = self.server
        import threading
        threading.Thread(target=lambda: srv.shutdown(2.0),
                         daemon=True).start()

    async def restart(self) -> None:
        loop = asyncio.get_running_loop()
        self.server = await loop.run_in_executor(None, self.factory)
        self.backend = LocalBackend(self.server, **self._door_cfg)
        self._dead = False
        self.restarts += 1

    async def infer(self, payload: dict):
        if self._dead:
            raise ConnectionError(f"{self.name}: worker dead")
        shed = self.backend.admit()
        if shed is not None:
            return shed
        out = await self.backend.infer(payload)
        if self._dead:
            # died while serving: the socket would have reset before the
            # response left the process
            raise ConnectionError(f"{self.name}: worker died mid-request")
        return out

    async def healthz(self):
        if self._dead:
            raise ConnectionError(f"{self.name}: worker dead")
        return await self.backend.health()

    async def drain(self, budget_s: float) -> None:
        if not self._dead:
            await self.backend.drain(budget_s)

    def terminate(self) -> None:
        self.crash()


class ProcWorker:
    """A worker OS process (``python -m repro.frontend.worker``) plus the
    HTTP client half: spawn, READY handshake, JSON requests, SIGTERM
    drain, kill.  ``restart()`` respawns from the same spec — the
    crash-resume path."""

    def __init__(self, name: str, spec: dict, *,
                 startup_timeout_s: float = 120.0,
                 request_timeout_s: float = 60.0,
                 probe_timeout_s: float = 5.0):
        self.name = name
        self.spec = dict(spec)
        self.spec.setdefault("port", 0)
        self.startup_timeout_s = startup_timeout_s
        self.request_timeout_s = request_timeout_s
        self.probe_timeout_s = probe_timeout_s
        self.proc: subprocess.Popen | None = None
        self.host = "127.0.0.1"
        self.port: int | None = None
        self.outstanding = 0
        self.state = "healthy"
        self.fails = 0
        self.oks = 0
        self.restarting = False
        self.restarts = 0

    # -- process lifecycle -------------------------------------------------

    def _spawn(self) -> None:
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.frontend.worker",
             "--spec", json.dumps(self.spec)],
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
            env=env, text=True)
        t_end = time.monotonic() + self.startup_timeout_s
        while time.monotonic() < t_end:
            line = self.proc.stdout.readline()
            if not line:
                break
            if line.startswith("READY"):
                fields = dict(kv.split("=", 1)
                              for kv in line.split()[1:] if "=" in kv)
                self.host = fields.get("host", "127.0.0.1")
                self.port = int(fields["port"])
                return
        raise RuntimeError(f"{self.name}: worker never became READY")

    async def start(self) -> "ProcWorker":
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self._spawn)
        return self

    def alive(self) -> bool:
        return self.proc is not None and self.proc.poll() is None

    async def restart(self) -> None:
        if self.alive():
            self.terminate()
        await self.start()
        self.restarts += 1

    def terminate(self) -> None:
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait(5.0)

    # -- request path ------------------------------------------------------

    async def infer(self, payload: dict):
        status, headers, body = await wire.http_json(
            self.host, self.port, "POST", "/v1/infer", payload,
            timeout=self.request_timeout_s)
        return status, body, dict(headers)

    async def healthz(self):
        status, _headers, body = await wire.http_json(
            self.host, self.port, "GET", "/healthz",
            timeout=self.probe_timeout_s)
        return status, body, {}

    async def drain(self, budget_s: float) -> None:
        """SIGTERM-initiated graceful drain; hard-kill at the budget."""
        if not self.alive():
            return
        self.proc.send_signal(signal.SIGTERM)
        loop = asyncio.get_running_loop()
        try:
            await asyncio.wait_for(
                loop.run_in_executor(None, self.proc.wait),
                budget_s)
        except asyncio.TimeoutError:
            self.terminate()


class Router:
    """Health-checked least-outstanding dispatch over a worker fleet.
    Implements the front-door backend protocol (``admit``/``infer``/
    ``health``/``metrics``/``drain``)."""

    def __init__(self, workers, *, rate: float | None = None,
                 burst: int = 64, max_outstanding: int | None = None,
                 eject_after: int = 3, reinstate_after: int = 2,
                 probe_interval_s: float = 0.05,
                 probe_timeout_s: float = 2.0,
                 retry_backoff_s: float = 0.01,
                 auto_restart: bool = True,
                 drain_budget_s: float = DRAIN_BUDGET_S,
                 seed: int = 0):
        self.workers = list(workers)
        if not self.workers:
            raise ValueError("Router needs at least one worker")
        self.bucket = TokenBucket(rate, burst)
        self.max_outstanding = max_outstanding
        self.eject_after = max(1, int(eject_after))
        self.reinstate_after = max(1, int(reinstate_after))
        self.probe_interval_s = probe_interval_s
        self.probe_timeout_s = probe_timeout_s
        self.retry_backoff_s = retry_backoff_s
        self.auto_restart = auto_restart
        self.drain_budget_s = drain_budget_s
        self.draining = False
        self._rng = random.Random(seed)
        self._rr = 0                          # round-robin tiebreaker
        self._outstanding = 0
        self._probe_task: asyncio.Task | None = None
        self.counters = {"dispatched": 0, "retries": 0, "sheds": 0,
                         "ejections": 0, "reinstatements": 0,
                         "restarts": 0, "no_worker": 0, "probes": 0}

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "Router":
        for w in self.workers:
            if isinstance(w, ProcWorker) and w.port is None:
                await w.start()
        self._probe_task = asyncio.ensure_future(self._probe_loop())
        return self

    async def aclose(self) -> None:
        if self._probe_task is not None:
            self._probe_task.cancel()
            try:
                await self._probe_task
            except (asyncio.CancelledError, Exception):
                pass
            self._probe_task = None

    # -- admission (pre-body) ----------------------------------------------

    def admit(self):
        if self.draining:
            return wire.error_reply(Shutdown("router draining: admission "
                                             "fenced"))
        if not self.bucket.admit():
            self.counters["sheds"] += 1
            return wire.shed_reply(
                "rate", retry_after_s=self.bucket.retry_after_s())
        if (self.max_outstanding is not None
                and self._outstanding >= self.max_outstanding):
            self.counters["sheds"] += 1
            return wire.shed_reply("outstanding")
        return None

    # -- dispatch ----------------------------------------------------------

    def _healthy(self, exclude=()):
        return [w for w in self.workers
                if w.state == "healthy" and w.alive() and w not in exclude]

    def _pick(self, exclude=()):
        pool = self._healthy(exclude)
        if not pool:
            return None
        lo = min(w.outstanding for w in pool)
        pool = [w for w in pool if w.outstanding == lo]
        self._rr += 1
        return pool[self._rr % len(pool)]

    async def _forward(self, w, payload: dict):
        """One attempt on one worker.  Transport failures come back as a
        typed retryable 503 (and feed the worker's ejection count) — the
        retry decision upstream only ever reads (status, body)."""
        w.outstanding += 1
        self._outstanding += 1
        try:
            faults.trip("worker", device=w.name)
            return await w.infer(payload)
        except RETRYABLE_EXC as e:
            self._record_failure(w)
            return 503, {"error": "worker_unreachable", "retryable": True,
                         "worker": w.name,
                         "message": f"{type(e).__name__}: {e}"}, {}
        finally:
            w.outstanding -= 1
            self._outstanding -= 1

    async def infer(self, payload: dict):
        self.counters["dispatched"] += 1
        w = self._pick()
        if w is None:
            self.counters["no_worker"] += 1
            return 503, {"error": "no_healthy_worker", "retryable": True,
                         "message": "every worker ejected or dead"}, {}
        status, body, headers = await self._forward(w, payload)
        if (status != 200 and wire.is_retryable(status, body)
                and not self.draining):
            w2 = self._pick(exclude=(w,))
            if w2 is not None:
                # ONE bounded retry, jittered so synchronized failures
                # don't re-converge on the same instant
                self.counters["retries"] += 1
                await asyncio.sleep(
                    self.retry_backoff_s * (0.5 + self._rng.random()))
                status, body, headers = await self._forward(w2, payload)
                if isinstance(body, dict):
                    body = dict(body)
                    body["retried"] = True
        return status, body, headers

    # -- health: probe loop, ejection, reinstatement, crash-resume ---------

    def _record_failure(self, w) -> None:
        w.oks = 0
        w.fails += 1
        if w.fails >= self.eject_after and w.state == "healthy":
            w.state = "ejected"
            self.counters["ejections"] += 1

    def _record_pass(self, w) -> None:
        w.fails = 0
        if w.state == "ejected":
            w.oks += 1
            if w.oks >= self.reinstate_after:
                w.state = "healthy"
                w.oks = 0
                self.counters["reinstatements"] += 1

    async def _probe_one(self, w) -> None:
        if not w.alive():
            self._record_failure(w)
            if w.state == "healthy":        # eject a corpse immediately
                w.state = "ejected"
                self.counters["ejections"] += 1
            if self.auto_restart and not w.restarting and not self.draining:
                w.restarting = True
                try:
                    await w.restart()
                    self.counters["restarts"] += 1
                except Exception:
                    pass                    # next probe tick tries again
                finally:
                    w.restarting = False
            return
        try:
            status, body, _h = await asyncio.wait_for(
                w.healthz(), self.probe_timeout_s)
            ok = status == 200 and bool((body or {}).get("ok", False))
        except Exception:
            ok = False
        self.counters["probes"] += 1
        if ok:
            self._record_pass(w)
        else:
            self._record_failure(w)

    async def _probe_loop(self) -> None:
        while not self.draining:
            await asyncio.gather(*(self._probe_one(w)
                                   for w in self.workers))
            await asyncio.sleep(self.probe_interval_s)

    # -- observability -----------------------------------------------------

    def snapshot(self) -> dict:
        return {"counters": dict(self.counters),
                "draining": self.draining,
                "outstanding": self._outstanding,
                "workers": {w.name: {"state": w.state,
                                     "alive": w.alive(),
                                     "outstanding": w.outstanding,
                                     "fails": w.fails, "oks": w.oks,
                                     "restarts": w.restarts}
                            for w in self.workers}}

    async def health(self):
        snap = self.snapshot()
        ok = not self.draining and bool(self._healthy())
        snap["ok"] = ok
        return (200 if ok else 503), snap, {}

    async def metrics(self):
        return 200, self.snapshot(), {}

    # -- drain -------------------------------------------------------------

    async def drain(self, budget_s: float | None = None):
        """Fence, settle, drain every worker in parallel, never hang."""
        budget = budget_s if budget_s is not None else self.drain_budget_s
        t0 = time.monotonic()
        self.draining = True                 # fence: admit() rejects now
        await self.aclose()                  # stop probing/respawning
        # settle the router's own in-flight forwards (they answer their
        # clients through the workers' own drains below)
        while self._outstanding > 0 and time.monotonic() - t0 < budget:
            await asyncio.sleep(0.005)
        remaining = max(0.5, budget - (time.monotonic() - t0))

        async def _drain_one(w):
            try:
                await asyncio.wait_for(w.drain(remaining), remaining + 1.0)
            except Exception:
                try:
                    w.terminate()           # budget's edge: hard stop
                except Exception:
                    pass

        await asyncio.gather(*(_drain_one(w) for w in self.workers))
        return 200, {"drained": True,
                     "elapsed_s": time.monotonic() - t0,
                     "outstanding": self._outstanding,
                     "counters": dict(self.counters)}, {}
