"""Process-level serving front door (PR 9; data plane v2 since PR 10).

``repro.serving`` answers in-process ``submit()`` calls; this package
puts a network boundary and a process supervisor in front of it:

  * ``wire``   — HTTP/1.1 protocol in two framings (JSON-base64 and
                 binary ``application/x-tensor``, ``Accept``-negotiated,
                 bit-match parity); typed serving errors cross as stable
                 ``code``/``retryable`` wire fields; ``HttpPool``
                 persistent keep-alive client connections.
  * ``app``    — ``FrontDoor`` (asyncio keep-alive HTTP door with
                 pipelined in-order responses), ``LocalBackend`` (one
                 in-process ``HeteroServer``), ``TokenBucket``/
                 ``WeightedTokenBuckets`` admission (per-priority-class
                 weighted refill), ``ServerThread`` harness.
  * ``router`` — ``Router`` (least-outstanding dispatch over pooled
                 connections, health-probe ejection/reinstatement,
                 one-retry-elsewhere, queue-depth worker auto-scaling,
                 fleet drain) over ``LocalWorker``/``ProcWorker``
                 fleets.
  * ``worker`` — the ``python -m repro.frontend.worker`` process
                 entrypoint (spec-driven registration, READY handshake,
                 SIGTERM graceful drain).
"""
from repro.frontend.app import (DRAIN_BUDGET_S, FrontDoor, LocalBackend,
                                ServerThread, TokenBucket,
                                WeightedTokenBuckets)
from repro.frontend.router import LocalWorker, ProcWorker, Router

__all__ = ["DRAIN_BUDGET_S", "FrontDoor", "LocalBackend", "ServerThread",
           "TokenBucket", "WeightedTokenBuckets", "LocalWorker",
           "ProcWorker", "Router", "build_server", "make_door", "wire"]


def __getattr__(name):
    # lazy re-export: importing `worker` here would make
    # `python -m repro.frontend.worker` warn about the module already
    # being in sys.modules before runpy executes it as __main__
    if name in ("build_server", "make_door"):
        from repro.frontend import worker
        return getattr(worker, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
