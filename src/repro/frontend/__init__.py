"""Process-level serving front door (PR 9).

``repro.serving`` answers in-process ``submit()`` calls; this package
puts a network boundary and a process supervisor in front of it:

  * ``wire``   — JSON-over-HTTP/1.1 protocol; typed serving errors cross
                 as stable ``code``/``retryable`` wire fields.
  * ``app``    — ``FrontDoor`` (asyncio HTTP door), ``LocalBackend``
                 (one in-process ``HeteroServer``), ``TokenBucket``
                 admission, ``ServerThread`` harness.
  * ``router`` — ``Router`` (least-outstanding dispatch, health-probe
                 ejection/reinstatement, one-retry-elsewhere, fleet
                 drain) over ``LocalWorker``/``ProcWorker`` fleets.
  * ``worker`` — the ``python -m repro.frontend.worker`` process
                 entrypoint (spec-driven registration, READY handshake,
                 SIGTERM graceful drain).
"""
from repro.frontend.app import (DRAIN_BUDGET_S, FrontDoor, LocalBackend,
                                ServerThread, TokenBucket)
from repro.frontend.router import LocalWorker, ProcWorker, Router

__all__ = ["DRAIN_BUDGET_S", "FrontDoor", "LocalBackend", "ServerThread",
           "TokenBucket", "LocalWorker", "ProcWorker", "Router",
           "build_server", "make_door", "wire"]


def __getattr__(name):
    # lazy re-export: importing `worker` here would make
    # `python -m repro.frontend.worker` warn about the module already
    # being in sys.modules before runpy executes it as __main__
    if name in ("build_server", "make_door"):
        from repro.frontend import worker
        return getattr(worker, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
